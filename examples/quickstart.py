"""Quickstart: the paper's full pipeline in ~50 lines — for every kernel family.

  benchmark table -> normalize -> cluster-select kernels -> train classifier
  -> deploy a multi-family bundle -> an isolated KernelRuntime dispatches
  every matmul, attention, WKV, and selective-scan launch in a model.

Fully on the redesigned explicit-handle API (DESIGN.md §10): nothing here
touches process-global state, and the whole lifecycle is

    bundle = repro.tune(...)            # or core tune() on your own dataset
    router = bundle.router(model, params)   # one engine per tuned device
    ticket = router.submit(prompt)          # SLO-aware dispatch + admission
    for tok in ticket.tokens(): ...         # streams while the fleet serves

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

import repro
from repro.core.codegen import tree_to_python
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.tuner import tune
from repro.kernels import ops

# 1. A benchmark table: 150 GEMM problems x 210 kernel configs.
#    (Analytic TPU-v5e model here; measured data plugs in identically —
#     see repro.core.cpubench for the real host-CPU source.)
dataset = build_model_dataset(synthetic_problems(150))
print(f"dataset: {len(dataset.problems)} problems x {len(dataset.configs)} configs")

# 2. The paper's pipeline: PCA+K-means selects 8 matmul kernels to deploy and
#    a decision tree learns to pick among them at runtime — and because every
#    op is a registered kernel family (repro.core.families), the SAME
#    pipeline prunes + classifies attention, WKV, and the selective-SSM scan.
#    (repro.tune(...) wraps this for whole-fleet, multi-device tuning.)
result = tune(dataset, n_kernels=8, method="pca_kmeans", classifier="DecisionTreeA")
dep = result.deployment
for fname in dep.family_names():
    configs, _tree = dep.family_tuning(fname)
    print(f"deployed {fname} kernels ({len(configs)}): {[c.name() for c in configs]}")
print(f"matmul oracle fraction of optimal:     {result.oracle_fraction:.1%}")
print(f"matmul classifier fraction of optimal: {result.classifier_fraction:.1%}")

# 3. The decision tree as launcher code (the paper embeds it as nested ifs):
print("\n--- generated launcher (first lines) ---")
print("\n".join(tree_to_python(dep.classifier).splitlines()[:8]))

# 4. Ship it: a v5 bundle carries all four families; bundle.runtime() loads
#    it into an ISOLATED KernelRuntime (build several for several tenants —
#    they share nothing), and activation scopes dispatch to that handle.
bundle = repro.DeploymentBundle({"tpu_v5e": dep})
rt = bundle.runtime(device="tpu_v5e")
rt.set_selection_logging(True)  # opt-in telemetry, scoped to this runtime
with rt.activate():  # every repro op in this block dispatches through rt
    a = jnp.ones((512, 784), jnp.bfloat16)
    b = jnp.ones((784, 512), jnp.bfloat16)
    ops.matmul(a, b)
    a2 = jnp.ones((1, 4096), jnp.bfloat16)  # decode-style GEMV picks differently
    b2 = jnp.ones((4096, 512), jnp.bfloat16)
    ops.matmul(a2, b2)
    q = jnp.ones((1, 4, 128, 64), jnp.bfloat16)
    ops.attention(q, q, q)  # flash-attention family
rt.select_wkv_config(4096, 64)  # RWKV6 recurrence family (direct handle call)
rt.select_ssm_config(2048, 1600)  # Mamba selective-scan family
print("\n--- trace-time kernel selections (family-qualified) ---")
for op, problem, cfg in rt.selection_log():
    print(f"  {op}{problem} -> {cfg.name()}")
stats = rt.shape_cache_stats()
print(f"shape cache per family: { {f: s['size'] for f, s in stats['per_family'].items()} }")
# No teardown choreography: rt and its caches/logs die with this scope, and
# the process default runtime was never touched.
