"""Quickstart: the paper's full pipeline in ~40 lines.

  benchmark table -> normalize -> cluster-select kernels -> train classifier
  -> deploy -> ML-guided dispatch of every matmul in a model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core.codegen import tree_to_python
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.tuner import tune
from repro.kernels import ops

# 1. A benchmark table: 150 GEMM problems x 210 kernel configs.
#    (Analytic TPU-v5e model here; measured data plugs in identically —
#     see repro.core.cpubench for the real host-CPU source.)
dataset = build_model_dataset(synthetic_problems(150))
print(f"dataset: {len(dataset.problems)} problems x {len(dataset.configs)} configs")

# 2. The paper's pipeline: PCA+K-means selects 8 kernels to deploy,
#    a decision tree learns to pick among them at runtime.
result = tune(dataset, n_kernels=8, method="pca_kmeans", classifier="DecisionTreeA")
print(f"deployed kernels ({len(result.deployment.configs)}):")
for cfg in result.deployment.configs:
    print(f"  {cfg.name()}")
print(f"oracle fraction of optimal:     {result.oracle_fraction:.1%}")
print(f"classifier fraction of optimal: {result.classifier_fraction:.1%}")

# 3. The decision tree as launcher code (the paper embeds it as nested ifs):
print("\n--- generated launcher (first lines) ---")
print("\n".join(tree_to_python(result.deployment.classifier).splitlines()[:8]))

# 4. Install the deployment: every repro matmul now dispatches through it.
ops.set_kernel_policy(result.deployment)
ops.set_selection_logging(True)  # opt-in: dispatch decisions are not recorded by default
ops.clear_selection_log()
a = jnp.ones((512, 784), jnp.bfloat16)
b = jnp.ones((784, 512), jnp.bfloat16)
ops.matmul(a, b)
a2 = jnp.ones((1, 4096), jnp.bfloat16)  # decode-style GEMV picks differently
b2 = jnp.ones((4096, 512), jnp.bfloat16)
ops.matmul(a2, b2)
print("\n--- trace-time kernel selections ---")
for op, problem, cfg in ops.selection_log():
    print(f"  {op}{problem} -> {cfg.name()}")
ops.set_kernel_policy(None)
