"""Tuning control-plane end-to-end demo (the CI control-plane job).

The fleet-wide tune -> publish -> serve -> federate -> retune -> push loop
of DESIGN.md §14, over real HTTP against a real in-process service:

  1. start a ControlPlane (ephemeral port) and submit a bring-up tune over
     ``POST /jobs`` — staged transfer tune (donors first) with
     ``measure_budget="auto"`` sized from donor lineage; assert the job
     walked queued -> running -> succeeded;
  2. open the versioned, content-hashed artifact straight from the registry
     with ``repro.load_bundle("registry://...")``;
  3. bring up TWO serving hosts on the artifact, each with an attached
     :class:`repro.control.PolicySubscriber` long-polling the policy board;
  4. serve a shifted workload (the artifact was tuned for a different
     architecture's GEMMs) and ``POST /telemetry`` each host's snapshot:
     host-1 alone stays under the federation's min-events floor — NO
     retune; host-2's merged aggregate crosses it and the drift verdict
     schedules an incremental-retune job;
  5. the retuned child version lands on the policy board, both subscribers
     deliver it, and each engine hot-swaps it canary-gated at a step
     boundary — mid-batch, zero dropped requests;
  6. assert health/job bookkeeping saw all of it.

Run:  PYTHONPATH=src python examples/control_plane_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs import registry
from repro.control import ControlPlaneClient, PolicySubscriber
from repro.core.retune import TelemetrySnapshot

DEVICE = "tpu_v5e"
MIN_EVENTS = 24  # one host's window stays below; two hosts' merge crosses


def serve_batch(engine, rng, cfg, n_prompts: int, max_new: int = 6):
    tickets = [
        engine.submit(
            rng.integers(0, cfg.vocab, size=int(rng.integers(6, 20))).astype(np.int32),
            max_new_tokens=max_new,
        )
        for _ in range(n_prompts)
    ]
    status = engine.drain()
    return tickets, status


def main() -> None:
    plane = repro.ControlPlane(port=0, min_events=MIN_EVENTS)
    plane.start()
    try:
        run(plane)
    finally:
        plane.stop()
    print("\ncontrol-plane demo: OK")


def run(plane) -> None:
    client = ControlPlaneClient(plane.url)
    print(f"control plane up at {plane.url}")

    # -- 1. bring-up tune over HTTP: staged transfer, auto-sized budget ------
    job = client.submit({
        "kind": "tune",
        "name": "default",
        "devices": [DEVICE, "tpu_v4"],
        "archs": ["qwen2.5-32b"],      # NOT the arch we serve below -> drift
        "transfer": True,
        "measure_budget": "auto",
        "n_kernels": 4,
        "max_problems": 60,
    })
    assert job["state"] == "queued", job
    done = client.wait_job(job["id"], timeout=600)
    assert done["state"] == "succeeded", done
    states = [s for s, _t in done["history"]]
    assert states == ["queued", "running", "succeeded"], states
    art = done["artifact"]
    print(f"{job['id']}: {' -> '.join(states)}; "
          f"published {art['name']}@{art['version']} for {art['devices']}")

    # -- 2. the serving host opens the artifact by registry URI --------------
    uri = client.registry_uri(art["name"], art["version"])
    bundle = repro.load_bundle(uri)
    assert sorted(bundle.devices) == sorted(art["devices"])
    recipient, _resolved = bundle.deployment_for("tpu_v4")
    v4 = (recipient.meta.get("tuning_lineage") or {}).get("matmul", {})
    assert v4.get("source_device") == DEVICE, v4       # donors tuned first
    assert 0.0 < v4.get("measured_fraction", 1.0) < 1.0, v4  # auto budget bit
    print(f"loaded {uri}\n  transfer lineage: tpu_v4 measured "
          f"{v4['measured_fraction']:.1%} (auto budget from donor "
          f"model_error={v4.get('model_error')})")

    # -- 3. two serving hosts, each subscribed to the policy board -----------
    cfg = registry.get("granite-8b").reduced()
    from repro.models.model import build_model

    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    hosts = []
    for name in ("host-1", "host-2"):
        rt = bundle.runtime(device=DEVICE, name=name)
        rt.set_selection_logging(True)
        engine = rt.serve(model, params, max_batch=2, cache_len=64, block_size=16)
        sub = PolicySubscriber(client, DEVICE, engine, poll_timeout=5.0).start()
        hosts.append((name, rt, engine, sub))

    # -- 4. shifted traffic + federation -------------------------------------
    acks = []
    for i, (name, rt, engine, _sub) in enumerate(hosts):
        rng = np.random.default_rng(7 + i)
        _tickets, status = serve_batch(engine, rng, cfg, n_prompts=4)
        assert status.completed == 4, status
        snap = TelemetrySnapshot.from_runtime(rt)
        assert snap.n_events > 0, f"{name} logged no selections"
        ack = client.post_telemetry(DEVICE, snap, host=name)
        acks.append(ack)
        trig = sorted(f for f, r in ack["drift"].items() if r["triggered"])
        print(f"{name}: posted {snap.n_events} events -> federated "
              f"{ack['merged_events']} across {ack['hosts']} host(s); "
              f"triggered={trig or 'none'} retune_job={ack['retune_job']}")

    # One host alone is under the floor; the merged fleet view is not.
    assert acks[0]["retune_job"] is None, acks[0]
    assert acks[0]["merged_events"] < MIN_EVENTS <= acks[1]["merged_events"], acks
    assert acks[1]["retune_job"] is not None, (
        "federated aggregate should have triggered a retune", acks[1])

    # -- 5. retune job -> child version -> policy push -> live hot-swap ------
    retune = client.wait_job(acks[1]["retune_job"], timeout=600)
    assert retune["state"] == "succeeded", retune
    child = retune["artifact"]
    assert child["parent"] == art["version"], child
    print(f"{retune['id']}: incremental retune of {child['families']} -> "
          f"{child['name']}@{child['version']} (parent {child['parent']})")

    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline and not all(s.updates for *_rest, s in hosts):
        time.sleep(0.1)
    for name, _rt, _engine, sub in hosts:
        assert sub.updates, f"{name} subscriber never saw the policy push"
        assert sub.updates[-1]["version"] == child["version"], sub.updates

    # The offer adopts at the next step boundary — mid-traffic, zero drops.
    for i, (name, rt, engine, _sub) in enumerate(hosts):
        epoch0 = rt.policy_epoch()
        rng = np.random.default_rng(21 + i)
        _tickets, status = serve_batch(engine, rng, cfg, n_prompts=4)
        assert status.completed == 4, (name, status)  # nothing dropped
        ev = next(e for e in reversed(engine.retune_events)
                  if e.source == "control-plane")
        assert ev.swapped, (name, ev)
        assert rt.policy_epoch() > epoch0
        print(f"{name}: hot-swapped {child['version']} at step {ev.step} "
              f"(source={ev.source}), 4/4 requests completed")

    for _name, _rt, _engine, sub in hosts:
        sub.stop()

    # -- 6. the service's own books ------------------------------------------
    health = client.healthz()
    assert health["status"] == "ok"
    assert health["jobs"].get("succeeded", 0) >= 2, health
    assert health["artifacts"]["default"] == 2, health  # bring-up + retune
    assert DEVICE in health["devices"], health
    print(f"healthz: jobs={health['jobs']} artifacts={health['artifacts']}")


if __name__ == "__main__":
    main()
