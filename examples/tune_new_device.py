"""Bring-up on new hardware with zero developer effort (the paper's pitch).

Given ONLY a benchmark data source for a device, produce the shippable
deployment artifact: measured host-CPU timings here (the paper's i7-6700K
analogue), the analytic TPU model as the second device.  Compares all
clustering methods x normalizations, ships the winner, and packs it together
with a TPU deployment into a multi-device bundle that any host auto-installs
for its detected hardware.

Run:  PYTHONPATH=src python examples/tune_new_device.py [--full]
"""
import argparse

from repro.core.bundle import DeploymentBundle
from repro.core.cluster import CLUSTER_METHODS
from repro.core.cpubench import build_cpu_dataset, cpu_problems
from repro.core.normalize import NORMALIZATIONS
from repro.core.selection import achievable_fraction, select_from_dataset
from repro.core.tuner import save_result, tune, tune_for_archs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="24 measured problems (slower)")
    ap.add_argument("--out", default="/tmp/deployment_host_cpu.json")
    args = ap.parse_args()

    print("measuring blocked-GEMM timings on this host (the only 'developer input')...")
    ds = build_cpu_dataset(cpu_problems(24 if args.full else 10), verbose=True)
    train, test = ds.split(0.25, seed=0)

    print("\nmethod x normalization sweep (oracle % of optimal, 8 kernels):")
    best = (None, None, -1.0)
    for norm in NORMALIZATIONS:
        row = []
        for method in CLUSTER_METHODS:
            chosen = select_from_dataset(train, 8, method, norm)
            frac = achievable_fraction(test.perf, chosen)
            row.append(f"{method}={frac:.1%}")
            if frac > best[2]:
                best = (method, norm, frac)
        print(f"  {norm:<11} " + "  ".join(row))

    method, norm, frac = best
    print(f"\nwinner: {method} + {norm} ({frac:.1%}); training the runtime classifier...")
    result = tune(ds, n_kernels=8, method=method, normalization=norm)
    save_result(result, args.out)
    print(f"deployment artifact -> {args.out}")
    print(f"  oracle {result.oracle_fraction:.1%} / classifier {result.classifier_fraction:.1%}")

    # Pack the measured host deployment with an analytic TPU one: the
    # deploy-anywhere bundle (this CPU host resolves to host_cpu; a TPU host
    # would pick its own entry; anything else degrades to the nearest sibling).
    tpu = tune_for_archs(None, device_name="tpu_v5e", n_kernels=8, max_problems=120)
    bundle = DeploymentBundle({
        "host_cpu": result.deployment,
        "tpu_v5e": tpu.deployment,
    })
    bundle_path = args.out.replace(".json", "") + ".bundle.json"
    bundle.save(bundle_path)
    # Serving hosts load the artifact into an isolated runtime handle; the
    # detected device picks its entry (nearest tuned sibling when untuned).
    rt = bundle.runtime()
    print(f"bundle ({bundle.devices}) -> {bundle_path}")
    print(f"runtime for this host: {rt!r}")
    print("serving hosts bring up with: repro.load_bundle(path).runtime()")


if __name__ == "__main__":
    main()
