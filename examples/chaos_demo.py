"""Chaos-injection end-to-end demo (the CI chaos job).

The DESIGN.md §11 failure model exercised against a real model and the real
serving engine, with faults injected mid-run from a seeded FaultPlan:

  1. tune an offline prior and install it into an isolated KernelRuntime;
  2. guarded dispatch: an injected *compile failure* and an injected *NaN
     output* hit the live matmul config — both are contained (the reference
     path serves the caller), the config is quarantined behind the circuit
     breaker, re-probed after backoff, and finally absolved.  The caller
     never sees an exception or a non-finite value;
  3. serving under chaos: a prefill compile fault mid-run costs one retry,
     and the first drift-triggered retune produces a *regressing candidate*
     (injected fault at ``retune.candidate``) that the canary gate rejects —
     the incumbent keeps serving; the next retune passes and hot-swaps;
  4. regressing hot-swap: the swapped-in policy starts faulting; the
     rollback watchdog reinstalls the pre-swap deployment from the bounded
     swap history, mid-run, with zero dropped requests;
  5. assert all of it: every request of every stage completes, the engine's
     health state dipped to ``degraded`` and recovered to ``healthy``.

Run:  PYTHONPATH=src python examples/chaos_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs import registry
from repro.core.bundle import DeploymentBundle
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.faults import FaultPlan
from repro.core.tuner import tune
from repro.kernels import ops
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine


def main() -> None:
    # -- 1. offline prior ----------------------------------------------------
    ds = build_model_dataset(synthetic_problems(80), device_name="tpu_v5e")
    res = tune(ds, n_kernels=6)
    bundle = DeploymentBundle({"tpu_v5e": res.deployment}, meta={"demo": "chaos"})
    print(f"offline prior: {len(res.deployment.configs)} kernels")

    # -- 2. guarded dispatch: compile fault + NaN on the live config ---------
    rt = repro.KernelRuntime(name="chaos-dispatch")
    rt.install_bundle(bundle, "tpu_v5e")
    with rt.activate():
        cfg = rt.select_matmul_config(64, 512, 256, 1)  # what this traffic serves
    plan = FaultPlan(seed=0)
    plan.inject("dispatch.matmul", "compile_error", times=1, match=cfg.name())
    plan.inject("dispatch.matmul", "nan", times=1, match=cfg.name())
    rt.set_fault_plan(plan)
    x, w = jnp.ones((64, 512)), jnp.ones((512, 256))
    with rt.activate():
        for _ in range(16):  # enough selections to re-probe through both faults
            out = ops.matmul(x, w)
            assert bool(jnp.isfinite(out).all()), "non-finite output escaped the guard!"
    actions = [i["action"] for i in rt.incidents()]
    assert actions.count("quarantined") == 2, actions  # compile fault, then NaN probe
    assert "absolved" in actions, actions               # final re-probe closed the breaker
    assert not rt.quarantined(), rt.quarantined()
    print(f"guarded dispatch: {cfg.name()} survived compile fault + NaN "
          f"(quarantined twice, re-probed, absolved); 16/16 calls finite")

    # -- 3. serving under chaos: retry + canary-rejected retune --------------
    mcfg = registry.get("granite-8b").reduced()
    model = build_model(mcfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rt2 = repro.KernelRuntime(name="chaos-serve")
    plan2 = FaultPlan(seed=1)
    plan2.inject("engine.prefill", "compile_error", times=1)
    plan2.inject("retune.candidate", "compile_error", times=1)  # regressing retune
    rt2.set_fault_plan(plan2)
    engine = ServingEngine(
        model, params, max_batch=2, cache_len=128,
        bundle=bundle, device="tpu_v5e", runtime=rt2,
        retune_interval=8, drift_threshold=0.15, retune_min_events=8,
    )
    original = engine.deployment
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, mcfg.vocab, size=plen).astype(np.int32),
                max_new_tokens=8)
        for i, plen in enumerate([6, 6, 6, 40, 40, 48, 48, 20])
    ]
    t0 = time.time()
    for r in reqs:
        engine.submit_request(r)
    status = engine.drain()
    print(f"served {len(reqs)} requests in {time.time() - t0:.1f}s under chaos")
    assert status.completed == len(reqs) and not status.exhausted, status
    assert all(r.done and r.state == "done" for r in reqs), "dropped request!"
    assert sum(r.retries for r in reqs) >= 1, "prefill fault never cost a retry?"
    rejected = [ev for ev in engine.retune_events if ev.rejected and not ev.swapped]
    swapped = [ev for ev in engine.retune_events if ev.swapped and not ev.rolled_back]
    assert rejected, f"regressing candidate was never rejected: {engine.retune_events}"
    assert swapped, f"clean retune never swapped: {engine.retune_events}"
    assert engine.deployment is not original
    print(f"retune under chaos: candidate rejected at step {rejected[0].step} "
          f"(families {rejected[0].rejected}), clean swap at step {swapped[0].step}")

    # -- 4. regressing hot-swap: auto-rollback from swap history -------------
    engine.retune_interval = None  # operator pauses the loop; watchdog stays on
    pre_swap = engine._swap_history[-1]
    plan2.inject("engine.decode", "oom", times=engine.rollback_threshold)
    reqs2 = [
        Request(uid=100 + i, prompt=rng.integers(0, mcfg.vocab, size=6).astype(np.int32),
                max_new_tokens=8)
        for i in range(4)
    ]
    for r in reqs2:
        engine.submit_request(r)
    status2 = engine.drain()
    assert status2.completed == len(reqs2) and not status2.exhausted, status2
    assert all(r.done and r.state == "done" for r in reqs2), "dropped request!"
    rolled = [ev for ev in engine.retune_events if ev.rolled_back]
    assert rolled, f"watchdog never rolled back: {engine.retune_events}"
    assert engine.deployment is pre_swap, "rollback did not restore the incumbent"
    assert any(i["action"] == "rollback" for i in rt2.incidents())
    print(f"auto-rollback: {engine.rollback_threshold} incidents after the swap "
          f"reinstalled the pre-swap deployment at step {rolled[0].step}")

    # -- 5. health state machine ---------------------------------------------
    states = [s for _, s in engine.health_events]
    assert "degraded" in states, engine.health_events
    assert engine.health == "healthy" and status2.health == "healthy"
    print(f"health transitions {engine.health_events}: degraded under chaos, "
          f"healthy at the end; zero dropped requests across "
          f"{len(reqs) + len(reqs2)} total")
    print("fault-contained serving loop OK")


if __name__ == "__main__":
    main()
