"""Batched serving example: continuous batching with a multi-device bundle.

Tunes a two-device DeploymentBundle in one run (``tune_fleet``), lets the
serving engine auto-install the deployment for the *detected* host device
(``REPRO_DEVICE`` overrides detection; an untuned host falls back to the
nearest tuned sibling), submits a burst of mixed-length requests through the
streaming Ticket API over a paged KV pool, and prints throughput + the
trace-time kernel selections made for prefill vs decode GEMMs.

Run:  PYTHONPATH=src python examples/serve_lm.py
      PYTHONPATH=src REPRO_DEVICE=tpu_v4 python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs import registry
from repro.core.tuner import tune_fleet
from repro.models.model import build_model
from repro.serve.engine import ServingEngine


def main() -> None:
    arch = "granite-8b"
    cfg = registry.get(arch).reduced()

    fleet = tune_fleet([arch], device_names=("tpu_v5e", "tpu_v4"),
                       n_kernels=8, max_problems=100)
    bundle = fleet.bundle
    print(f"bundle tuned for {bundle.devices}")
    # One isolated runtime per tenant: telemetry below is scoped to it.
    rt = repro.KernelRuntime(name="serve-lm")
    rt.set_selection_logging(True)

    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    # The engine installs the right per-device Deployment from the bundle
    # into ITS runtime (nothing process-global is touched).
    engine = ServingEngine(model, params, max_batch=4, cache_len=128,
                           block_size=32, bundle=bundle, runtime=rt)
    print(f"host resolved to device {engine.device!r} "
          f"(detected or REPRO_DEVICE; nearest tuned sibling when untuned)")

    rng = np.random.default_rng(0)
    t0 = time.time()
    tickets = [
        engine.submit(
            rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24))).astype(np.int32),
            max_new_tokens=int(rng.integers(8, 24)),
        )
        for i in range(12)
    ]
    # Stream the first ticket token by token (the iterator steps the engine,
    # so every resident request advances while we watch this one)...
    first = list(tickets[0].tokens())
    print(f"streamed ticket 0: {first[:8]}{'...' if len(first) > 8 else ''}")
    # ...then drain the rest of the fleet's work.
    status = engine.drain()
    dt = time.time() - t0
    requests = [t.request for t in tickets]
    tokens = sum(len(r.output) for r in requests)
    print(f"served {status.completed}/{len(requests)} requests / {tokens} tokens "
          f"in {dt:.2f}s ({tokens / dt:.1f} tok/s, {engine.steps} batched decode steps)")
    pool = engine.pool.stats()
    print(f"kv pool: {pool['used_blocks']}/{pool['n_blocks']} blocks of "
          f"{pool['block_size']} tokens in use at drain")

    decode_sel = {c.name() for op, p, c in rt.selection_log() if p[0] <= 4}
    prefill_sel = {c.name() for op, p, c in rt.selection_log() if p[0] > 4}
    print(f"decode-GEMM kernels selected:  {sorted(decode_sel)}")
    print(f"prefill-GEMM kernels selected: {sorted(prefill_sel)}")
    # No teardown choreography: the runtime handle dies with this function.


if __name__ == "__main__":
    main()
