"""Fleet serving demo: one router, two devices, mixed-SLO traffic.

End-to-end DESIGN.md §13 walkthrough on real (reduced) model math:

1. tune a two-device DeploymentBundle in one run;
2. ``bundle.router(model, params, ...)`` — one ServingEngine per tuned
   device, each on its own isolated KernelRuntime, behind one front door;
3. submit a burst of mixed-priority requests — all opening with the same
   16-token system prompt, half carrying a per-token latency target —
   through the streaming submit/stream API over paged KV pools (chunked
   prefill + prefix sharing: later requests alias the system prompt's
   blocks instead of re-prefilling them);
4. stream one ticket token-by-token while the rest of the fleet serves,
   then drain and assert the dispatch spread both engines and the prefix
   cache took hits.

Run:  PYTHONPATH=src python -W error::DeprecationWarning examples/fleet_serve_demo.py
(CI runs exactly that: any engine.run() shim call in this path is a failure.)
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.tuner import tune_fleet
from repro.models.model import build_model


def main() -> None:
    arch = "granite-8b"
    cfg = registry.get(arch).reduced()

    fleet = tune_fleet([arch], device_names=("tpu_v5e", "tpu_v4"),
                       n_kernels=4, max_problems=60)
    bundle = fleet.bundle
    print(f"bundle tuned for {bundle.devices}")

    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    router = bundle.router(model, params, max_batch=2, cache_len=64,
                           block_size=16)
    print(f"router fronting engines: {sorted(router.engines)}")
    for dev, eng in router.engines.items():
        assert eng.runtime.active_device() == dev  # isolated per-device runtime

    rng = np.random.default_rng(0)
    n = 8
    # One block-sized system prompt shared by every request: the first
    # admission per engine prefills + indexes it, later siblings alias those
    # blocks (refcounted) and skip that span of prefill work entirely.
    system_prompt = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
    t0 = time.time()
    tickets = [
        router.submit(
            np.concatenate([
                system_prompt,
                rng.integers(0, cfg.vocab, size=int(rng.integers(4, 12))).astype(np.int32),
            ]),
            max_new_tokens=int(rng.integers(4, 9)),
            priority=i % 3,
            # every other request carries a (generous) per-token SLO: the
            # latency_target threads request -> scheduler -> kernel selection
            latency_target_ms=5_000.0 if i % 2 else None,
        )
        for i in range(n)
    ]
    # Stream the first ticket while the whole fleet makes progress...
    first = list(tickets[0].tokens())
    print(f"streamed ticket 0 ({tickets[0].request.routed_to}): {first}")
    # ...then run everything else down and aggregate the fleet status.
    status = router.drain()
    dt = time.time() - t0

    reqs = [t.request for t in tickets]
    tokens = sum(len(r.output) for r in reqs)
    routes = sorted({r.routed_to for r in reqs})
    print(f"served {status.completed}/{n} requests / {tokens} tokens in "
          f"{dt:.2f}s across {routes} ({status.steps} fleet rounds, "
          f"{status.preempted} preempted)")
    for dev in sorted(router.engines):
        pool = router.engines[dev].pool.stats()
        print(f"  {dev}: {pool['used_blocks']}/{pool['n_blocks']} blocks of "
              f"{pool['block_size']} tokens in use at drain, "
              f"{pool['prefix_hits']}/{pool['prefix_lookups']} prefix hits")
    print(f"fleet health: {router.healths()}")
    print(f"prefix cache: {status.prefix_hits}/{status.prefix_lookups} "
          f"admissions aliased the shared system prompt "
          f"(hit rate {status.prefix_hit_rate:.2f})")

    assert status.completed == n and not status.exhausted
    assert all(t.done for t in tickets)
    assert len(routes) == 2, f"dispatch piled everything on {routes}"
    assert status.health == "healthy"
    assert status.prefix_hits >= 1, "shared system prompt was never aliased"
    print("fleet serving demo OK")


if __name__ == "__main__":
    main()
