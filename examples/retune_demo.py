"""Continuous-tuning end-to-end demo (the CI retune-e2e job).

The full DESIGN.md §8 loop against a real model and the real serving engine:

  1. tune a deployment OFFLINE on the paper-flavoured synthetic benchmark
     distribution and pack it as a v4 bundle (provenance included) — a
     deliberately imperfect prior for the model we are about to serve;
  2. serve a shifted synthetic workload: the model's actual projection /
     MLP / vocab GEMMs at serving shapes land in buckets the tuning data
     never covered, so the live telemetry histogram drifts;
  3. the engine's in-loop drift check fires, runs an *incremental* retune
     (bucket-level harvest, warm-started clustering, traffic-weighted
     classifier refit) and hot-swaps the new Deployment into the live
     policy registry — mid-run, with zero dropped requests;
  4. assert all of it actually happened;
  5. family-qualified loop: an ssm-only traffic shift (no matmul drift at
     all) fires drift detection for the ``ssm_scan`` family and the
     incremental retune refreshes ONLY that family's configs + classifier —
     the proof that every registered kernel family rides the same
     tune -> deploy -> dispatch -> retune pipeline.

Run:  PYTHONPATH=src python examples/retune_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs import registry
from repro.core.bundle import DeploymentBundle
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.tuner import tune
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine


def main() -> None:
    # -- 1. offline prior: tuned on benchmark data, not on this workload ----
    ds = build_model_dataset(synthetic_problems(80), device_name="tpu_v5e")
    res = tune(ds, n_kernels=6)
    bundle = DeploymentBundle({"tpu_v5e": res.deployment}, meta={"demo": True})
    assert "train_distribution" in res.deployment.meta  # v4 provenance
    print(f"offline prior: {len(res.deployment.configs)} kernels, "
          f"classifier fraction {res.classifier_fraction:.1%} on its own test split")

    # -- 2. serve a shifted workload under the continuous tuning loop -------
    cfg = registry.get("granite-8b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    # The engine owns an explicit, isolated KernelRuntime: telemetry, the
    # policy registry, and the hot swap below are all scoped to this tenant.
    rt = repro.KernelRuntime(name="retune-demo")
    engine = ServingEngine(
        model, params, max_batch=2, cache_len=128,
        bundle=bundle, device="tpu_v5e", runtime=rt,
        retune_interval=8, drift_threshold=0.15, retune_min_events=8,
    )
    epoch0 = rt.policy_epoch()
    original = engine.deployment

    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=plen).astype(np.int32),
                max_new_tokens=8)
        for i, plen in enumerate([6, 6, 6, 40, 40, 48, 48, 20])
    ]
    t0 = time.time()
    for r in reqs:
        engine.submit_request(r)
    status = engine.drain()
    dt = time.time() - t0
    print(f"served {len(reqs)} requests in {dt:.1f}s, {engine.steps} decode steps")

    # -- 3/4. the loop fired, swapped, and dropped nothing -------------------
    assert status.completed == len(reqs) and not status.exhausted, status
    assert all(r.done and r.state == "done" for r in reqs), "dropped request!"
    swapped = [ev for ev in engine.retune_events if ev.swapped]
    assert swapped, f"drift never triggered a retune: {engine.retune_events}"
    assert engine.deployment is not original, "policy was not hot-swapped"
    assert engine.deployment.meta.get("retune_count", 0) >= 1
    assert rt.policy_epoch() > epoch0, "runtime policy epoch did not advance"
    assert rt.active_device() == "tpu_v5e"  # registry swap, not a manual detach
    first = swapped[0]
    print(f"drift {first.drift_score:.3f} (unseen {first.unseen_fraction:.1%}) "
          f"fired at step {first.step}: retuned to {first.n_configs} kernels and "
          f"hot-swapped (policy epoch {epoch0} -> {rt.policy_epoch()})")
    print(f"retune checks: {len(engine.retune_events)}, swaps: {len(swapped)}, "
          f"final retune_count {engine.deployment.meta['retune_count']}")
    print("zero-downtime continuous tuning loop OK")

    # -- 5. ssm-only traffic shift: drift + retune for one family -----------
    # A SECOND isolated runtime (same process, zero interaction with rt):
    # exactly the multi-tenant shape of an A/B shadow-policy deployment.
    from repro.core import retune

    dep = engine.deployment
    assert "ssm_scan" in (dep.meta.get("family_distributions") or {}), \
        "tune() should have stamped per-family provenance"
    ssm_before = dep.family_tuning("ssm_scan")
    rt2 = repro.KernelRuntime(name="ssm-shift")
    rt2.install(dep)
    rt2.set_selection_logging(True)
    # Live selective-scan shapes far from the harvested (train/prefill)
    # distribution — a reduced Mamba serving workload.  No matmul traffic.
    for _ in range(6):
        for s, d in [(96, 48), (160, 48), (96, 96)]:
            rt2.select_ssm_config(s, d)
    snap = retune.TelemetrySnapshot.from_runtime(rt2)
    assert snap.families() == ["ssm_scan"], snap.families()
    rep_mm = retune.detect_drift(snap, dep, family="matmul", min_events=8)
    rep_ssm = retune.detect_drift(snap, dep, family="ssm_scan", min_events=8)
    assert not rep_mm.triggered, "no matmul traffic must mean no matmul drift"
    assert rep_ssm.triggered and rep_ssm.unseen_fraction > 0.9, rep_ssm
    out = retune.incremental_retune(dep, snap, family="ssm_scan", report=rep_ssm,
                                    min_events=8)
    nd = out.deployment
    assert out.family == "ssm_scan" and out.n_harvested > 0
    assert nd.configs == dep.configs  # matmul artifact untouched
    assert nd.attention_tree is dep.attention_tree
    cfg = nd.select_ssm(96, 48)
    assert cfg in nd.family_tuning("ssm_scan").configs
    print(f"ssm-only shift: drift {rep_ssm.score:.3f} -> retuned ssm_scan "
          f"({len(ssm_before.configs)} -> {len(nd.family_tuning('ssm_scan').configs)} kernels, "
          f"{out.n_harvested} buckets harvested); live (96, 48) now runs {cfg.name()}")
    print("family-qualified continuous tuning loop OK")
    # No teardown: both runtimes are local handles; nothing global to undo.


if __name__ == "__main__":
    main()
