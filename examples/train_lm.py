"""End-to-end training driver: a ~100M-param dense LM for a few hundred steps.

Demonstrates the full production loop on whatever devices exist: tuned-kernel
deployment installed, deterministic data pipeline, async checkpointing with
auto-resume, preemption-safe exit, straggler detection.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params is sized for a real machine; --tiny gives the CI-sized run.)
"""
import argparse
import dataclasses

import jax.numpy as jnp

import repro
from repro.configs import registry
from repro.core.tuner import tune_for_archs
from repro.data.pipeline import DataConfig
from repro.models.model import build_model
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true", help="CI-sized model/data")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # A ~100M-param phi4-family config (same family, scaled down).
    base = registry.get("phi4-mini-3.8b")
    if args.tiny:
        cfg, batch, seq = base.reduced(), 8, 64
    else:
        cfg = dataclasses.replace(
            base, n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=32000,
        )
        batch, seq = 16, 256
    print(f"model: {cfg.name} family={cfg.family} ~{cfg.n_params() / 1e6:.0f}M params")

    # Tune the kernel deployment against this architecture's GEMM shapes
    # (the paper's pipeline) and install it for trace-time dispatch.
    result = tune_for_archs([base.name], n_kernels=8, max_problems=100)
    rt = repro.KernelRuntime(name="train-lm")
    rt.install(result.deployment)
    print(f"kernel deployment: {len(result.deployment.configs)} configs, "
          f"oracle {result.oracle_fraction:.1%}, classifier {result.classifier_fraction:.1%}")

    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    trainer = Trainer(
        model,
        cfg,
        DataConfig(global_batch=batch, seq_len=seq),
        adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(
            total_steps=args.steps,
            ckpt_every=max(args.steps // 4, 1),
            ckpt_dir=args.ckpt_dir,
            log_every=10,
        ),
    )
    with rt.activate():  # every trace-time GEMM selection dispatches via rt
        step, _, _, metrics = trainer.train()
    stats = rt.shape_cache_stats()
    print(f"done at step {step}: loss {float(metrics['loss']):.4f} "
          f"(selections made: {stats['hits'] + stats['misses']})")


if __name__ == "__main__":
    main()
