"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up the paged serving engine with the tuned kernel deployment and
drives a batch of synthetic requests through the submit/stream API
(prefill + continuous decode, optional latency targets).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.retune import DEFAULT_DRIFT_THRESHOLD, DEFAULT_MIN_EVENTS
from repro.core.runtime import KernelRuntime
from repro.models.model import build_model
from repro.serve.engine import ServingEngine


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(registry.ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=None, metavar="TOKENS",
                    help="paged KV cache block size (divides --cache-len; "
                         "default: one dense block per lane)")
    ap.add_argument("--n-blocks", type=int, default=None,
                    help="total KV pool blocks (default: lanes * blocks/lane)")
    ap.add_argument("--latency-target-ms", type=float, default=None,
                    help="per-token latency SLO attached to every other "
                         "request (exercises objective-aware selection)")
    ap.add_argument("--deployment", default=None, help="single-device Deployment json")
    ap.add_argument("--bundle", default=None,
                    help="multi-device DeploymentBundle json (auto-installs for this host)")
    ap.add_argument("--serve-device", default=None,
                    help="override device name for --bundle resolution (default: detect)")
    ap.add_argument("--retune-interval", type=int, default=None, metavar="STEPS",
                    help="check telemetry drift every N decode steps and "
                         "incrementally retune + hot-swap the policy when it fires")
    ap.add_argument("--drift-threshold", type=float, default=DEFAULT_DRIFT_THRESHOLD,
                    help="Jensen-Shannon divergence (0-1) that triggers a retune")
    ap.add_argument("--retune-min-events", type=int, default=DEFAULT_MIN_EVENTS,
                    help="telemetry floor before a drift check may trigger")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="fault-injection plan 'site:kind[:times[:after]],...' — e.g. "
                         "'dispatch.matmul:compile_error,engine.prefill:compile_error'; "
                         "injected faults are contained by the dispatch guard "
                         "(DESIGN.md §11) and reported after the run (nan/inf "
                         "kinds poison concrete values only, so they are no-ops "
                         "inside jit-traced serving programs)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the fault plan's probabilistic specs")
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch).reduced()
    # The launcher owns an explicit runtime handle: every policy, cache, and
    # telemetry mutation below is scoped to it (nothing process-global).
    rt = KernelRuntime(name=f"serve[{args.arch}]")
    if args.chaos:
        from repro.core.faults import FaultPlan

        rt.set_fault_plan(FaultPlan.parse(args.chaos, seed=args.chaos_seed))
    bundle = None
    if args.bundle:
        from repro.core.bundle import DeploymentBundle

        bundle = DeploymentBundle.load(args.bundle)
    elif args.deployment:
        from repro.core.dispatch import Deployment

        rt.install(Deployment.load(args.deployment))

    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    extra = {}
    if cfg.family == "vlm":
        extra["image_embs"] = jnp.zeros((1, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        extra["frames"] = jnp.zeros((1, 32, cfg.d_model), jnp.float32)

    engine = ServingEngine(
        model, params, max_batch=args.max_batch, cache_len=args.cache_len,
        block_size=args.block_size, n_blocks=args.n_blocks,
        extra_inputs=extra, bundle=bundle, device=args.serve_device, runtime=rt,
        retune_interval=args.retune_interval, drift_threshold=args.drift_threshold,
        retune_min_events=args.retune_min_events,
    )
    if bundle is not None:
        print(f"bundle installed: serving with the {engine.device!r} deployment")
    rng = np.random.default_rng(0)
    t0 = time.time()
    tickets = [
        engine.submit(
            rng.integers(0, cfg.vocab, size=8).astype(np.int32),
            max_new_tokens=args.max_new_tokens,
            latency_target_ms=args.latency_target_ms if i % 2 else None,
        )
        for i in range(args.requests)
    ]
    status = engine.drain()
    dt = time.time() - t0
    reqs = [t.request for t in tickets]
    toks = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests, {toks} tokens, {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s), {engine.steps} decode steps")
    pool = engine.pool.stats()
    print(f"kv pool: {pool['n_blocks']} blocks x {pool['block_size']} tokens, "
          f"{pool['used_blocks']} in use at drain ({pool['utilization']:.0%}), "
          f"{status.preempted} requests preempted")
    if engine.slo_events:
        print(f"slo: {len(engine.slo_events)} mode transitions under "
              f"latency target {args.latency_target_ms} ms")
    # Dispatch evidence: nonzero counters prove the traces consulted the
    # installed policy (the counters only move when a policy is live).
    stats = rt.shape_cache_stats()
    print(f"policy selections at trace time: {stats['hits'] + stats['misses']} "
          f"({stats['hits']} shape-cache hits) on runtime {rt.name!r}")
    if status.exhausted:
        print(f"WARNING: step budget exhausted with {status.in_flight} in-flight / "
              f"{status.queued} queued requests unfinished")
    for ev in engine.retune_events:
        if ev.swapped:
            verdict = f"retuned {'+'.join(ev.families) or 'matmul'} + hot-swapped"
        elif ev.drift_score >= args.drift_threshold:
            verdict = f"below event floor ({ev.n_events}/{args.retune_min_events})"
        else:
            verdict = "no drift"
        print(f"  retune check @ step {ev.step}: drift {ev.drift_score:.3f} "
              f"(unseen {ev.unseen_fraction:.1%}) -> {verdict} "
              f"[{ev.n_configs} kernels, policy epoch {ev.epoch}]")
    if args.chaos:
        plan = rt.fault_plan
        print(f"chaos: {len(plan.events)} faults fired, {rt.incident_count()} "
              f"incidents contained, {len(rt.quarantined())} configs in "
              f"quarantine, engine health {status.health!r}")
        for inc in rt.incidents()[-5:]:
            print(f"  incident #{inc['seq']} {inc['site']} [{inc['config']}] "
                  f"-> {inc['action']}: {inc['error']}")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.output[:10]}...")


if __name__ == "__main__":
    main()
