"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (reduced or full) training job on whatever devices exist,
with the tuned kernel deployment installed, checkpoint/auto-resume, and the
fault-tolerance runtime active.  On this CPU container the reduced configs
train for real; the full configs are exercised via the dry-run.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.configs import registry
from repro.core.runtime import default_runtime
from repro.data.pipeline import DataConfig
from repro.models.model import build_model
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(registry.ARCHS))
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--deployment", default=None, help="tuned kernel deployment JSON")
    ap.add_argument("--fleet", action="store_true",
                    help="multi-host: init jax.distributed from the scheduler env")
    args = ap.parse_args(argv)

    topo = None
    if args.fleet:
        from repro.launch.fleet import initialize

        topo = initialize()
        print(f"fleet: process {topo.process_id}/{topo.num_processes} "
              f"(coordinator {topo.coordinator})")

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    if args.deployment:
        from repro.core.dispatch import Deployment

        # Training dispatch runs on the process default runtime (the trainer
        # owns every thread here, so an isolated handle buys nothing).
        default_runtime().install(Deployment.load(args.deployment))
        print(f"installed kernel deployment from {args.deployment}")

    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    data = DataConfig(global_batch=args.batch, seq_len=args.seq)
    if topo is not None:
        from repro.launch.fleet import fleet_data_config

        data = fleet_data_config(data, topo)
    opt = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        num_microbatches=args.microbatches,
    )
    trainer = Trainer(model, cfg, data, opt, tcfg)
    step, _params, _opt, metrics = trainer.train()
    print(f"finished at step {step}: loss={float(metrics.get('loss', float('nan'))):.4f}")


if __name__ == "__main__":
    main()
