"""Control-plane CLI: run the tuning service, submit jobs, inspect state.

The operator tool for the fleet-wide tuning loop (DESIGN.md §14):

  # run the service (ephemeral port unless --port; artifacts persisted)
  python -m repro.launch.ctl serve --port 8080 --registry-root artifacts/

  # submit a bring-up tune over HTTP and wait for the versioned artifact
  python -m repro.launch.ctl submit --url http://host:8080 \\
      --devices tpu_v5e,tpu_v4 --archs granite-8b --transfer \\
      --measure-budget auto --wait

  # job + artifact + health inspection
  python -m repro.launch.ctl status --url http://host:8080 [--job job-0001]
  python -m repro.launch.ctl artifacts --url http://host:8080 [--name default]

A serving host consumes the produced artifact with
``repro.load_bundle("registry://host:8080/default")`` and stays current by
attaching a :class:`repro.control.PolicySubscriber` to its engine.
"""
from __future__ import annotations

import argparse
import json

from repro.control import ControlPlane, ControlPlaneClient

from .tune import _measure_budget


def _cmd_serve(args) -> None:
    plane = ControlPlane(
        host=args.host, port=args.port, registry_root=args.registry_root,
        drift_threshold=args.drift_threshold, min_events=args.min_events,
    )
    plane.start()
    print(f"control plane listening on {plane.url}")
    if args.registry_root:
        print(f"artifacts persisted under {args.registry_root}")
    try:
        import signal
        import threading

        stop = threading.Event()
        signal.signal(signal.SIGTERM, lambda *_: stop.set())
        signal.signal(signal.SIGINT, lambda *_: stop.set())
        stop.wait()
    finally:
        plane.stop()
        print("control plane stopped")


def _cmd_submit(args) -> None:
    client = ControlPlaneClient(args.url)
    spec: dict = {"kind": "tune", "name": args.name}
    if args.devices:
        spec["devices"] = args.devices.replace(" ", "").split(",")
    if args.archs:
        spec["archs"] = args.archs.replace(" ", "").split(",")
    if args.families:
        spec["families"] = args.families.replace(" ", "").split(",")
    if args.transfer:
        spec["transfer"] = True
    if args.prune_ratio is not None:
        spec["prune_ratio"] = args.prune_ratio
    if args.measure_budget is not None:
        spec["measure_budget"] = args.measure_budget
    if args.n_kernels is not None:
        spec["n_kernels"] = args.n_kernels
    if args.max_problems is not None:
        spec["max_problems"] = args.max_problems
    job = client.submit(spec)
    print(f"{job['id']} {job['state']}")
    if not args.wait:
        return
    done = client.wait_job(job["id"], timeout=args.timeout)
    print(f"{done['id']} {done['state']}"
          + (f": {done['error']}" if done.get("error") else ""))
    if done["state"] == "succeeded":
        art = done["artifact"]
        print(f"artifact {art['name']}@{art['version']} "
              f"(registry://{args.url.split('://', 1)[-1]}/{art['name']}/{art['version']})")
    else:
        raise SystemExit(1)


def _cmd_status(args) -> None:
    client = ControlPlaneClient(args.url)
    if args.job:
        print(json.dumps(client.job(args.job), indent=1))
        return
    print(json.dumps(client.healthz(), indent=1))
    for job in client.jobs():
        line = f"{job['id']} [{job['kind']}] {job['state']}"
        if job.get("artifact"):
            line += f" -> {job['artifact']['name']}@{job['artifact']['version']}"
        if job.get("error"):
            line += f" ({job['error']})"
        print(line)


def _cmd_artifacts(args) -> None:
    client = ControlPlaneClient(args.url)
    arts = client.artifacts()
    names = [args.name] if args.name else sorted(arts)
    for name in names:
        for rec in arts.get(name, []):
            lineage = rec.get("lineage") or {}
            parent = lineage.get("parent")
            print(f"{name}@{rec['version']} seq={rec['seq']}"
                  + (f" parent={parent}" if parent else ""))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="command", required=True)

    serve = sub.add_parser("serve", help="run the control-plane service")
    serve.add_argument("--host", default="127.0.0.1")
    serve.add_argument("--port", type=int, default=8080)
    serve.add_argument("--registry-root", default=None,
                       help="directory to persist published artifacts (default: in-memory)")
    from repro.core.retune import DEFAULT_DRIFT_THRESHOLD, DEFAULT_MIN_EVENTS

    serve.add_argument("--drift-threshold", type=float, default=DEFAULT_DRIFT_THRESHOLD)
    serve.add_argument("--min-events", type=int, default=DEFAULT_MIN_EVENTS)
    serve.set_defaults(func=_cmd_serve)

    submit = sub.add_parser("submit", help="submit a bring-up tune job")
    submit.add_argument("--url", required=True, help="control-plane base URL")
    submit.add_argument("--name", default="default", help="artifact name to publish")
    submit.add_argument("--devices", default=None)
    submit.add_argument("--archs", default=None)
    submit.add_argument("--families", default=None)
    submit.add_argument("--transfer", action="store_true")
    submit.add_argument("--prune-ratio", type=float, default=None)
    submit.add_argument("--measure-budget", type=_measure_budget, default=None,
                        help="fraction in (0,1) or 'auto' (donor-lineage sized)")
    submit.add_argument("--n-kernels", type=int, default=None)
    submit.add_argument("--max-problems", type=int, default=None)
    submit.add_argument("--wait", action="store_true",
                        help="poll the job to a terminal state")
    submit.add_argument("--timeout", type=float, default=1800.0)
    submit.set_defaults(func=_cmd_submit)

    status = sub.add_parser("status", help="service health + job states")
    status.add_argument("--url", required=True)
    status.add_argument("--job", default=None, help="show one job in full")
    status.set_defaults(func=_cmd_status)

    artifacts = sub.add_parser("artifacts", help="list published artifact versions")
    artifacts.add_argument("--url", required=True)
    artifacts.add_argument("--name", default=None)
    artifacts.set_defaults(func=_cmd_artifacts)

    args = ap.parse_args(argv)
    args.func(args)


if __name__ == "__main__":
    main()
