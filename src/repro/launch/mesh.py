"""Production mesh construction.

A function (never a module-level constant) so importing this module does not
touch JAX device state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist on this host (smoke tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=(AxisType.Auto, AxisType.Auto))
