"""Compiled-HLO analysis helpers (no jax import — safe anywhere).

Parses collective ops and their shard byte counts out of ``compiled.as_text()``
for the §Roofline collective term.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_ARRAY_RE = re.compile(r"(pred|[sfu](?:8|16|32|64)|bf16)\[([0-9,]*)\]")
_LINE_RE = re.compile(r"%?[\w.\-]+\s*=\s*(.+?)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-array bytes of every collective op in the compiled HLO."""
    out: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line.strip())
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        nbytes = 0.0
        for dt, dims in _ARRAY_RE.findall(result_type):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
        counts[op] += 1
    return {**{f"{k}_bytes": v for k, v in out.items()}, **{f"{k}_count": counts[k] for k in counts}}
