"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices stand in for 2 TPU-v5e pods.  For every cell we record
``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs/bytes) and the
collective schedule parsed from the compiled HLO — the inputs to
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --all [--mesh both]
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k --mesh single
"""
# The XLA device-count override MUST precede any jax-touching import.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.launch.hloanalysis import collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402


def _specs_to_shardings(tree, mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree)


def build_cell(arch: str, shape: str, mesh, opts: frozenset = frozenset()):
    """Build (fn, example_args, in_shardings, out_shardings, donate) for a cell.

    ``opts`` — §Perf hillclimb variants, recorded per-artifact:
      'grad_bf16'   — all-reduce gradients in bf16 (halves DP-reduction bytes)
      'micro4'      — 4-way microbatch gradient accumulation
      'cache_seq_model' — context-parallel decode: KV-cache time dim over 'model'
      'seq_model'   — Megatron SP: activations shard S over 'model' (train/prefill)
    """
    cfg = registry.get(arch)
    sp = registry.SHAPES[shape]
    kv_quant = "kv_int8" in opts and cfg.family in ("dense", "moe", "vlm")
    model = build_model(cfg, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, kv_quant=kv_quant)
    shard_seq = shape == "long_500k"
    kv_seq_axis = "model" if "cache_seq_model" in opts else None

    # Anchor activation sharding: DP on batch (SP on sequence for long ctx).
    from repro.models import layers as _L

    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if shard_seq:
        _L.set_activation_sharding(batch_axes=None, seq_axes="data")
    elif "seq_model" in opts and sp.kind in ("train", "prefill"):
        # Megatron-style sequence parallelism: residual-stream activations
        # shard S over 'model' between blocks, so TP output all-reduces
        # become reduce-scatters (§Perf hillclimb option).
        _L.set_activation_sharding(batch_axes=dp, seq_axes="model")
    else:
        _L.set_activation_sharding(batch_axes=dp, seq_axes=None)
    _L.set_remat_policy("dots" if "remat_dots" in opts else "full")
    if "moe_cap_data" in opts:
        # EP buffers: experts over 'model', capacity over 'data' — expert-GEMM
        # partial sums become reduce-scatters instead of all-reduces.
        _L.set_moe_sharding(ep_axes="model", cap_axes="data")
    else:
        _L.set_moe_sharding(None, None)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = shd.param_pspecs(params_shape, mesh)
    p_sh = _specs_to_shardings(p_spec, mesh)
    inputs = registry.input_specs(arch, shape)

    if sp.kind == "train":
        opt_shape = jax.eval_shape(adamw.init, params_shape)
        o_spec = shd.opt_pspecs(opt_shape, p_spec)
        o_sh = _specs_to_shardings(o_spec, mesh)
        batch_sh = _specs_to_shardings(shd.batch_pspecs(inputs, mesh), mesh)
        opt_cfg = adamw.AdamWConfig(
            grad_dtype="bfloat16" if "grad_bf16" in opts else "float32"
        )
        step = make_train_step(
            model, opt_cfg, num_microbatches=4 if "micro4" in opts else 1
        )
        metrics_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P()),
            jax.eval_shape(step, params_shape, opt_shape, inputs)[2],
        )
        return dict(
            fn=step,
            args=(params_shape, opt_shape, inputs),
            in_shardings=(p_sh, o_sh, batch_sh),
            out_shardings=(p_sh, o_sh, metrics_sh),
            donate_argnums=(0, 1),
        )

    if sp.kind == "prefill":
        cache_len = sp.seq_len
        fn = lambda params, batch: model.prefill(params, batch, cache_len)
        batch_sh = _specs_to_shardings(shd.batch_pspecs(inputs, mesh), mesh)
        logits_shape, cache_shape = jax.eval_shape(fn, params_shape, inputs)
        c_sh = _specs_to_shardings(
            shd.cache_pspecs(cache_shape, mesh, shard_seq=shard_seq, kv_seq_axis=kv_seq_axis),
            mesh,
        )
        l_sh = NamedSharding(mesh, shd.batch_pspecs(logits_shape, mesh))
        return dict(
            fn=fn,
            args=(params_shape, inputs),
            in_shardings=(p_sh, batch_sh),
            out_shardings=(l_sh, c_sh),
            donate_argnums=(),
        )

    # decode: one token against a full-length cache
    b = sp.global_batch
    cache_shape = jax.eval_shape(lambda: model.init_cache(b, sp.seq_len))
    if cfg.family == "audio":  # decoder needs the encoder memory
        cache_shape = dict(cache_shape)
        cache_shape["memory"] = jax.ShapeDtypeStruct((b, sp.seq_len, cfg.d_model), jnp.bfloat16)
    c_spec = shd.cache_pspecs(cache_shape, mesh, shard_seq=shard_seq, kv_seq_axis=kv_seq_axis)
    c_sh = _specs_to_shardings(c_spec, mesh)
    fn = lambda params, cache, tokens, positions: model.decode_step(params, cache, tokens, positions)
    logits_shape, _ = jax.eval_shape(fn, params_shape, cache_shape, inputs["tokens"], inputs["positions"])
    if shard_seq:
        # batch=1 long-context: per-step inputs/outputs are tiny — replicate
        # them (the resident state is what's sharded, over sequence/feature).
        tok_sh = {
            k: NamedSharding(mesh, P(*([None] * len(v.shape)))) for k, v in inputs.items()
        }
        l_sh = NamedSharding(mesh, P(*([None] * len(logits_shape.shape))))
    else:
        tok_sh = _specs_to_shardings(shd.batch_pspecs(
            {k: v for k, v in inputs.items()}, mesh, shard_seq=False), mesh)
        l_sh = NamedSharding(mesh, shd.batch_pspecs(logits_shape, mesh))
    return dict(
        fn=fn,
        args=(params_shape, cache_shape, inputs["tokens"], inputs["positions"]),
        in_shardings=(p_sh, c_sh, tok_sh["tokens"], tok_sh["positions"]),
        out_shardings=(l_sh, c_sh),
        donate_argnums=(1,),
    )


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: Path, opts: frozenset = frozenset()) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    t0 = time.time()
    with mesh:
        cell = build_cell(arch, shape, mesh, opts)
        jitted = jax.jit(
            cell["fn"],
            in_shardings=cell["in_shardings"],
            out_shardings=cell["out_shardings"],
            donate_argnums=cell["donate_argnums"],
        )
        lowered = jitted.lower(*cell["args"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        colls = collective_bytes(compiled.as_text())
    record = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "opts": sorted(opts),
        "devices": int(mesh.size),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "collectives": colls,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = ("@" + "+".join(sorted(opts))) if opts else ""
    (out_dir / f"{arch}__{shape}__{mesh_kind}{suffix}.json").write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", default="", help="comma-separated §Perf options, e.g. grad_bf16,cache_seq_model")
    ap.add_argument("--skip-existing", action="store_true", help="skip cells with a committed record")
    args = ap.parse_args()

    out_dir = Path(args.out)
    cells = registry.all_cells() if args.all else [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    opts = frozenset(o for o in args.opt.split(",") if o)
    failures = []
    suffix = ("@" + "+".join(sorted(opts))) if opts else ""
    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch} x {shape} x {mk}"
            if args.skip_existing and (out_dir / f"{arch}__{shape}__{mk}{suffix}.json").exists():
                print(f"SKIP-EXISTING {tag}")
                continue
            try:
                rec = run_cell(arch, shape, mk, out_dir, opts)
                per_dev_gb = (rec["argument_bytes"] + rec["temp_bytes"]) / 2**30
                print(
                    f"PASS {tag}: compile={rec['compile_s']}s "
                    f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
                    f"arg+temp/dev={per_dev_gb:.2f}GiB "
                    f"colls={ {k: v for k, v in rec['collectives'].items() if k.endswith('_count')} }",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — a failing cell is a bug we must surface
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e!r}", flush=True)
    for skip in registry.skipped_cells():
        print(f"SKIP {skip[0]} x {skip[1]}: {skip[2]}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {[f[0] for f in failures]}")


if __name__ == "__main__":
    main()
