"""Tuning CLI: produce a shippable kernel deployment (or multi-device bundle).

The operator tool for new-hardware bring-up (the paper's zero-developer-
effort pitch):

  python -m repro.launch.tune --device tpu_v5e --out deploy.json
  python -m repro.launch.tune --device host_cpu --out deploy.json   # measured
  python -m repro.launch.tune --device tpu_v5e --archs granite-8b,glm4-9b

Every registered kernel family (matmul, attention, wkv, ssm_scan, ...) is
tuned into the artifact; ``--families`` restricts the set.  Fleet mode packs
one Deployment per device into a single v5 bundle any host auto-installs for
its detected hardware:

  python -m repro.launch.tune --devices tpu_v5e,tpu_v4 --bundle bundle.json

New hardware can be brought up cheaply through the staged pipeline
(DESIGN.md §12): ``--transfer-from deploy_v5e.json`` warm-starts from a tuned
sibling's artifact and measures only where model and sibling disagree,
``--prune-ratio 0.5`` drops the half of the config space the perf model rules
out before any measurement, and ``--measure-budget 0.3`` hard-caps measured
cells at 30% of a full harvest (``--measure-budget auto`` sizes the cap per
device from the donor's recorded lineage ``model_error``).  Fleet mode chains
transfers automatically with ``--transfer`` (donors tune first, siblings
warm-start off them).

Artifacts are consumed by trainers/servers via ``--deployment`` / ``--bundle``
launcher flags or ``repro.core.bundle.install_bundle(path)``.
"""
from __future__ import annotations

import argparse

from repro.configs import registry
from repro.core.cluster import CLUSTER_METHODS
from repro.core.normalize import NORMALIZATIONS
from repro.core.tuner import save_fleet, save_result, tune, tune_fleet, tune_for_archs


def _measure_budget(text: str):
    """argparse type for --measure-budget: a fraction in (0, 1) or 'auto'."""
    if text.strip().lower() == "auto":
        return "auto"
    try:
        val = float(text)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected a fraction in (0, 1) or 'auto', got {text!r}"
        ) from None
    if not 0.0 < val < 1.0:
        raise argparse.ArgumentTypeError(
            f"must be a fraction in (0, 1) or 'auto', got {val}"
        )
    return val


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--device", default="tpu_v5e", choices=["tpu_v5e", "tpu_v4", "host_cpu"])
    ap.add_argument("--devices", default=None,
                    help="comma-separated device names to tune into one bundle (fleet mode)")
    ap.add_argument("--archs", default=None, help="comma-separated arch ids (default: all)")
    ap.add_argument("--families", default=None,
                    help="comma-separated kernel families to tune beyond matmul "
                         "(default: every registered family; see repro.core.families)")
    ap.add_argument("--n-kernels", type=int, default=8)
    ap.add_argument("--method", default="pca_kmeans", choices=CLUSTER_METHODS)
    ap.add_argument("--normalization", default="standard", choices=NORMALIZATIONS)
    ap.add_argument("--classifier", default="DecisionTreeA")
    ap.add_argument("--max-problems", type=int, default=300)
    ap.add_argument("--cpu-problems", type=int, default=24)
    ap.add_argument("--out", default=None, help="single-device deployment output path")
    ap.add_argument("--bundle", default=None, help="multi-device bundle output path")
    ap.add_argument("--transfer-from", default=None, metavar="DEPLOY_JSON",
                    help="warm-start from a tuned sibling's deployment artifact: reuse "
                         "its kernel subset as clustering seeds and measure only where "
                         "the perf model and the sibling disagree (single-device mode)")
    ap.add_argument("--transfer", action="store_true",
                    help="fleet mode: tune donors first and warm-start each remaining "
                         "device from its nearest tuned sibling (devices.FALLBACKS)")
    ap.add_argument("--prune-ratio", type=float, default=None, metavar="R",
                    help="keep only the top R (0<R<1) of the config space by predicted "
                         "perf before measuring anything")
    ap.add_argument("--measure-budget", type=_measure_budget, default=None, metavar="B",
                    help="measure at most B (0<B<1) of the full harvest's cells; the "
                         "rest is filled from the perf model.  'auto' sizes B per "
                         "device from its donor's recorded lineage model_error "
                         "(donor-less tunes measure in full)")
    args = ap.parse_args(argv)

    if not args.out and not args.bundle:
        ap.error("one of --out / --bundle is required")
    if args.devices and not args.bundle:
        ap.error("--devices selects fleet mode and requires --bundle <path>")
    if args.prune_ratio is not None and not 0.0 < args.prune_ratio < 1.0:
        ap.error(f"--prune-ratio must be a fraction in (0, 1), got {args.prune_ratio}")
    if args.transfer_from and args.device == "host_cpu":
        ap.error("--transfer-from does not apply to host_cpu (it always measures)")
    transfer_prior = None
    if args.transfer_from:
        from repro.core.dispatch import Deployment

        transfer_prior = Deployment.load(args.transfer_from)

    archs = args.archs.split(",") if args.archs else None
    if archs:
        for a in archs:
            registry.get(a)  # validate early
    families = None
    if args.families is not None:
        from repro.core.families import get_family

        families = [f for f in args.families.replace(" ", "").split(",") if f]
        for f in families:
            get_family(f)  # validate early

    if args.bundle:
        device_names = tuple(
            (args.devices or "tpu_v5e,tpu_v4").replace(" ", "").split(",")
        )
        fleet = tune_fleet(
            archs, device_names=device_names, n_kernels=args.n_kernels,
            method=args.method, normalization=args.normalization,
            classifier=args.classifier, max_problems=args.max_problems,
            cpu_problems=args.cpu_problems, families=families,
            transfer=args.transfer, prune_ratio=args.prune_ratio,
            measure_budget=args.measure_budget,
        )
        save_fleet(fleet, args.bundle)
        print(f"bundle ({len(fleet.results)} devices) -> {args.bundle}")
        for name, res in sorted(fleet.results.items()):
            print(f"  {name}: oracle {res.oracle_fraction:.1%} / "
                  f"classifier {res.classifier_fraction:.1%} "
                  f"(families: {', '.join(res.deployment.family_names())})")
        # Prove the saved artifact serves: load it back into a fresh, isolated
        # KernelRuntime (nothing process-global is touched) and dispatch one
        # probe selection against the first tuned device.
        import repro

        rt = repro.load_bundle(args.bundle).runtime(device=device_names[0])
        probe = rt.select_matmul_config(512, 784, 512, 16)
        if probe is None:
            raise SystemExit(
                f"bundle verification failed: {args.bundle} loaded into {rt!r} "
                f"but served no probe selection"
            )
        print(f"verified: {rt!r} serves (probe matmul -> {probe.name()})")
        if not args.out:
            return
    if args.device == "host_cpu":
        from repro.core.cpubench import build_cpu_dataset, cpu_problems

        print(f"measuring {args.cpu_problems} problems x 210 configs on this host...")
        ds = build_cpu_dataset(cpu_problems(args.cpu_problems), verbose=True)
        result = tune(
            ds, n_kernels=args.n_kernels, method=args.method,
            normalization=args.normalization, classifier=args.classifier,
            arch_ids=archs, families=families,
        )
    else:
        result = tune_for_archs(
            archs, device_name=args.device, n_kernels=args.n_kernels,
            method=args.method, normalization=args.normalization,
            classifier=args.classifier, max_problems=args.max_problems,
            families=families, transfer_from=transfer_prior,
            prune_ratio=args.prune_ratio, measure_budget=args.measure_budget,
        )
    save_result(result, args.out)
    dep = result.deployment
    print(f"deployment -> {args.out}")
    for fname in dep.family_names():
        configs, _tree = dep.family_tuning(fname)
        print(f"  {fname:9s} kernels: {[c.name() for c in configs]}")
    print(f"  oracle {result.oracle_fraction:.1%} / classifier {result.classifier_fraction:.1%}")
    lineage = dep.meta.get("tuning_lineage") or {}
    rec = lineage.get("matmul")
    if rec and rec.get("measured_fraction", 1.0) < 1.0:
        src = rec.get("source_device") or "model only"
        print(f"  staged: measured {rec['measured_fraction']:.1%} of a full harvest "
              f"(donor: {src}, kept {rec['prune_ratio']:.0%} of config space)")


if __name__ == "__main__":
    main()
