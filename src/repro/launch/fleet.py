"""Multi-host fleet bootstrap: jax.distributed + topology-derived configs.

On a real TPU fleet every host runs the same binary:

  python -m repro.launch.train --arch ... --fleet

and this module turns scheduler-provided environment variables into the
process-level jax.distributed initialization plus the host-sharded
DataConfig.  Env contract (GKE/JobSet-style; SLURM variables are mapped):

  REPRO_COORDINATOR   host:port of process 0   (or SLURM nodelist head)
  REPRO_NUM_PROCESSES total host count         (or SLURM_NTASKS)
  REPRO_PROCESS_ID    this host's index        (or SLURM_PROCID)

Elastic restarts re-enter through the same path: after the scheduler
replaces a host, every process re-initializes with the new topology and the
trainer resumes from the latest committed checkpoint with a re-derived
``DataConfig`` (see core.faults.elastic_plan) — the checkpoint format is
sharding-agnostic, so no conversion step exists.
"""
from __future__ import annotations

import dataclasses
import os

from repro.data.pipeline import DataConfig


@dataclasses.dataclass(frozen=True)
class FleetTopology:
    coordinator: str
    num_processes: int
    process_id: int

    @property
    def is_multihost(self) -> bool:
        return self.num_processes > 1


def topology_from_env(env: dict | None = None) -> FleetTopology:
    """Read the fleet topology from the scheduler environment."""
    e = env if env is not None else os.environ
    coord = e.get("REPRO_COORDINATOR") or e.get("SLURM_LAUNCH_NODE_IPADDR", "localhost:12355")
    if ":" not in coord:
        coord = f"{coord}:12355"
    n = int(e.get("REPRO_NUM_PROCESSES") or e.get("SLURM_NTASKS") or 1)
    pid = int(e.get("REPRO_PROCESS_ID") or e.get("SLURM_PROCID") or 0)
    if not (0 <= pid < n):
        raise ValueError(f"process id {pid} out of range for {n} processes")
    return FleetTopology(coord, n, pid)


def initialize(topology: FleetTopology | None = None) -> FleetTopology:
    """Initialize jax.distributed for multi-host meshes (no-op single-host).

    Must run before any other jax call on every host; after it,
    ``jax.devices()`` spans the fleet and ``make_production_mesh`` builds the
    global mesh exactly as in the dry-run.
    """
    topo = topology or topology_from_env()
    if topo.is_multihost:
        import jax

        jax.distributed.initialize(
            coordinator_address=topo.coordinator,
            num_processes=topo.num_processes,
            process_id=topo.process_id,
        )
    return topo


def fleet_data_config(base: DataConfig, topo: FleetTopology) -> DataConfig:
    """Host-shard the data pipeline to this process (stateless resume/elastic)."""
    if base.global_batch % topo.num_processes != 0:
        raise ValueError(
            f"global_batch={base.global_batch} not divisible by "
            f"{topo.num_processes} hosts (see core.faults.elastic_plan)"
        )
    return dataclasses.replace(
        base, host_index=topo.process_id, host_count=topo.num_processes
    )
