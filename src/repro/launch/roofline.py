"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Derives the three roofline terms per (arch x shape) cell from the compiled
dry-run records in experiments/dryrun/:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

Semantics notes (documented in EXPERIMENTS.md):
  * ``compiled.cost_analysis()`` reports per-partition (per-device) numbers
    post-SPMD, so no further division by chip count is needed.
  * XLA cost analysis does NOT multiply ``while``-loop bodies by their trip
    count; our layer stacks are ``lax.scan``-ed, so HLO_FLOPs under-counts by
    ~n_layers.  We therefore also compute the analytic MODEL_FLOPS
    (6·N_active·D train / 2·N_active·D inference) per device and report
    both; the *analytic* compute term is the one used to pick the dominant
    bottleneck, and the MODEL/HLO ratio column exposes the scan factor +
    remat overhead exactly as intended.
  * collective bytes are parsed from the compiled (partitioned) HLO, so they
    are per-device shard bytes; one ICI link (~50 GB/s) is assumed (v5e has
    more links; this is the conservative bound).

Usage:  python -m repro.launch.roofline [--dir experiments/dryrun --mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import registry

PEAK_FLOPS = 197e12  # bf16 FLOP/s per v5e chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link


def model_flops(arch: str, shape: str) -> float:
    """Analytic useful FLOPs for one step of this cell (whole job)."""
    cfg = registry.get(arch)
    sp = registry.SHAPES[shape]
    n = cfg.n_active_params()
    b, s = sp.global_batch, sp.seq_len
    if sp.kind == "train":
        return 6.0 * n * b * s
    if sp.kind == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b  # decode: one new token per sequence


def analyze_record(rec: dict) -> dict:
    arch, shape = rec["arch"], rec["shape"]
    chips = rec["devices"]
    mf = model_flops(arch, shape)
    hlo_flops = rec["flops"]  # per device (post-SPMD)
    coll = sum(v for k, v in rec["collectives"].items() if k.endswith("_bytes"))
    t_compute_hlo = hlo_flops / PEAK_FLOPS
    t_compute = mf / chips / PEAK_FLOPS
    t_memory = rec["bytes_accessed"] / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    # Roofline fraction = intrinsic bound / achieved bound.  The intrinsic
    # bound of a step is max(compute, memory) — what the hardware allows
    # given the step's arithmetic intensity (a decode step is *inherently*
    # memory-bound; holding it to the compute roofline would be meaningless).
    # Collectives are overhead against that bound.
    intrinsic = max(t_compute, t_memory)
    frac = intrinsic / step_time if step_time > 0 else 0.0
    compute_frac = t_compute / step_time if step_time > 0 else 0.0
    hints = {
        "compute": "compute-bound: at roofline for the mesh; only a faster-"
                   "math kernel (fusion/precision) or more chips moves it",
        "memory": "memory-bound: cut HBM traffic (remat policy, bf16 state, "
                  "fuse reloads, shard the dominant resident tensor further)",
        "collective": "collective-bound: reshard to shrink the largest "
                      "collective or overlap it with compute (async)",
    }
    return {
        "arch": arch,
        "shape": shape,
        "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute,
        "t_compute_hlo_s": t_compute_hlo,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": frac,
        "compute_fraction": compute_frac,
        "model_flops": mf,
        "model_over_hlo": (mf / chips) / hlo_flops if hlo_flops else float("nan"),
        "bytes_per_device_gib": (rec["argument_bytes"] + rec["temp_bytes"]) / 2**30,
        "hint": hints[dominant],
    }


def load_records(dir_: Path, mesh: str) -> list[dict]:
    recs = []
    for p in sorted(dir_.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def table(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "roofline frac | MODEL/HLO | GiB/dev |\n|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | {r['dominant']} | "
            f"{r['roofline_fraction']:.2f} | {r['model_over_hlo']:.1f} | "
            f"{r['bytes_per_device_gib']:.1f} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = [analyze_record(r) for r in load_records(Path(args.dir), args.mesh)]
    rows.sort(key=lambda r: r["roofline_fraction"])
    print(table(rows))
    worst = [r for r in rows if r["roofline_fraction"] < 0.5]
    print(f"\n{len(rows)} cells; {len(worst)} below 50% of roofline")
    for r in rows[:3]:
        print(f"  worst: {r['arch']} x {r['shape']} ({r['roofline_fraction']:.2f}) — {r['hint']}")
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
