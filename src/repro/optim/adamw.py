"""AdamW with global-norm clipping and cosine LR schedule (from scratch).

Moments are kept in f32 regardless of param dtype.  Optimizer state shards
exactly like the params (the sharding rules map over the same tree), giving
ZeRO-style partitioning for free.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # optional gradient compression (see train/train_step.py)
    grad_dtype: str = "float32"  # 'float32' | 'bfloat16'


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    b1, b2 = cfg.betas
    lr = schedule(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**step.astype(jnp.float32))
        vh = v / (1 - b2**step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
