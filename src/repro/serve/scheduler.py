"""Admission/eviction scheduling for continuous batching.

The scheduler owns the wait queue and the ranking rules; the engine owns
lanes, the block pool, and the decode loop.  Policy:

* **Priority first.**  Requests carry an integer ``priority`` (higher runs
  sooner).  Waiting requests *age*: every ``aging_steps`` engine steps spent
  in the queue adds +1 to the effective priority, so a starved low-priority
  request eventually outranks fresh high-priority traffic (and eventually
  earns the right to preempt for admission).
* **Deadline second.**  Among equal effective priority, a smaller
  ``latency_target_ms`` (the request's SLO) sorts earlier; untargeted
  requests sort last.  Submission order breaks remaining ties, so scheduling
  is deterministic.
* **Head-of-line bypass.**  ``pop_next`` returns the best-ranked request
  *that fits* (per the engine's block-availability predicate), letting short
  prompts slip past a big one waiting for cache blocks.
* **Preemption.**  ``pick_victim`` chooses the active request to evict when
  the pool runs dry: lowest priority first, SLO-targeted requests protected
  over untargeted ones, then the one holding the most emitted tokens (the
  over-budget decode), newest submission last.  Preempted requests come back
  through ``submit`` with state ``"preempted"`` and keep their output; the
  engine re-admits them by re-prefilling prompt + generated tokens.  The
  engine passes requests whose lanes hold shared (refcount > 1) prefix
  blocks via ``protect=`` so siblings keep their cheap aliases; eviction of
  a protected holder is only a fallback when no other victim exists (and is
  still safe — release just decrements the refcount).
* **Prefill budget.**  With ``prefill_token_budget`` set, the engine calls
  ``begin_step()`` each step and ``charge_prefill(n)`` per admitted prompt
  chunk; ``prefill_budget_left`` caps how much prefill work one step may
  interleave with decode, so a 4k-token prompt is spread over many steps
  instead of stalling every decode lane while it traces.
"""
from __future__ import annotations

import dataclasses
import itertools
import math

__all__ = ["Scheduler", "SchedulerConfig"]


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    # Queue steps per +1 effective priority for waiting requests.
    aging_steps: int = 16
    # A waiter must outrank a victim by this much to preempt it for admission.
    preempt_priority_gap: int = 1
    # Max prefill tokens admitted per engine step (None = unbounded, the
    # monolithic-prefill behaviour).  Counted in padded chunk widths.
    prefill_token_budget: int | None = None


class Scheduler:
    def __init__(self, config: SchedulerConfig | None = None):
        self.config = config or SchedulerConfig()
        self._wait: list = []
        self._seq = itertools.count()
        self._prefill_spent = 0

    # -- per-step prefill budget -------------------------------------------
    def begin_step(self) -> None:
        """Reset the step's prefill-token spend (call once per engine step)."""
        self._prefill_spent = 0

    def charge_prefill(self, n_tokens: int) -> None:
        self._prefill_spent += n_tokens

    def prefill_budget_left(self) -> int | float:
        budget = self.config.prefill_token_budget
        if budget is None:
            return math.inf
        return max(0, budget - self._prefill_spent)

    def __len__(self) -> int:
        return len(self._wait)

    def waiting(self) -> list:
        return list(self._wait)

    def clear(self) -> list:
        """Drop (and return) everything still waiting — drain exhaustion."""
        out, self._wait = self._wait, []
        return out

    def submit(self, req, *, step: int) -> None:
        if getattr(req, "_seq", None) is None:
            req._seq = next(self._seq)
        req._enqueued_step = step
        self._wait.append(req)

    def effective_priority(self, req, step: int) -> int:
        aging = self.config.aging_steps
        waited = max(0, step - getattr(req, "_enqueued_step", step))
        return req.priority + (waited // aging if aging else 0)

    def _rank_key(self, req, step: int):
        target = req.latency_target_ms
        return (
            -self.effective_priority(req, step),
            target if target is not None else math.inf,
            req._seq,
        )

    def peek_best(self, step: int):
        if not self._wait:
            return None
        return min(self._wait, key=lambda r: self._rank_key(r, step))

    def pop_next(self, step: int, *, fits=lambda req: True):
        """Best-ranked waiting request that ``fits``; head-of-line bypass."""
        for req in sorted(self._wait, key=lambda r: self._rank_key(r, step)):
            if fits(req):
                self._wait.remove(req)
                return req
        return None

    def remove(self, req) -> None:
        self._wait.remove(req)

    def pick_victim(self, running, step: int, *, protect=()):
        """Active request to evict under block pressure (None if no choice).

        Raw priority (no aging — active requests aren't waiting), untargeted
        before SLO-targeted, most-emitted-tokens first, newest submission
        breaking ties.
        """
        cands = [r for r in running if r is not None and r not in protect]
        if not cands:
            return None
        return min(
            cands,
            key=lambda r: (
                r.priority,
                0 if r.latency_target_ms is None else 1,
                -len(r.output),
                -r._seq,
            ),
        )
