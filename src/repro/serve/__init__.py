"""Serving tier: paged KV cache, SLO-aware continuous batching, fleet router.

The serving lifecycle in four lines (DESIGN.md §13)::

    bundle = repro.load_bundle("fleet.json")
    router = bundle.router(model, params)          # one engine per tuned device
    ticket = router.submit(prompt, latency_target_ms=8.0)
    for tok in ticket.tokens(): ...                # streams while the fleet runs

Single-engine serving is ``rt.serve(model, params)`` on a
:class:`~repro.core.runtime.KernelRuntime`; the :class:`Router` fronts one
engine per device of a :class:`~repro.core.bundle.DeploymentBundle` with
least-loaded, health- and SLO-aware dispatch.
"""
from repro.core.runtime import Objective

from .engine import EngineStatus, Request, RetuneEvent, ServingEngine, Ticket
from .kvpool import KVPool
from .router import Router
from .scheduler import Scheduler, SchedulerConfig

__all__ = [
    "EngineStatus",
    "KVPool",
    "Objective",
    "Request",
    "RetuneEvent",
    "Router",
    "Scheduler",
    "SchedulerConfig",
    "ServingEngine",
    "Ticket",
]
