"""Multi-engine router: one ServingEngine per device behind one front door.

A :class:`Router` fronts a fleet of :class:`~repro.serve.engine.ServingEngine`
instances — typically one per tuned device of a single
:class:`~repro.core.bundle.DeploymentBundle`, each on its own isolated
:class:`~repro.core.runtime.KernelRuntime` (``bundle.router(model, params)``
builds exactly that).  Per-engine isolation is what makes per-engine SLO
objectives safe: an engine entering SLO mode constrains only its own
runtime's kernel selection.

Dispatch policy (deterministic):

* engines reporting ``health == "degraded"`` (the PR 6 incident/quarantine
  state machine) are skipped while any healthy engine exists;
* **prefix affinity**: when the prompt is known, engines are probed with
  :meth:`~repro.serve.engine.ServingEngine.prefix_overlap` (read-only — the
  hit-rate counters are untouched) and the ones already holding the longest
  cached prefix win, provided they hold at least one full block.  Sending a
  shared-system-prompt request to the engine that cached the prompt turns
  its prefill into a block-table alias instead of recomputation;
* among equally-affine engines, least-loaded wins — load is normalized
  queue+lane occupancy plus KV-pool block utilization;
* a request carrying ``latency_target_ms`` additionally avoids engines
  currently under SLO pressure (their width is capped — adding latency-
  sensitive traffic there defeats the point);
* remaining ties break on device name, so routing is reproducible.

The router re-exposes the engine's submit/stream surface: ``submit`` returns
a :class:`~repro.serve.engine.Ticket` whose streaming iterator steps the
whole fleet; ``step`` round-robins one scheduling round across engines with
work; ``drain`` runs everything down and aggregates the per-engine
:class:`~repro.serve.engine.EngineStatus`.
"""
from __future__ import annotations

import itertools

from .engine import EngineStatus, Request, ServingEngine, Ticket

__all__ = ["Router"]


class Router:
    def __init__(self, engines, *, name: str | None = None):
        """``engines``: mapping of key (device name) -> ServingEngine, or an
        iterable of engines (keyed by their ``device`` / position)."""
        if isinstance(engines, dict):
            self.engines: dict[str, ServingEngine] = dict(engines)
        else:
            self.engines = {}
            for i, eng in enumerate(engines):
                key = getattr(eng, "device", None) or f"engine{i}"
                if key in self.engines:
                    key = f"{key}#{i}"
                self.engines[key] = eng
        if not self.engines:
            raise ValueError("Router needs at least one engine")
        self.name = name or "router"
        self._uid = itertools.count()

    # -- dispatch -------------------------------------------------------------
    def _load(self, eng: ServingEngine) -> float:
        occupancy = (len(eng.scheduler) + sum(s is not None for s in eng.slots)) / max(
            eng.max_batch, 1
        )
        stats = eng.pool.stats()
        return occupancy + stats["used_blocks"] / max(stats["n_blocks"], 1)

    def dispatch(
        self, *, latency_target_ms: float | None = None, prompt=None
    ) -> str:
        """The engine key the next submit would pick (pure, no side effects)."""
        keys = sorted(self.engines)
        healthy = [k for k in keys if self.engines[k].health == "healthy"]
        eligible = healthy or keys
        if latency_target_ms is not None:
            calm = [k for k in eligible if not self.engines[k]._slo_mode]
            eligible = calm or eligible
        if prompt is not None and len(prompt) > 1:
            # Prefix affinity: prefer engines already holding the longest
            # cached prefix of this prompt (at least one full block).
            overlap = {k: self.engines[k].prefix_overlap(prompt) for k in eligible}
            best = max(overlap.values(), default=0)
            if best > 0:
                eligible = [k for k in eligible if overlap[k] == best]
        return min(eligible, key=lambda k: (self._load(self.engines[k]), k))

    # -- serving surface ------------------------------------------------------
    def submit(
        self,
        prompt,
        *,
        max_new_tokens: int = 16,
        eos_id: int | None = None,
        priority: int = 0,
        latency_target_ms: float | None = None,
        uid: int | None = None,
    ) -> Ticket:
        """Route one prompt to the best engine; returns a fleet-wide Ticket
        (its streaming iterator steps the whole router, so progress does not
        depend on which engine holds the request)."""
        key = self.dispatch(latency_target_ms=latency_target_ms, prompt=prompt)
        ticket = self.engines[key].submit(
            prompt,
            max_new_tokens=max_new_tokens,
            eos_id=eos_id,
            priority=priority,
            latency_target_ms=latency_target_ms,
            uid=uid if uid is not None else next(self._uid),
        )
        ticket.request.routed_to = key
        return Ticket(ticket.request, self)

    def submit_request(self, req: Request) -> Ticket:
        key = self.dispatch(
            latency_target_ms=req.latency_target_ms, prompt=req.prompt
        )
        self.engines[key].submit_request(req)
        req.routed_to = key
        return Ticket(req, self)

    def pending(self) -> bool:
        return any(e.pending() for e in self.engines.values())

    def step(self) -> bool:
        """One scheduling round on every engine with work; False = no progress."""
        progressed = False
        for key in sorted(self.engines):
            eng = self.engines[key]
            if eng.pending():
                progressed = bool(eng.step()) or progressed
        return progressed

    def drain(self, *, max_steps: int = 10_000) -> EngineStatus:
        """Serve everything submitted fleet-wide; aggregate EngineStatus.

        Engines are stepped round-robin (not drained one after another), so
        a slow engine cannot starve the others' budget and the fleet finishes
        together.  ``steps`` in the aggregate is the per-engine maximum (the
        wall-clock analogue), not the sum.
        """
        rounds = 0
        while self.pending() and rounds < max_steps:
            if not self.step():
                break
            rounds += 1
        statuses = [
            eng.drain(max_steps=eng.steps)  # budget spent: just close the epoch
            for eng in (self.engines[k] for k in sorted(self.engines))
        ]
        return self._aggregate(statuses)

    def status(self) -> EngineStatus:
        """Live fleet-wide aggregate snapshot."""
        return self._aggregate(
            [self.engines[k].status() for k in sorted(self.engines)]
        )

    def _aggregate(self, statuses: list[EngineStatus]) -> EngineStatus:
        n = max(len(statuses), 1)
        return EngineStatus(
            completed=sum(s.completed for s in statuses),
            in_flight=sum(s.in_flight for s in statuses),
            queued=sum(s.queued for s in statuses),
            steps=max((s.steps for s in statuses), default=0),
            exhausted=any(s.exhausted for s in statuses),
            health="degraded" if any(s.health == "degraded" for s in statuses)
            else "healthy",
            preempted=sum(s.preempted for s in statuses),
            # Pool health: ratios average across the fleet, counters sum.
            pool_utilization=sum(s.pool_utilization for s in statuses) / n,
            pool_fragmentation=sum(s.pool_fragmentation for s in statuses) / n,
            shared_blocks=sum(s.shared_blocks for s in statuses),
            prefix_hits=sum(s.prefix_hits for s in statuses),
            prefix_lookups=sum(s.prefix_lookups for s in statuses),
        )

    def healths(self) -> dict[str, str]:
        return {k: self.engines[k].health for k in sorted(self.engines)}
