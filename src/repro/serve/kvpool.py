"""Block-allocated paged KV cache for the serving tier.

The seed engine owned one dense ``model.init_cache(max_batch, cache_len)``
pytree: every slot paid for ``cache_len`` tokens of cache whether it held a
7-token prompt or none at all.  ``KVPool`` replaces that with a classic paged
layout (vLLM-style, adapted to "models consume dense caches"):

* cache storage is a pool of ``n_blocks`` fixed-size **blocks** of
  ``block_size`` tokens each, plus one permanently-zero **scratch block**
  (id 0) used to pad partially-filled lanes;
* every live request (a **lane**) owns an ordered **block table** — the
  blocks that back its tokens, allocated on admit and grown one block at a
  time as decode advances;
* models never see blocks: ``gather(lane_ids)`` materialises a dense
  ``(len(lane_ids), cache_len, ...)`` decode view from the tables, and
  ``scatter(lane_ids, cache)`` writes the updated view back into the pool.

Cache pytrees are classified *structurally*, with no per-model knowledge, by
probing ``model.init_cache`` at two (batch, length) points and watching which
axes scale:

* **paged** leaves have both a batch axis and a length axis that tracks
  ``cache_len`` exactly (k/v token caches) — these live in the block pool;
* **lane** leaves have a batch axis but no scaling length axis (recurrent
  WKV/SSM state, sliding-window rings shorter than ``cache_len``, cross-
  attention caches) — these live in a per-lane array, one row per lane;
* **replicated** leaves have neither (shared constants) — stored once.

The exact-scaling test is what makes sliding-window leaves safe: a Hymba SWA
ring of ``min(window, cache_len)`` tokens only classifies as paged when it
tracks *both* probe lengths, i.e. when it genuinely is a full-length cache.

Invariant relied on for byte-identity with the dense engine: models write
cache content only at positions ``< position`` and mask reads beyond it, and
freshly-initialised cache content is zero — so zero-filled growth blocks are
indistinguishable from a dense slot's untouched tail.

``block_size=None`` degenerates to one ``cache_len``-sized block per lane —
the dense layout, byte-identical to the seed engine (and the default for the
``ServingEngine`` constructor, so existing callers see no change).

**Prefix sharing.**  Blocks are refcounted and indexed by a *chain hash* of
the token ids they cache: ``h_i = hash((h_{i-1}, tokens[i*bs:(i+1)*bs]))``.
A lane that finished prefilling a prompt registers its fully-covered blocks
(:meth:`register_prefix`); a later submit with the same leading tokens finds
the longest indexed run (:meth:`match_prefix`) and aliases those blocks into
its own table (:meth:`alias`), skipping prefill for the shared span.  Shared
blocks are copy-on-write at block granularity: only *full* blocks whose
content can never be rewritten are ever indexed (the block holding a lane's
last/decode position stays private), so siblings only ever re-write shared
blocks with byte-identical content.  ``release`` decrements refcounts and
reclaims a block only at zero — index entries die with the block, so a
recycled block id can never serve a stale prefix.  Retired-but-unreclaimed
lanes keep their blocks indexed, so a popular system prompt survives its
original request (until block pressure harvests it).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["KVPool", "LeafSpec"]

_PROBE_BATCHES = (3, 5, 7, 11, 13)


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Structural classification of one cache leaf."""

    path: str
    kind: str  # "paged" | "lane" | "replicated"
    batch_axis: int | None
    length_axis: int | None


def _flatten_with_paths(tree):
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]
    return paths, leaves, treedef


def probe_cache_layout(init_cache, cache_len: int, block_size: int):
    """Classify every leaf of ``init_cache(batch, length)`` by axis scaling.

    Returns ``(specs, treedef)`` where ``specs[i]`` classifies the i-th leaf
    in flatten order and ``treedef`` rebuilds the pytree from a leaf list.
    """
    length_b = block_size if block_size != cache_len else max(1, cache_len // 2)
    if length_b == cache_len:
        raise ValueError(f"cache_len={cache_len} too small to probe a paged layout")
    batches = [b for b in _PROBE_BATCHES if b not in (cache_len, length_b)]
    pb_a, pb_b = batches[0], batches[1]

    paths_a, leaves_a, treedef = _flatten_with_paths(init_cache(pb_a, cache_len))
    _, leaves_b, treedef_b = _flatten_with_paths(init_cache(pb_b, length_b))
    if treedef != treedef_b:
        raise ValueError(
            "init_cache structure changes with (batch, length); cannot page it"
        )

    specs = []
    for path, la, lb in zip(paths_a, leaves_a, leaves_b):
        sa, sb = np.shape(la), np.shape(lb)
        if len(sa) != len(sb):
            raise ValueError(f"cache leaf {path} changes rank with (batch, length)")
        batch_axis = next(
            (i for i in range(len(sa)) if sa[i] == pb_a and sb[i] == pb_b), None
        )
        length_axis = None
        if batch_axis is not None:
            length_axis = next(
                (
                    i
                    for i in range(len(sa))
                    if i != batch_axis and sa[i] == cache_len and sb[i] == length_b
                ),
                None,
            )
        if batch_axis is None:
            kind = "replicated"
        elif length_axis is None:
            kind = "lane"
        else:
            kind = "paged"
        specs.append(LeafSpec(path, kind, batch_axis, length_axis))
    return tuple(specs), treedef


class KVPool:
    """Paged KV storage: block pool + per-lane block tables + lane state.

    ``lanes`` bounds concurrent decode residents (the engine's ``max_batch``);
    ``n_blocks`` bounds total live cache tokens (``n_blocks * block_size``).
    With the defaults (``block_size=None``) the pool is layout- and
    byte-identical to the seed engine's dense ``init_cache(lanes, cache_len)``.
    """

    def __init__(self, model, *, lanes: int, cache_len: int,
                 block_size: int | None = None, n_blocks: int | None = None):
        if lanes < 1:
            raise ValueError("lanes must be >= 1")
        self.lanes = int(lanes)
        self.cache_len = int(cache_len)
        self.block_size = int(block_size) if block_size else self.cache_len
        if self.cache_len % self.block_size:
            raise ValueError(
                f"cache_len={cache_len} not divisible by block_size={self.block_size}"
            )
        self.blocks_per_lane = self.cache_len // self.block_size
        self.n_blocks = int(n_blocks) if n_blocks else self.lanes * self.blocks_per_lane
        if self.n_blocks < self.blocks_per_lane:
            raise ValueError(
                f"n_blocks={self.n_blocks} cannot back even one full lane "
                f"({self.blocks_per_lane} blocks)"
            )

        self.specs, self.treedef = probe_cache_layout(
            model.init_cache, self.cache_len, self.block_size
        )
        # Block pool: batch axis indexes blocks; id 0 is the always-zero
        # scratch block that pads unallocated table rows in gathered views.
        _, pool_leaves, _ = _flatten_with_paths(
            model.init_cache(self.n_blocks + 1, self.block_size)
        )
        # Lane state (and replicated leaves) at the engine's dense shape.
        _, lane_leaves, _ = _flatten_with_paths(
            model.init_cache(self.lanes, self.cache_len)
        )
        self._store = [
            pool_leaves[i] if spec.kind == "paged" else lane_leaves[i]
            for i, spec in enumerate(self.specs)
        ]
        # Free list popped from the tail: ids come out ascending (1, 2, ...).
        self._free = list(range(self.n_blocks, 0, -1))
        self._tables: list[list[int]] = [[] for _ in range(self.lanes)]
        # Lanes whose resident finished but whose blocks haven't been
        # reclaimed yet: content stays readable (dense-engine parity for
        # post-run cache inspection) until an allocation actually needs it.
        self._retired: set[int] = set()
        # Per-block refcounts (index 0 = scratch, never allocated).  A fresh
        # allocation starts at 1; aliasing a shared prefix increments; release
        # decrements and only refcount 0 returns a block to the free list.
        self._rc = [0] * (self.n_blocks + 1)
        # Tokens actually resident per lane (for fragmentation accounting).
        self._lane_tokens = [0] * self.lanes
        # Prefix index: chain hash over block token content -> block id, plus
        # the reverse map so freeing a block drops its index entry.
        self._prefix_index: dict[int, int] = {}
        self._block_hash: dict[int, int] = {}
        self._prefix_lookups = 0
        self._prefix_hits = 0
        self._prefix_hit_tokens = 0

    # -- block accounting ---------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def block_table(self, lane: int) -> tuple[int, ...]:
        return tuple(self._tables[lane])

    def lane_capacity(self, lane: int) -> int:
        return len(self._tables[lane]) * self.block_size

    def blocks_needed(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.block_size))

    def block_refcount(self, blk: int) -> int:
        return self._rc[blk]

    def lane_holds_shared(self, lane: int) -> bool:
        """True if any block in ``lane``'s table is aliased by another lane."""
        return any(self._rc[blk] > 1 for blk in self._tables[lane])

    @property
    def retired_blocks(self) -> int:
        # Only blocks a harvest would actually free: refcount-1 residents of
        # retired lanes.  Shared blocks survive their retired owner.
        return sum(
            1
            for lane in self._retired
            for blk in self._tables[lane]
            if self._rc[blk] == 1
        )

    def retire(self, lane: int) -> None:
        """Mark a finished lane reclaimable without scrubbing it yet."""
        if self._tables[lane]:
            self._retired.add(lane)

    def _harvest(self, need: int) -> None:
        """Reclaim retired lanes (lowest lane id first) until ``need`` free
        blocks exist or no retired lane remains."""
        while len(self._free) < need and self._retired:
            lane = min(self._retired)
            self.release(lane)

    def can_fit(self, n_tokens: int) -> bool:
        """Could a fresh lane for ``n_tokens`` be admitted right now?"""
        return self.blocks_needed(n_tokens) <= len(self._free) + self.retired_blocks

    def ensure(self, lane: int, n_tokens: int) -> bool:
        """Grow ``lane``'s table to cover ``n_tokens``; False if pool is dry.

        Newly-allocated blocks are zeroed so the gathered view of the lane's
        unwritten tail matches a dense slot's untouched (zero) tail.
        """
        table = self._tables[lane]
        need = self.blocks_needed(n_tokens) - len(table)
        if need <= 0:
            return True
        if need > len(self._free):
            self._harvest(need)
        if need > len(self._free):
            return False
        for _ in range(need):
            blk = self._free.pop()
            self._rc[blk] = 1
            self._zero_block(blk)
            table.append(blk)
        return True

    def note_tokens(self, lane: int, n_tokens: int) -> None:
        """Record how many token slots ``lane`` actually uses (monotone)."""
        cap = len(self._tables[lane]) * self.block_size
        self._lane_tokens[lane] = min(max(self._lane_tokens[lane], n_tokens), cap)

    def release(self, lane: int) -> int:
        """Drop ``lane``'s claim on its blocks (finish or preemption).

        Each block's refcount is decremented; only blocks reaching zero are
        returned to the free list (a sibling aliasing a shared prefix keeps
        it alive).  Returns the number of blocks actually freed.
        """
        self._retired.discard(lane)
        table = self._tables[lane]
        dropped = []
        for blk in table:
            self._rc[blk] -= 1
            if self._rc[blk] == 0:
                dropped.append(blk)
                h = self._block_hash.pop(blk, None)
                if h is not None and self._prefix_index.get(h) == blk:
                    del self._prefix_index[h]
        # Reverse so pop() reuses the lane's lowest block id first.
        self._free.extend(reversed(dropped))
        self._tables[lane] = []
        self._lane_tokens[lane] = 0
        return len(dropped)

    # -- prefix sharing -----------------------------------------------------
    def _chain_hashes(self, tokens) -> list[int]:
        """Chain hash per *fully covered* block of ``tokens`` (token ids)."""
        bs = self.block_size
        toks = [int(t) for t in tokens]
        out: list[int] = []
        h = 0x9E3779B9  # fixed chain seed
        for i in range(len(toks) // bs):
            h = hash((h, tuple(toks[i * bs : (i + 1) * bs])))
            out.append(h)
        return out

    def match_prefix(self, tokens, *, peek: bool = False) -> list[int]:
        """Longest indexed block run caching a prefix of ``tokens``.

        Returns the block ids, in prefix order.  ``peek=True`` skips the
        hit-rate counters (used by router affinity probes so observability
        reflects actual admissions only).
        """
        run: list[int] = []
        for h in self._chain_hashes(tokens):
            blk = self._prefix_index.get(h)
            if blk is None:
                break
            run.append(blk)
        if not peek:
            self._prefix_lookups += 1
            if run:
                self._prefix_hits += 1
                self._prefix_hit_tokens += len(run) * self.block_size
        return run

    def register_prefix(self, lane: int, tokens) -> int:
        """Index ``lane``'s blocks that fully cover a prefix of ``tokens``.

        Only blocks whose ``block_size`` tokens are all real (never to be
        rewritten) are indexed — the copy-on-write rule: the block holding
        the lane's decode frontier stays private.  First registration of a
        chain hash wins; re-registering identical content is a no-op.
        Returns the number of shareable blocks.
        """
        table = self._tables[lane]
        n = 0
        for h, blk in zip(self._chain_hashes(tokens), table):
            if h not in self._prefix_index:
                self._prefix_index[h] = blk
                self._block_hash[blk] = h
            n += 1
        return n

    def admit_prefix(self, lane: int, tokens) -> int:
        """Release ``lane``'s previous tenant and seed it with the longest
        cached prefix of ``tokens``, atomically.

        The outgoing (retired) tenant may itself own the matched blocks — a
        follow-up request with the same system prompt admitted into its old
        lane — so the match is reserved (incref) *before* the release that
        would otherwise free it.  Returns the number of prefix tokens served
        from cache.
        """
        matched = self.match_prefix(tokens)
        for blk in matched:
            self._rc[blk] += 1  # reserve against the release below
        self.release(lane)
        self._tables[lane] = list(matched)
        self._lane_tokens[lane] = len(matched) * self.block_size
        return len(matched) * self.block_size

    def alias(self, lane: int, blocks) -> None:
        """Seed a fresh lane's table with shared ``blocks`` (incref each)."""
        table = self._tables[lane]
        if table:
            raise ValueError(f"alias() requires an empty table (lane {lane})")
        for blk in blocks:
            if self._rc[blk] < 1:
                raise ValueError(f"alias() of unallocated block {blk}")
            self._rc[blk] += 1
            table.append(blk)
        self._lane_tokens[lane] = len(table) * self.block_size

    def reset_lane_state(self, lane: int) -> None:
        """Zero ``lane``'s row of every lane-kind leaf (fresh-cache state).

        Used by aliased admissions: paged content arrives via shared blocks,
        but recurrent/lane state must start from ``init_cache`` zeros.
        """
        for i, spec in enumerate(self.specs):
            if spec.kind != "lane":
                continue
            arr = self._store[i]
            idx = [slice(None)] * arr.ndim
            idx[spec.batch_axis] = lane
            self._store[i] = arr.at[tuple(idx)].set(0)

    @property
    def shared_blocks(self) -> int:
        """Blocks currently aliased by more than one lane table."""
        return sum(1 for blk in range(1, self.n_blocks + 1) if self._rc[blk] > 1)

    def stats(self) -> dict:
        alloc_slots = sum(len(t) for t in self._tables) * self.block_size
        used_slots = min(sum(self._lane_tokens), alloc_slots)
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "free_blocks": self.free_blocks,
            "retired_blocks": self.retired_blocks,
            "used_blocks": self.used_blocks,
            "utilization": self.used_blocks / self.n_blocks,
            "fragmentation": (
                1.0 - used_slots / alloc_slots if alloc_slots else 0.0
            ),
            "shared_blocks": self.shared_blocks,
            "prefix_lookups": self._prefix_lookups,
            "prefix_hits": self._prefix_hits,
            "prefix_hit_tokens": self._prefix_hit_tokens,
            "prefix_hit_rate": (
                self._prefix_hits / self._prefix_lookups
                if self._prefix_lookups
                else 0.0
            ),
            "lanes": self.lanes,
            "lanes_used": sum(1 for t in self._tables if t),
        }

    # -- data movement ------------------------------------------------------
    def _zero_block(self, blk: int) -> None:
        for i, spec in enumerate(self.specs):
            if spec.kind != "paged":
                continue
            arr = self._store[i]
            idx = [slice(None)] * arr.ndim
            idx[spec.batch_axis] = blk
            self._store[i] = arr.at[tuple(idx)].set(0)

    def _padded_tables(self, lane_ids) -> np.ndarray:
        """(W, blocks_per_lane) block ids, scratch-0 padded."""
        bt = np.zeros((len(lane_ids), self.blocks_per_lane), dtype=np.int32)
        for row, lane in enumerate(lane_ids):
            table = self._tables[lane]
            bt[row, : len(table)] = table
        return bt

    def admit(self, lane: int, cache1) -> None:
        """Write a batch-1 prefill cache (full ``cache_len`` length) into
        ``lane``'s allocated blocks and lane-state row.

        Only the lane's allocated blocks are written; content beyond them is
        zero in ``cache1`` by the masking invariant (see module docstring).
        """
        leaves = self.treedef.flatten_up_to(cache1)
        table = self._tables[lane]
        ids = np.asarray(table, dtype=np.int32)
        for i, (spec, leaf) in enumerate(zip(self.specs, leaves)):
            if spec.kind == "replicated":
                # Dense-engine parity: _scatter_slot kept the pool's value.
                continue
            arr = self._store[i]
            if spec.kind == "lane":
                idx = [slice(None)] * arr.ndim
                idx[spec.batch_axis] = slice(lane, lane + 1)
                self._store[i] = arr.at[tuple(idx)].set(leaf)
                continue
            # paged: (…,1,…,cache_len,…) -> (blocks_per_lane, block_size, rest)
            canon = jnp.moveaxis(leaf, (spec.batch_axis, spec.length_axis), (0, 1))[0]
            chunks = canon.reshape(
                (self.blocks_per_lane, self.block_size) + canon.shape[1:]
            )
            pooled = jnp.moveaxis(arr, (spec.batch_axis, spec.length_axis), (0, 1))
            pooled = pooled.at[ids].set(chunks[: len(table)])
            self._store[i] = jnp.moveaxis(
                pooled, (0, 1), (spec.batch_axis, spec.length_axis)
            )

    def gather(self, lane_ids) -> object:
        """Materialise the dense decode view for ``lane_ids``.

        Paged leaves are assembled from block tables (scratch-padded rows
        read as zero); lane leaves are row-gathered; replicated leaves pass
        through untouched.
        """
        lane_ids = list(lane_ids)
        idx = jnp.asarray(self._padded_tables(lane_ids).reshape(-1))
        rows = jnp.asarray(np.asarray(lane_ids, dtype=np.int32))
        out = []
        for spec, arr in zip(self.specs, self._store):
            if spec.kind == "replicated":
                out.append(arr)
            elif spec.kind == "lane":
                out.append(jnp.take(arr, rows, axis=spec.batch_axis))
            else:
                pooled = jnp.moveaxis(
                    arr, (spec.batch_axis, spec.length_axis), (0, 1)
                )
                got = jnp.take(pooled, idx, axis=0)  # (W*bpl, block, rest)
                got = got.reshape(
                    (len(lane_ids), self.blocks_per_lane * self.block_size)
                    + got.shape[2:]
                )
                out.append(
                    jnp.moveaxis(got, (0, 1), (spec.batch_axis, spec.length_axis))
                )
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def scatter(self, lane_ids, cache) -> None:
        """Write an updated dense view back into the pool.

        The scratch block absorbs writes from unallocated table rows and is
        re-zeroed afterwards so later gathers still read zeros there.
        """
        lane_ids = list(lane_ids)
        idx = jnp.asarray(self._padded_tables(lane_ids).reshape(-1))
        rows = jnp.asarray(np.asarray(lane_ids, dtype=np.int32))
        leaves = self.treedef.flatten_up_to(cache)
        touched_scratch = False
        for i, (spec, leaf) in enumerate(zip(self.specs, leaves)):
            arr = self._store[i]
            if spec.kind == "replicated":
                # Dense-engine parity: the decode output's replicated leaves
                # became the pool wholesale.
                self._store[i] = leaf
            elif spec.kind == "lane":
                moved = jnp.moveaxis(arr, spec.batch_axis, 0)
                new = jnp.moveaxis(leaf, spec.batch_axis, 0)
                moved = moved.at[rows].set(new)
                self._store[i] = jnp.moveaxis(moved, 0, spec.batch_axis)
            else:
                pooled = jnp.moveaxis(
                    arr, (spec.batch_axis, spec.length_axis), (0, 1)
                )
                canon = jnp.moveaxis(
                    leaf, (spec.batch_axis, spec.length_axis), (0, 1)
                )
                chunks = canon.reshape(
                    (len(lane_ids) * self.blocks_per_lane, self.block_size)
                    + canon.shape[2:]
                )
                pooled = pooled.at[idx].set(chunks)
                self._store[i] = jnp.moveaxis(
                    pooled, (0, 1), (spec.batch_axis, spec.length_axis)
                )
                touched_scratch = True
        if touched_scratch:
            self._zero_block(0)
