"""Batched serving engine: slot-based continuous batching over a KV cache.

The engine owns a fixed pool of ``max_batch`` cache slots of ``cache_len``
tokens (static shapes => one compiled prefill fn and one compiled decode fn,
reused for the whole serving lifetime — the same "few deployed kernels"
economics as the paper's library setting; the ML-guided matmul selection in
``repro.kernels.ops`` runs once at trace time for each of the two programs).

Scheduling loop (``run``):
  1. admit queued requests into free slots (prefill, one request at a time —
     prefill shapes bucket by padded length);
  2. one batched decode step advances *all* active slots;
  3. finished sequences (EOS or max_new_tokens) free their slot.

Per-slot position/valid bookkeeping lives in numpy on the host; tokens and
caches stay on device.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.retune import DEFAULT_DRIFT_THRESHOLD, DEFAULT_MIN_EVENTS


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    state: str = "queued"  # queued | active | done | starved
    truncated_tokens: int = 0  # prompt tokens dropped by sliding-window admit
    retries: int = 0  # kernel-fault retries this request survived


@dataclasses.dataclass(frozen=True)
class RetuneEvent:
    """One firing of the continuous tuning loop (DESIGN.md §8).

    ``swapped`` distinguishes a drift check that triggered a retune + policy
    hot-swap from one that merely looked; ``epoch`` is the engine runtime's
    policy epoch after the swap (monotonic within that runtime).  Drift is checked
    per kernel family: ``families`` names the families whose tunings were
    refreshed by this event (empty when nothing triggered), and
    ``drift_score`` / ``unseen_fraction`` report the worst family observed.
    ``rejected`` names families whose retune candidate failed the canary and
    was never installed; ``rolled_back`` marks the auto-rollback event of a
    previously installed policy that regressed in service (DESIGN.md §11).
    """

    step: int
    drift_score: float
    unseen_fraction: float
    swapped: bool
    triggered: bool  # False + high score means the min-events floor blocked it
    n_events: int
    n_configs: int
    epoch: int
    families: tuple[str, ...] = ()
    rejected: tuple[str, ...] = ()
    rolled_back: bool = False


@dataclasses.dataclass(frozen=True)
class EngineStatus:
    """What ``ServingEngine.run`` actually finished (and what it didn't).

    ``exhausted`` means the step budget ran out with work left: ``in_flight``
    requests hold slots mid-decode, ``queued`` never got a slot.  Both carry
    ``done=False`` and a non-``"done"`` per-request ``state`` — checking
    ``output`` alone cannot distinguish them once prefill has emitted tokens.
    ``health`` is the engine's final serving-health state (``"healthy"`` /
    ``"degraded"``): degraded while dispatch incidents are arriving or
    configs sit in quarantine, healthy again once the window is clean.
    """

    completed: int
    in_flight: int
    queued: int
    steps: int
    exhausted: bool
    health: str = "healthy"


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServingEngine:
    def __init__(
        self,
        model,
        params,
        *,
        max_batch: int = 4,
        cache_len: int = 256,
        prefill_buckets: tuple[int, ...] = (32, 64, 128),
        extra_inputs: dict | None = None,
        bundle=None,
        device: str | None = None,
        runtime=None,
        retune_interval: int | None = None,
        drift_threshold: float = DEFAULT_DRIFT_THRESHOLD,
        retune_min_events: int = DEFAULT_MIN_EVENTS,
        canary: bool = True,
        rollback_threshold: int = 3,
        swap_history: int = 4,
    ):
        from repro.core.runtime import current_runtime

        # The engine dispatches against ONE explicit KernelRuntime for its
        # whole lifetime: every prefill/decode trace runs inside
        # ``runtime.activate()``, so two engines with different runtimes (two
        # tenants, an A/B shadow pair) share no policy, shape-cache, or
        # selection-log state even on the same thread.  ``runtime=None``
        # adopts the caller's current runtime (the process default unless the
        # ctor runs inside an activation) — the legacy behavior.
        self.runtime = runtime if runtime is not None else current_runtime()
        # A serving host consumes the multi-device artifact directly: install
        # the Deployment resolved for this host (nearest tuned sibling when
        # untuned) before the first trace-time kernel selection runs.
        self.deployment = None
        self.device = device
        if bundle is not None:
            self.deployment = self.runtime.install_bundle(bundle, device)
            self.device = self.runtime.active_device()
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prefill_buckets = prefill_buckets
        self.extra_inputs = extra_inputs or {}

        self.cache = model.init_cache(max_batch, cache_len)
        self.positions = np.zeros(max_batch, dtype=np.int32)  # next position to write
        self.slots: list[Request | None] = [None] * max_batch
        self.steps = 0

        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill_cache = {}

        # -- continuous tuning loop (DESIGN.md §8) ---------------------------
        self.retune_interval = retune_interval
        self.drift_threshold = drift_threshold
        self.retune_min_events = retune_min_events
        self.retune_events: list[RetuneEvent] = []
        self._last_retune_check = 0
        # -- failure containment (DESIGN.md §11) -----------------------------
        self.canary = canary
        self.rollback_threshold = max(int(rollback_threshold), 1)
        self.health = "healthy"
        self.health_events: list[tuple[int, str]] = []  # (step, new state)
        self._incidents_seen = self.runtime.incident_count()
        # Previous deployments, newest last; maybe_retune pushes the incumbent
        # before installing a candidate, the rollback watchdog pops it.
        self._swap_history: deque = deque(maxlen=max(int(swap_history), 1))
        self._incidents_at_swap: int | None = None
        if retune_interval is not None:
            # Telemetry source: the runtime's selection log (cache hits
            # included, so the histogram reflects real traffic frequencies).
            self.runtime.set_selection_logging(True)

    def dispatch_stats(self) -> dict:
        """Kernel-selection shape-cache counters (convenience passthrough).

        Each prefill bucket and the decode program retrace the model, so
        repeated admissions re-run trace-time kernel selection; the runtime's
        shape cache (DESIGN.md §6) turns those repeats into dict hits.  Note
        the counters are per *thread within the runtime*: call from the
        thread that drives this engine, and expect other engines sharing the
        same runtime on this thread to contribute to the same numbers.
        """
        return self.runtime.shape_cache_stats()

    # -- slot admission -------------------------------------------------------
    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            fn = lambda params, batch: self.model.prefill(params, batch, self.cache_len)
            self._prefill_cache[plen] = jax.jit(fn)
        return self._prefill_cache[plen]

    def _admit(self, req: Request, slot: int) -> None:
        plen = _bucket(len(req.prompt), self.prefill_buckets)
        tail = np.asarray(req.prompt, dtype=np.int32)
        if len(tail) > plen:
            # Sliding-window truncation: a prompt longer than the largest
            # prefill bucket keeps its most recent plen tokens (causal decode
            # conditions on the suffix) instead of raising on the left-pad.
            req.truncated_tokens = len(tail) - plen
            tail = tail[-plen:]
        prompt = np.zeros(plen, dtype=np.int32)
        if len(tail):
            prompt[-len(tail) :] = tail  # left-pad (causal end-aligned)
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        for k, v in self.extra_inputs.items():
            batch[k] = _batch_extra(k, v)
        with self.runtime.activate():  # trace-time selections hit OUR runtime
            logits, cache1 = self._run_program(
                "engine.prefill",
                lambda: self._prefill_fn(plen)(self.params, batch),
                retrace=lambda: self._prefill_cache.pop(plen, None),
                request=req,
            )
        # Scatter the single-sequence prefill cache into this slot.
        self.cache = jax.tree.map(
            lambda full, one: _scatter_slot(full, one, slot, self.max_batch),
            self.cache,
            cache1,
        )
        first = int(jnp.argmax(logits[0, -1]))
        req.output.append(first)
        req.state = "active"
        self.slots[slot] = req
        self.positions[slot] = plen

    # -- decode ---------------------------------------------------------------
    def _decode_all(self) -> None:
        tokens = np.zeros((self.max_batch, 1), dtype=np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                tokens[i, 0] = r.output[-1]
        with self.runtime.activate():  # trace-time selections hit OUR runtime
            logits, self.cache = self._run_program(
                "engine.decode",
                lambda: self._decode(
                    self.params, self.cache, jnp.asarray(tokens), jnp.asarray(self.positions)
                ),
                retrace=self._rejit_decode,
            )
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            self.positions[i] += 1
            tok = int(nxt[i])
            r.output.append(tok)
            if (
                len(r.output) >= r.max_new_tokens
                or (r.eos_id is not None and tok == r.eos_id)
                or self.positions[i] >= self.cache_len - 1
            ):
                r.done = True
                r.state = "done"
                self.slots[i] = None
        self.steps += 1

    # -- failure containment (DESIGN.md §11) -----------------------------------
    def _rejit_decode(self) -> None:
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def _run_program(self, site: str, fn, *, retrace, request: Request | None = None):
        """Run one compiled program with per-request retry-on-kernel-fault.

        The engine-level safety net above the ops-layer guard: an injected
        fault at ``site`` (fired *before* execution, so donated buffers are
        never half-consumed) or a real failure escaping the compiled program
        gets one retry after ``retrace()`` drops the compiled artifact —
        the re-trace re-runs kernel selection, picking up any quarantine the
        ops guard installed meanwhile.  A second failure propagates: zero
        silent drops, but also no infinite retry loop.
        """
        from repro.core.faults import GUARDED_EXCEPTIONS, incident

        rt = self.runtime
        plan = rt.fault_plan
        try:
            if plan is not None:
                plan.raise_if(site)
            return fn()
        except GUARDED_EXCEPTIONS as e:
            rt.record_incident(incident(
                site, "engine", None, e, "retry", device=rt.active_device()))
            if request is not None:
                request.retries += 1
            retrace()
            return fn()

    def _update_health(self) -> str:
        """Advance the healthy/degraded state machine; record transitions.

        Degraded while new incidents arrived since the last check or any
        config sits in quarantine; healthy once a full check window passes
        clean with an empty quarantine table.
        """
        rt = self.runtime
        count = rt.incident_count()
        fresh = count > self._incidents_seen
        self._incidents_seen = count
        state = "degraded" if (fresh or rt.quarantined()) else "healthy"
        if state != self.health:
            self.health = state
            self.health_events.append((self.steps, state))
        return state

    def maybe_rollback(self) -> RetuneEvent | None:
        """Auto-rollback watchdog for an installed-but-regressing policy.

        If :data:`rollback_threshold` incidents accumulate after a hot-swap,
        the most recent pre-swap deployment is reinstalled from the bounded
        swap history (one rollback per swap: the counter re-arms only on the
        next swap).  Compiled programs are invalidated the same way a swap
        does; in-flight requests keep their slots.
        """
        from repro.core.faults import incident

        rt = self.runtime
        if self._incidents_at_swap is None or not self._swap_history:
            return None
        if rt.incident_count() - self._incidents_at_swap < self.rollback_threshold:
            return None
        prev = self._swap_history.pop()
        if self.device is not None and rt.active_device() == self.device:
            rt.install_for_device(self.device, prev)
        else:
            rt.install(prev)
        self.deployment = prev
        self._incidents_at_swap = None  # one rollback per swap
        rt.record_incident(incident(
            "engine.retune", "engine", None,
            f"{self.rollback_threshold}+ incidents since hot-swap",
            "rollback", device=rt.active_device()))
        rt.clear_selection_log()
        self._prefill_cache.clear()
        self._rejit_decode()
        ev = RetuneEvent(self.steps, 0.0, 0.0, True, True, 0,
                         len(prev.configs) if hasattr(prev, "configs") else 0,
                         rt.policy_epoch(), rolled_back=True)
        self.retune_events.append(ev)
        return ev

    # -- continuous tuning -----------------------------------------------------
    def maybe_retune(self, *, force: bool = False, online=None) -> RetuneEvent | None:
        """Telemetry -> drift check -> incremental retune -> policy hot-swap.

        Called between ``run()`` decode steps when ``retune_interval`` is set,
        or directly from an operator's background hook (the runtime's policy
        registry is lock+epoch protected, so a swap from another thread
        reaches the serving thread atomically — and only threads dispatching
        against *this engine's runtime*; other tenants' runtimes never see
        it).  Returns the :class:`RetuneEvent` when a
        drift check actually ran (``swapped=False`` if it didn't trigger),
        ``None`` when there is no deployment or not enough telemetry yet.
        ``online`` optionally names a hybrid-mode ``OnlinePolicy``: its arm
        measurements ride into the snapshot, and after a swap it adopts the
        retuned deployment as its prior (``set_prior``).

        The hot swap is zero-downtime: KV caches, slots, and in-flight
        requests are untouched; compiled programs for *already-traced* shapes
        keep their old kernels until natural retrace, while the cleared
        prefill/decode jit wrappers make every subsequent trace consult the
        new policy.
        """
        from repro.core.dispatch import Deployment
        from repro.core.faults import FaultError, incident
        from repro.core.retune import (
            canary_deployment,
            detect_drift_all,
            incremental_retune,
        )

        rt = self.runtime
        dep = self.deployment
        if dep is None:
            pol = rt.policy()
            dep = pol if isinstance(pol, Deployment) else None
        if dep is None:
            return None
        snap = rt.telemetry(online=online)
        if snap.n_events == 0:
            return None
        # Drift is detected per (device, family, shape): every family with
        # live traffic gets its own report against its own provenance, so an
        # ssm-only traffic shift retunes the ssm family without touching the
        # (undrifted) matmul artifact.
        reports = detect_drift_all(
            snap, dep, threshold=self.drift_threshold, min_events=self.retune_min_events
        )
        worst = max(reports.values(), key=lambda r: r.score)
        to_retune = [f for f, r in reports.items() if r.triggered]
        if force and not to_retune:
            to_retune = list(reports)
        if not to_retune:
            # n_events is the worst family's own event count: the "below
            # event floor" verdict must be judged against the per-family
            # floor drift detection actually applied, not the cross-family
            # aggregate.
            ev = RetuneEvent(self.steps, worst.score, worst.unseen_fraction,
                             False, any(r.triggered for r in reports.values()),
                             worst.n_events, len(dep.configs), rt.policy_epoch())
            self.retune_events.append(ev)
            return ev
        # Canary-gated adoption: each family's candidate must pass the
        # holdout validation (selection quality + numeric agreement with
        # ref) before it is allowed anywhere near install_for_device.  A
        # rejected candidate leaves the incumbent family tuning in place.
        new_dep = dep
        adopted: list[str] = []
        rejected: list[str] = []
        for fam in to_retune:
            try:
                if rt.fault_plan is not None:
                    rt.fault_plan.raise_if("retune.candidate", fam)
                cand = incremental_retune(
                    new_dep, snap, family=fam, report=reports[fam],
                    threshold=self.drift_threshold, min_events=self.retune_min_events,
                ).deployment
            except (FaultError, ValueError) as e:
                rejected.append(fam)
                rt.record_incident(incident(
                    "retune.candidate", fam, None, e, "candidate_failed",
                    device=rt.active_device()))
                continue
            if self.canary:
                verdict = canary_deployment(new_dep, cand, snap, family=fam, runtime=rt)
                if not verdict.ok:
                    rejected.append(fam)
                    rt.record_incident(incident(
                        f"canary.{fam}", fam, None, verdict.reason,
                        "candidate_rejected", device=rt.active_device()))
                    continue
            new_dep = cand
            adopted.append(fam)
        if not adopted:
            ev = RetuneEvent(self.steps, worst.score, worst.unseen_fraction,
                             False, any(r.triggered for r in reports.values()),
                             worst.n_events, len(dep.configs), rt.policy_epoch(),
                             rejected=tuple(rejected))
            self.retune_events.append(ev)
            return ev
        to_retune = adopted
        # Keep the incumbent in the bounded swap history and re-arm the
        # rollback watchdog: incidents from here on count against this swap.
        self._swap_history.append(dep)
        self._incidents_at_swap = rt.incident_count()
        if self.device is not None and rt.active_device() == self.device:
            rt.install_for_device(self.device, new_dep)  # registry hot-swap
        else:
            rt.install(new_dep)
        if online is not None and hasattr(online, "set_prior"):
            # A hybrid-mode OnlinePolicy must adopt the retuned deployment as
            # its prior (and drop its prior-derived attention cache with it).
            online.set_prior(new_dep)
        self.deployment = new_dep
        rt.clear_selection_log()  # fresh telemetry window for the new policy
        # Invalidate this engine's compiled programs so the next admission /
        # decode trace re-runs kernel selection under the swapped-in policy.
        # Engine state (cache pool, slots, positions) survives: in-flight
        # requests continue without a drop, paying only a retrace.
        self._prefill_cache.clear()
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        worst_retuned = max((reports[f] for f in to_retune), key=lambda r: r.score)
        ev = RetuneEvent(self.steps, worst_retuned.score, worst_retuned.unseen_fraction,
                         True, any(r.triggered for r in reports.values()),
                         worst_retuned.n_events, len(new_dep.configs), rt.policy_epoch(),
                         tuple(to_retune), rejected=tuple(rejected))
        self.retune_events.append(ev)
        return ev

    # -- public ---------------------------------------------------------------
    def run(self, requests: list[Request], *, max_steps: int = 10_000) -> EngineStatus:
        """Serve a request list with continuous batching until done or budget.

        Returns an :class:`EngineStatus`.  When the ``max_steps`` budget is
        exhausted, unfinished requests are NOT silently returned as results:
        in-flight ones keep ``state="active"`` and queued ones are marked
        ``state="starved"`` (both stay ``done=False``), so callers can retry
        or surface them even though partial ``output`` tokens exist.
        """
        queue = list(requests)
        while (queue or any(s is not None for s in self.slots)) and self.steps < max_steps:
            while queue:
                slot = self._free_slot()
                if slot is None:
                    break
                self._admit(queue.pop(0), slot)
            if any(s is not None for s in self.slots):
                self._decode_all()
            self._update_health()
            self.maybe_rollback()
            if (
                self.retune_interval is not None
                and self.steps - self._last_retune_check >= self.retune_interval
            ):
                self._last_retune_check = self.steps
                self.maybe_retune()
        exhausted = bool(queue or any(s is not None for s in self.slots))
        for r in queue:
            r.state = "starved"
        self._update_health()
        return EngineStatus(
            completed=sum(r.done for r in requests),
            in_flight=sum(s is not None for s in self.slots),
            queued=len(queue),
            steps=self.steps,
            exhausted=exhausted,
            health=self.health,
        )


def _batch_extra(key: str, v) -> jax.Array:
    """Shape one extra input for the batch-1 prefill, explicitly per rank.

    Extras come in two layouts: already batched with a leading batch-1 axis
    (``(1, n, d)``) which pass through, or per-sequence without a batch axis
    (``(n, d)``, or a scalar) which gain one.  A leading axis > 1 that is not
    batch-1 is treated as per-sequence data; an explicit batch > 1 cannot be
    meant for a single-sequence prefill, so there is nothing to guess.
    """
    v = jnp.asarray(v)
    if v.ndim == 0:
        return v[None]  # scalar -> (1,)
    if v.shape[0] == 1:
        return v  # already batched (batch-1 leading axis)
    return v[None]  # per-sequence -> add the batch axis


def _scatter_slot(full: jax.Array, one: jax.Array, slot: int, max_batch: int) -> jax.Array:
    """Write a batch-1 cache entry into batch slot ``slot`` of the pool.

    Cache leaves carry batch either at axis 0 (B, ...) or axis 1 (L, B, ...);
    the batch axis is the one sized ``max_batch`` in the pool and 1 in the
    prefill output.  Matching against the *pool size* (not shape inequality)
    keeps the write live when ``max_batch == 1``, where pool and prefill
    shapes coincide and an inequality guard silently drops the cache.
    """
    if one.ndim != full.ndim:
        raise ValueError(f"cache rank mismatch {one.shape} vs {full.shape}")
    for axis in (0, 1):
        if one.ndim > axis and one.shape[axis] == 1 and full.shape[axis] == max_batch:
            idx = [slice(None)] * full.ndim
            idx[axis] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(one)
    # replicated leaf (e.g. shared encoder memory broadcast across slots): keep.
    return full
