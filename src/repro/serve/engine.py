"""Continuous-batching serving engine over a paged KV cache.

The engine owns ``max_batch`` decode **lanes** backed by a block-allocated
:class:`~repro.serve.kvpool.KVPool` (cache memory scales with live tokens,
not slots), a priority/deadline :class:`~repro.serve.scheduler.Scheduler`
with starvation aging and preemption, and per-width compiled decode programs
(static shapes => a small set of compiled programs reused for the whole
serving lifetime — the same "few deployed kernels" economics as the paper's
library setting; ML-guided kernel selection runs once at trace time per
program).

Serving surface (new code):

    ticket = engine.submit(prompt, max_new_tokens=32,
                           priority=1, latency_target_ms=8.0)
    for tok in ticket.tokens():   # streams; drives engine.step() as needed
        ...
    status = engine.drain()       # run everything submitted to completion

One ``engine.step()`` is one scheduling round: admit waiting requests into
free lanes (prefill, bucketed by padded length), grow each active lane's
block table by one block when decode crosses a block boundary (preempting
the lowest-priority resident back to the wait queue — with block reclaim —
when the pool runs dry), then one batched decode advances all active lanes
at the smallest compiled width bucket that fits.

``latency_target_ms`` threads an SLO into kernel selection: when a targeted
request's recent per-token latency overruns its target, the engine installs
an :class:`~repro.core.runtime.Objective` on its runtime (selection policies
answer ``select_for_objective`` — e.g. a lower-latency kernel config instead
of the throughput pick), caps admission below the current width bucket, and
invalidates compiled programs so the next trace re-selects; it backs off
with hysteresis once targeted lanes run comfortably under target.

``engine.run(requests)`` — the seed batch API — remains as a deprecated
shim over submit/drain with byte-identical outputs.  Per-lane bookkeeping
lives in numpy on the host; tokens and caches stay on device.

**Streaming admission (prefix sharing + chunked prefill, DESIGN.md §13).**
When the pool is paged and the model supports ``prefill_chunk``, admission
switches from the legacy regime (left-padded monolithic prefill, first token
from the prefill's last-position logits) to a *streaming* regime: the
sequence is left-aligned so block content is position-stable, the prompt
body is prefilled in scheduler-budgeted chunks (``prefill_chunk_tokens`` /
``SchedulerConfig.prefill_token_budget``) that interleave with decode
rounds, and the final chunk also covers the last prompt token so its
logits at the last real row yield the first output token (no separate
first-token program).  Left alignment is what makes prefix sharing possible: a new
request whose prompt starts with tokens another lane already cached aliases
those blocks (refcounted, copy-on-write at the first partial block) and
skips prefill for the shared span.  Dense pools (``block_size=None``)
without an explicit chunk budget keep the legacy regime bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import warnings
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.retune import DEFAULT_DRIFT_THRESHOLD, DEFAULT_MIN_EVENTS

from .kvpool import KVPool
from .scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    priority: int = 0  # higher admits sooner (scheduler ages waiters up)
    latency_target_ms: float | None = None  # per-token SLO -> kernel selection
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    state: str = "queued"  # queued | active | preempted | done | starved
    truncated_tokens: int = 0  # prompt tokens dropped by sliding-window admit
    retries: int = 0  # kernel-fault retries this request survived
    preemptions: int = 0  # times evicted back to the wait queue
    token_ms: list[float] = dataclasses.field(default_factory=list)  # per-token latency
    routed_to: str | None = None  # engine key a Router dispatched this to


@dataclasses.dataclass(frozen=True)
class RetuneEvent:
    """One firing of the continuous tuning loop (DESIGN.md §8).

    ``swapped`` distinguishes a drift check that triggered a retune + policy
    hot-swap from one that merely looked; ``epoch`` is the engine runtime's
    policy epoch after the swap (monotonic within that runtime).  Drift is checked
    per kernel family: ``families`` names the families whose tunings were
    refreshed by this event (empty when nothing triggered), and
    ``drift_score`` / ``unseen_fraction`` report the worst family observed.
    ``rejected`` names families whose retune candidate failed the canary and
    was never installed; ``rolled_back`` marks the auto-rollback event of a
    previously installed policy that regressed in service (DESIGN.md §11).
    ``source`` records who produced the swapped-in deployment: ``"drift"``
    for the engine's own loop, ``"control-plane"`` (or any caller-supplied
    label) for an externally pushed artifact adopted via
    :meth:`ServingEngine.adopt_deployment`.
    """

    step: int
    drift_score: float
    unseen_fraction: float
    swapped: bool
    triggered: bool  # False + high score means the min-events floor blocked it
    n_events: int
    n_configs: int
    epoch: int
    families: tuple[str, ...] = ()
    rejected: tuple[str, ...] = ()
    rolled_back: bool = False
    source: str = "drift"


@dataclasses.dataclass(frozen=True)
class EngineStatus:
    """What a drain/status snapshot finished (and what it didn't).

    ``exhausted`` means the step budget ran out with work left: ``in_flight``
    requests hold lanes mid-decode, ``queued`` never got one (or lost one and
    were never re-admitted).  Both carry ``done=False`` and a non-``"done"``
    per-request ``state`` — checking ``output`` alone cannot distinguish them
    once prefill has emitted tokens.  A request evicted back to the wait
    queue counts **once**: in live snapshots it moves from ``in_flight`` to
    ``preempted`` (state ``"preempted"``, excluded from ``queued``) and back
    on re-admission, so ``completed + in_flight + queued + preempted``
    partitions the epoch; a drain report instead uses ``preempted`` for how
    many requests were evicted at least once while it served.  ``health`` is
    the engine's serving-health
    state (``"healthy"`` / ``"degraded"``): degraded while dispatch incidents
    are arriving or configs sit in quarantine, healthy again once the window
    is clean.
    """

    completed: int
    in_flight: int
    queued: int
    steps: int
    exhausted: bool
    health: str = "healthy"
    preempted: int = 0
    # -- pool health (observability for the paged/prefix-reuse path) --------
    pool_utilization: float = 0.0  # used / total blocks
    pool_fragmentation: float = 0.0  # 1 - used token slots / allocated slots
    shared_blocks: int = 0  # blocks aliased by more than one lane
    prefix_hits: int = 0  # admissions that reused a cached prefix
    prefix_lookups: int = 0  # admissions that probed the prefix index

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_lookups if self.prefix_lookups else 0.0


@dataclasses.dataclass
class Ticket:
    """Streaming handle for one submitted request.

    ``tokens()`` yields generated tokens as they land, driving
    ``source.step()`` (the engine or router it was submitted to) whenever it
    runs out of buffered output; it stops at EOS/completion, starvation, or
    when the source reports no further progress is possible.
    """

    request: Request
    source: object  # anything with .step() -> bool

    @property
    def done(self) -> bool:
        return self.request.done

    def tokens(self):
        sent = 0
        while True:
            out = self.request.output
            while sent < len(out):
                yield out[sent]
                sent += 1
            if self.request.done or self.request.state == "starved":
                return
            if not self.source.step():
                return

    def result(self) -> list[int]:
        """Block (stepping the source) until done; return the full output."""
        for _ in self.tokens():
            pass
        return list(self.request.output)


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def _extend_ladder(buckets: tuple[int, ...], cache_len: int) -> tuple[int, ...]:
    """Extend the prefill bucket ladder geometrically, capped below cache_len.

    A prompt longer than the largest configured bucket used to truncate to
    that bucket even when the cache had room; doubling the ladder up to (but
    excluding) ``cache_len`` keeps long prompts intact while bounding the
    number of compiled prefill programs at O(log cache_len).  ``cache_len``
    itself is excluded so an admitted prompt always leaves decode room.
    """
    out = [int(b) for b in buckets]
    last = out[-1]
    while last * 2 < cache_len:
        last *= 2
        out.append(last)
    return tuple(out)


def _recent_ms(req: Request, k: int = 3) -> float | None:
    if not req.token_ms:
        return None
    xs = req.token_ms[-k:]
    return sum(xs) / len(xs)


class ServingEngine:
    def __init__(
        self,
        model,
        params,
        *,
        max_batch: int = 4,
        cache_len: int = 256,
        prefill_buckets: tuple[int, ...] = (32, 64, 128),
        extra_inputs: dict | None = None,
        bundle=None,
        device: str | None = None,
        runtime=None,
        block_size: int | None = None,
        n_blocks: int | None = None,
        scheduler: SchedulerConfig | None = None,
        prefill_chunk_tokens: int | None = None,
        prefix_sharing: bool = True,
        slo_aware: bool = True,
        slo_patience: int = 4,
        clock=None,
        on_prefill=None,
        on_decode=None,
        retune_interval: int | None = None,
        drift_threshold: float = DEFAULT_DRIFT_THRESHOLD,
        retune_min_events: int = DEFAULT_MIN_EVENTS,
        canary: bool = True,
        rollback_threshold: int = 3,
        swap_history: int = 4,
    ):
        from repro.core.runtime import current_runtime

        # The engine dispatches against ONE explicit KernelRuntime for its
        # whole lifetime: every prefill/decode trace runs inside
        # ``runtime.activate()``, so two engines with different runtimes (two
        # tenants, an A/B shadow pair) share no policy, shape-cache, or
        # selection-log state even on the same thread.  ``runtime=None``
        # adopts the caller's current runtime (the process default unless the
        # ctor runs inside an activation) — the legacy behavior.
        self.runtime = runtime if runtime is not None else current_runtime()
        # A serving host consumes the multi-device artifact directly: install
        # the Deployment resolved for this host (nearest tuned sibling when
        # untuned) before the first trace-time kernel selection runs.
        self.deployment = None
        self.device = device
        if bundle is not None:
            self.deployment = self.runtime.install_bundle(bundle, device)
            self.device = self.runtime.active_device()
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prefill_buckets = prefill_buckets
        # Geometric ladder extension (satellite fix): prompts longer than the
        # largest configured bucket bucket into doubled sizes up to cache_len
        # instead of truncating while cache room remains.
        self._ladder = _extend_ladder(tuple(prefill_buckets), cache_len)
        self.extra_inputs = extra_inputs or {}

        # Paged KV storage.  block_size=None keeps the dense layout (one
        # cache_len-sized block per lane) — byte-identical to the seed
        # engine's init_cache(max_batch, cache_len) pool.
        self.pool = KVPool(
            model, lanes=max_batch, cache_len=cache_len,
            block_size=block_size, n_blocks=n_blocks,
        )
        # Streaming admission regime (chunked prefill + prefix sharing):
        # requires a chunk-capable model, no extra prefill inputs, and either
        # an explicit chunk budget or a paged pool with sharing enabled.
        # Everything else keeps the legacy (byte-identical) admission path.
        chunk_capable = (
            hasattr(model, "prefill_chunk")
            and getattr(model, "supports_chunked_prefill", lambda: True)()
            and not self.extra_inputs
        )
        paged = self.pool.block_size < self.cache_len
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self._sharing = bool(prefix_sharing) and paged and chunk_capable
        self._streaming = chunk_capable and (
            prefill_chunk_tokens is not None or self._sharing
        )
        if (
            self._streaming
            and prefill_chunk_tokens is not None
            and (scheduler is None or scheduler.prefill_token_budget is None)
        ):
            # The chunk cap doubles as the default per-step prefill budget.
            scheduler = dataclasses.replace(
                scheduler or SchedulerConfig(),
                prefill_token_budget=int(prefill_chunk_tokens),
            )
        self.scheduler = Scheduler(scheduler)
        self.positions = np.zeros(max_batch, dtype=np.int32)  # next position to write
        self.slots: list[Request | None] = [None] * max_batch
        self.steps = 0
        self._uid = itertools.count()
        self._epoch_requests: list[Request] = []

        # Compiled decode programs, one per width bucket (powers of two up
        # to max_batch): a lone straggler decodes at width 1, a full house
        # at max_batch, without retracing in between.
        buckets, w = [], 1
        while w < max_batch:
            buckets.append(w)
            w *= 2
        buckets.append(max_batch)
        self._width_buckets = tuple(buckets)
        self._decode_cache: dict[int, object] = {}
        self._prefill_cache = {}
        self._chunk_cache: dict[int, object] = {}  # chunk width -> jitted program

        # -- SLO-aware selection ---------------------------------------------
        self.slo_aware = slo_aware
        self.slo_patience = max(int(slo_patience), 1)
        self._prefix_reused_tokens = 0  # prefill tokens skipped via aliasing
        self.slo_events: list[tuple[int, str, float | None]] = []
        self._slo_mode = False
        self._slo_cap: int | None = None
        self._slo_ok = 0
        self._step_ms: deque = deque(maxlen=8)  # recent per-step wall times
        # Injectable clock + hooks let the serving benchmark drive a
        # deterministic simulated timeline; production uses the wall clock.
        self._clock = clock if clock is not None else time.perf_counter
        self.on_prefill = on_prefill
        self.on_decode = on_decode

        # -- continuous tuning loop (DESIGN.md §8) ---------------------------
        self.retune_interval = retune_interval
        self.drift_threshold = drift_threshold
        self.retune_min_events = retune_min_events
        self.retune_events: list[RetuneEvent] = []
        self._last_retune_check = 0
        # -- failure containment (DESIGN.md §11) -----------------------------
        self.canary = canary
        self.rollback_threshold = max(int(rollback_threshold), 1)
        self.health = "healthy"
        self.health_events: list[tuple[int, str]] = []  # (step, new state)
        self._incidents_seen = self.runtime.incident_count()
        # Previous deployments, newest last; maybe_retune pushes the incumbent
        # before installing a candidate, the rollback watchdog pops it.
        self._swap_history: deque = deque(maxlen=max(int(swap_history), 1))
        self._incidents_at_swap: int | None = None
        # Externally offered deployment (control-plane push): staged from any
        # thread via offer_deployment, adopted at the next step boundary so
        # the swap never lands mid-decode.
        self._offer_lock = threading.Lock()
        self._offered: tuple[object, str] | None = None
        if retune_interval is not None:
            # Telemetry source: the runtime's selection log (cache hits
            # included, so the histogram reflects real traffic frequencies).
            self.runtime.set_selection_logging(True)

    @property
    def cache(self):
        """Dense read view of all lanes (seed-engine layout), for inspection."""
        return self.pool.gather(range(self.max_batch))

    def dispatch_stats(self) -> dict:
        """Kernel-selection shape-cache counters (convenience passthrough).

        Each prefill bucket and decode width bucket retrace the model, so
        repeated admissions re-run trace-time kernel selection; the runtime's
        shape cache (DESIGN.md §6) turns those repeats into dict hits.  Note
        the counters are per *thread within the runtime*: call from the
        thread that drives this engine, and expect other engines sharing the
        same runtime on this thread to contribute to the same numbers.
        """
        return self.runtime.shape_cache_stats()

    # -- lane admission -------------------------------------------------------
    def _free_lane(self) -> int | None:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            fn = lambda params, batch: self.model.prefill(params, batch, self.cache_len)
            self._prefill_cache[plen] = jax.jit(fn)
        return self._prefill_cache[plen]

    def _seq_tokens(self, req: Request) -> np.ndarray:
        """Tokens a (re-)admission must prefill: prompt plus anything already
        generated (a preempted request resumes by re-prefilling both — the
        last position's argmax is then exactly the next token it needed)."""
        prompt = np.asarray(req.prompt, dtype=np.int32)
        if req.output:
            return np.concatenate([prompt, np.asarray(req.output, dtype=np.int32)])
        return prompt

    def _fits(self, req: Request) -> bool:
        if self._streaming:
            kept = min(len(self._seq_tokens(req)), self._ladder[-1])
            return self.pool.can_fit(kept)
        plen = _bucket(len(self._seq_tokens(req)), self._ladder)
        return self.pool.can_fit(plen)

    def _admit(self, req: Request, slot: int) -> bool:
        """Admit ``req`` into ``slot``; True if a first token was emitted
        (legacy monolithic prefill), False if the lane entered the
        ``"prefilling"`` state (streaming regime — chunks run via
        :meth:`_advance_prefills` under the scheduler's budget)."""
        if self._streaming:
            self._admit_streaming(req, slot)
            return False
        plen = _bucket(len(self._seq_tokens(req)), self._ladder)
        tail = self._seq_tokens(req)
        if len(tail) > plen:
            # Sliding-window truncation: a prompt longer than the largest
            # prefill bucket keeps its most recent plen tokens (causal decode
            # conditions on the suffix) instead of raising on the left-pad.
            req.truncated_tokens = len(tail) - plen
            tail = tail[-plen:]
        prompt = np.zeros(plen, dtype=np.int32)
        if len(tail):
            prompt[-len(tail) :] = tail  # left-pad (causal end-aligned)
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        for k, v in self.extra_inputs.items():
            batch[k] = _batch_extra(k, v)
        with self.runtime.activate():  # trace-time selections hit OUR runtime
            logits, cache1 = self._run_program(
                "engine.prefill",
                lambda: self._prefill_fn(plen)(self.params, batch),
                retrace=lambda: self._prefill_cache.pop(plen, None),
                request=req,
            )
        # Back the lane with blocks and scatter the single-sequence prefill
        # cache into them (the lane's previous tenant, if any, is reclaimed).
        self.pool.release(slot)
        if not self.pool.ensure(slot, plen):
            raise RuntimeError(
                f"admitted request {req.uid} with no blocks for plen={plen}"
            )
        self.pool.admit(slot, cache1)
        self.pool.note_tokens(slot, min(len(tail), plen))
        if self.on_prefill is not None:
            self.on_prefill(plen)
        first = int(jnp.argmax(logits[0, -1]))
        req.output.append(first)
        req.state = "active"
        self.slots[slot] = req
        self.positions[slot] = plen
        return True

    # -- streaming admission (chunked prefill + prefix sharing) ---------------
    def _chunk_cap(self) -> int:
        """Largest chunk width one prefill program may cover right now.

        The base cap is the biggest ladder value at or under
        ``prefill_chunk_tokens`` (whole ladder if unset); SLO mode shrinks it
        one ladder rung so deadline pressure reduces the unit of prefill work
        interleaved between decode rounds.
        """
        limit = self.prefill_chunk_tokens
        cap = self._ladder[0]
        for b in self._ladder:
            if limit is None or b <= limit:
                cap = max(cap, b)
        if self._slo_mode:
            below = [b for b in self._ladder if b < cap]
            cap = max(below) if below else self._ladder[0]
        return cap

    def _chunk_fn(self, width: int):
        if width not in self._chunk_cache:
            # jit a fresh closure, not the bound method: jax's trace cache
            # keys on the callable, and equal bound methods would share one
            # trace across engines (and across pop+re-jit after a hot-swap),
            # skipping the trace-time kernel selection that must run under
            # THIS engine's runtime and policy.
            chunk = self.model.prefill_chunk
            self._chunk_cache[width] = jax.jit(
                lambda params, cache, tokens, start, last: chunk(
                    params, cache, tokens, start, last
                ),
                donate_argnums=(1,),
            )
        return self._chunk_cache[width]

    def _admit_streaming(self, req: Request, slot: int) -> None:
        """Left-aligned admission: alias any cached prefix, allocate the rest,
        and queue the sequence for budgeted chunked prefill.

        No model program runs here — chunks run in
        :meth:`_advance_prefills` (the final chunk emits the first token),
        so one step's prefill work is bounded by the scheduler's token
        budget no matter how many admissions land.
        """
        seq = self._seq_tokens(req)
        keep = self._ladder[-1]
        if len(seq) > keep:
            # Sliding-window truncation, as in the legacy regime: keep the
            # most recent tokens (causal decode conditions on the suffix).
            req.truncated_tokens = len(seq) - keep
            seq = seq[-keep:]
        body = seq[:-1]
        shared_tokens = 0
        if self._sharing:
            # Atomic match-then-release: the lane's outgoing tenant may itself
            # own the matched blocks (same system prompt re-admitted into its
            # old lane), so the pool reserves them before reclaiming.
            shared_tokens = self.pool.admit_prefix(slot, body)
            self._prefix_reused_tokens += shared_tokens
        else:
            self.pool.release(slot)  # reclaim the lane's previous tenant
        # Lane-kind leaves (recurrent state, rings) must start from zeros:
        # nothing below ever rewrites them wholesale the way pool.admit does.
        self.pool.reset_lane_state(slot)
        if not self.pool.ensure(slot, len(seq)):
            raise RuntimeError(
                f"admitted request {req.uid} with no blocks for {len(seq)} tokens"
            )
        self.pool.note_tokens(slot, len(seq))
        # Chunks cover the FULL sequence: the final chunk's logits at its
        # last real row predict the first output token (legacy parity — no
        # separate first-token program).  Sharing still matches/registers on
        # the body only, so the decode-frontier block stays private (COW).
        req._chunk_tokens = np.asarray(seq, dtype=np.int32)
        req._chunk_pos = shared_tokens
        req._first_logits = None
        req.state = "prefilling"
        self.slots[slot] = req
        self.positions[slot] = len(seq) - 1  # overwritten by the final chunk

    def _run_chunk(self, lane: int, req: Request, width: int) -> None:
        """One chunk-append prefill program over ``[chunk_pos, chunk_pos+width)``."""
        toks = req._chunk_tokens
        s0 = req._chunk_pos
        chunk = np.zeros(width, dtype=np.int32)
        real = toks[s0 : s0 + width]
        chunk[: len(real)] = real
        with self.runtime.activate():
            logits, cache = self._run_program(
                "engine.prefill",
                lambda: self._chunk_fn(width)(
                    self.params,
                    self.pool.gather([lane]),  # re-gathered on retry: donation-safe
                    jnp.asarray(chunk[None, :]),
                    jnp.int32(s0),
                    jnp.int32(len(real) - 1),
                ),
                retrace=lambda: self._chunk_cache.pop(width, None),
                request=req,
            )
        self.pool.scatter([lane], cache)
        if self.on_prefill is not None:
            self.on_prefill(width)
        req._chunk_pos = min(s0 + width, len(toks))
        if req._chunk_pos >= len(toks):
            # Final chunk: its last real row predicts the first output token.
            req._first_logits = logits
        if self._sharing:
            # Index the blocks this chunk completed right away, so siblings
            # admitted while a long prompt is still prefilling can alias the
            # finished span instead of waiting for activation.  Only the
            # body (all but the last token) is ever indexed — the block
            # holding the decode frontier stays private (COW rule).
            self.pool.register_prefix(
                lane, toks[: min(req._chunk_pos, len(toks) - 1)]
            )

    def _activate_lane(self, lane: int, req: Request) -> None:
        """Sequence fully cached: emit the first token from the final
        chunk's logits and join the batched decode.  No program runs here —
        activation costs nothing beyond the chunks themselves, matching the
        legacy prefill's first-token-from-last-position-logits economics."""
        seq = req._chunk_tokens
        if self._sharing:
            # Index the lane's fully-covered body blocks for future reuse.
            # The block holding the decode frontier is never indexed (COW
            # rule), so shared content is immutable by construction.
            self.pool.register_prefix(lane, seq[:-1])
        first = int(jnp.argmax(req._first_logits[0, -1]))
        req._first_logits = None  # free the device buffer
        req.output.append(first)
        req.state = "active"
        self.positions[lane] = len(seq)
        self.pool.note_tokens(lane, len(seq))

    def _advance_prefills(self) -> tuple[list[Request], list[Request]]:
        """Run chunk programs for ``"prefilling"`` lanes within this step's
        prefill-token budget; activate lanes whose body is done.

        Returns ``(progressed, activated)``: requests that did chunk work and
        requests whose final chunk landed (first token emitted, lane joins
        the decode batch).  At least one chunk runs per
        step when any lane is prefilling (the budget is a soft cap, never a
        stall), so streaming callers always observe progress.
        """
        progressed: list[Request] = []
        activated: list[Request] = []
        for lane, req in enumerate(self.slots):
            if req is None or req.state != "prefilling":
                continue
            did = False
            while req._chunk_pos < len(req._chunk_tokens):
                remaining = len(req._chunk_tokens) - req._chunk_pos
                width = min(self._chunk_cap(), _bucket(remaining, self._ladder))
                left = self.scheduler.prefill_budget_left()
                if width > left and self.scheduler._prefill_spent > 0:
                    break  # budget spent; resume next step
                self._run_chunk(lane, req, width)
                self.scheduler.charge_prefill(width)
                did = True
            if did:
                progressed.append(req)
            if req._chunk_pos >= len(req._chunk_tokens):
                self._activate_lane(lane, req)
                activated.append(req)
        return progressed, activated

    def _preempt(self, lane: int) -> Request:
        """Evict the lane's resident back to the wait queue, reclaiming its
        blocks; it keeps its output and re-admits via prompt+output prefill."""
        req = self.slots[lane]
        self.slots[lane] = None
        self.pool.release(lane)
        req.state = "preempted"
        req.preemptions += 1
        self.scheduler.submit(req, step=self.steps)
        return req

    def _pick_victim(self, running: list) -> Request | None:
        """Victim selection that prefers lanes holding no shared blocks.

        Evicting a refcount>1 holder never corrupts a sibling (release only
        decrements), but it throws away blocks other lanes ride on — so
        shared-prefix holders are passed to the scheduler as ``protect``ed
        and only become candidates when no unprotected victim exists.
        """
        protect = [
            r
            for lane, r in enumerate(self.slots)
            if r is not None and self.pool.lane_holds_shared(lane)
        ]
        victim = self.scheduler.pick_victim(running, self.steps, protect=protect)
        if victim is None and protect:
            victim = self.scheduler.pick_victim(running, self.steps)
        return victim

    def _preempt_for_admission(self) -> Request | None:
        """Admission-time preemption: a waiter that outranks the weakest
        active resident by the configured gap may take its blocks."""
        best = self.scheduler.peek_best(self.steps)
        if best is None:
            return None
        running = [r for r in self.slots if r is not None]
        victim = self._pick_victim(running)
        if victim is None:
            return None
        gap = self.scheduler.config.preempt_priority_gap
        if self.scheduler.effective_priority(best, self.steps) < victim.priority + gap:
            return None
        self._preempt(self.slots.index(victim))
        if self._fits(best):
            self.scheduler.remove(best)
            return best
        return None

    def _grow_active(self) -> None:
        """Every active lane must own the block its next token writes into;
        under pool pressure the scheduler's victim (lowest priority, most
        emitted tokens) is preempted until the allocation fits."""
        for lane, req in enumerate(self.slots):
            if req is None or req.state == "prefilling":
                continue  # prefilling lanes allocated fully at admission
            need = int(self.positions[lane]) + 1
            while not self.pool.ensure(lane, need):
                running = [r for r in self.slots if r is not None]
                victim = self._pick_victim(running)
                if victim is None:
                    break
                vlane = self.slots.index(victim)
                self._preempt(vlane)
                if vlane == lane:
                    break  # preempted ourselves; the lane is empty now
            else:
                self.pool.note_tokens(lane, need)

    # -- decode ---------------------------------------------------------------
    def _width(self, n_active: int) -> int:
        for b in self._width_buckets:
            if n_active <= b:
                return b
        return self._width_buckets[-1]

    def _decode_fn(self, width: int):
        if width not in self._decode_cache:
            # Fresh closure per jit — see _chunk_fn for why the bound method
            # must not be jitted directly.
            step = self.model.decode_step
            self._decode_cache[width] = jax.jit(
                lambda params, cache, tokens, pos: step(params, cache, tokens, pos),
                donate_argnums=(1,),
            )
        return self._decode_cache[width]

    def _decode_active(self) -> list[Request]:
        """One batched decode over the compacted active lanes, at the
        smallest compiled width bucket that fits; returns the requests that
        received a token."""
        active = [
            i for i, r in enumerate(self.slots)
            if r is not None and r.state == "active"
        ]
        if not active:
            return []
        width = self._width(len(active))
        # Pad the batch to the bucket with idle lanes (their block tables are
        # empty or retired, so their writes land in scratch / reclaimed rows
        # — same as the seed engine decoding its idle slots; a retired lane's
        # *registered* prefix blocks are safe because the stale write position
        # is at/beyond the old decode frontier, outside every indexed block).
        # When mid-prefill lanes leave too few idle lanes, they serve as
        # padding too: the pad write lands at the last prompt position (at
        # or past every finished chunk), which the lane's final chunk
        # overwrites with the real last-token k/v before activation.
        idle = [i for i, r in enumerate(self.slots) if r is None]
        idle += [
            i for i, r in enumerate(self.slots)
            if r is not None and r.state == "prefilling"
        ]
        sel = active + idle[: width - len(active)]
        tokens = np.zeros((width, 1), dtype=np.int32)
        for row, lane in enumerate(active):
            tokens[row, 0] = self.slots[lane].output[-1]
        pos = self.positions[sel]
        with self.runtime.activate():  # trace-time selections hit OUR runtime
            logits, new_cache = self._run_program(
                "engine.decode",
                lambda: self._decode_fn(width)(
                    self.params,
                    self.pool.gather(sel),  # re-gathered on retry: donation-safe
                    jnp.asarray(tokens),
                    jnp.asarray(pos),
                ),
                retrace=lambda: self._decode_cache.pop(width, None),
            )
        self.pool.scatter(sel, new_cache)
        if self.on_decode is not None:
            self.on_decode(width)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        got = []
        for row, lane in enumerate(active):
            r = self.slots[lane]
            self.positions[lane] += 1
            tok = int(nxt[row])
            r.output.append(tok)
            got.append(r)
            if (
                len(r.output) >= r.max_new_tokens
                or (r.eos_id is not None and tok == r.eos_id)
                or self.positions[lane] >= self.cache_len - 1
            ):
                r.done = True
                r.state = "done"
                self.slots[lane] = None
                # Lazy reclaim: blocks stay readable (pool.retire) until a
                # later admission actually needs them.
                self.pool.retire(lane)
        self.steps += 1
        return got

    # -- SLO pressure (objective-aware selection) -----------------------------
    def _admit_blocked(self) -> bool:
        if not self._slo_mode or self._slo_cap is None:
            return False
        return sum(s is not None for s in self.slots) >= self._slo_cap

    def _enter_slo(self, target: float) -> None:
        from repro.core.runtime import Objective

        self._slo_mode = True
        self._slo_ok = 0
        # Cap admissions below the current width bucket so the batch shrinks
        # as residents finish instead of refilling.
        cur = self._width(sum(s is not None for s in self.slots))
        cap = 1
        for b in self._width_buckets:
            if b < cur:
                cap = b
        self._slo_cap = cap
        self.slo_events.append((self.steps, "enter", target))
        # SLO mode also shrinks the prefill chunk cap one ladder rung (the
        # _chunk_cap() consults _slo_mode, already set above); publishing it
        # on the Objective lets selection policies prefer configs tuned at
        # the chunk's GEMM shapes.
        self.runtime.set_objective(Objective(
            latency_target_ms=float(target),
            prefill_chunk_tokens=self._chunk_cap() if self._streaming else None,
        ))
        # Invalidate compiled programs: the next trace re-runs kernel
        # selection under the objective (select_for_objective).
        self._prefill_cache.clear()
        self._decode_cache.clear()
        self._chunk_cache.clear()

    def _exit_slo(self) -> None:
        self._slo_mode = False
        self._slo_cap = None
        self._slo_ok = 0
        self.slo_events.append((self.steps, "exit", None))
        self.runtime.set_objective(None)
        self._prefill_cache.clear()
        self._decode_cache.clear()
        self._chunk_cache.clear()

    def _update_slo(self) -> None:
        """Hysteresis loop around the latency objective.

        Enter SLO mode when the engine's recent per-step time (or a targeted
        resident's own recent per-token latency) overruns the target of any
        latency-targeted request — *resident or queued*: a queued target
        about to be admitted into an over-budget batch would blow its SLO on
        its very first token, so the constraint lands before admission, not
        after the damage.  Exit when no targeted work remains anywhere, or
        after ``slo_patience`` consecutive comfortable (<0.7x target) steps.
        """
        if not self.slo_aware:
            return
        resident = [
            r for r in self.slots if r is not None and r.latency_target_ms is not None
        ]
        queued = [
            r for r in self.scheduler.waiting() if r.latency_target_ms is not None
        ]
        if not self._slo_mode:
            recent = list(self._step_ms)[-3:]
            step_ms = sum(recent) / len(recent) if recent else None
            at_risk = [
                r for r in resident
                if _recent_ms(r) is not None and _recent_ms(r) > r.latency_target_ms
            ]
            if step_ms is not None:
                at_risk += [
                    r for r in resident + queued if r.latency_target_ms < step_ms
                ]
            if at_risk:
                self._enter_slo(min(r.latency_target_ms for r in at_risk))
            return
        if not resident and not queued:
            self._exit_slo()
            return
        # A targeted request still waiting admission holds the mode: dropping
        # the cap now would re-widen the batch right before it lands.
        calm = bool(resident) and all(
            _recent_ms(r) is None or _recent_ms(r) < 0.7 * r.latency_target_ms
            for r in resident
        )
        if calm:
            self._slo_ok += 1
            if self._slo_ok >= self.slo_patience:
                self._exit_slo()
        else:
            self._slo_ok = 0

    # -- failure containment (DESIGN.md §11) -----------------------------------
    def _rejit_decode(self) -> None:
        self._decode_cache.clear()
        self._chunk_cache.clear()

    def _run_program(self, site: str, fn, *, retrace, request: Request | None = None):
        """Run one compiled program with per-request retry-on-kernel-fault.

        The engine-level safety net above the ops-layer guard: an injected
        fault at ``site`` (fired *before* execution, so donated buffers are
        never half-consumed) or a real failure escaping the compiled program
        gets one retry after ``retrace()`` drops the compiled artifact —
        the re-trace re-runs kernel selection, picking up any quarantine the
        ops guard installed meanwhile.  A second failure propagates: zero
        silent drops, but also no infinite retry loop.
        """
        from repro.core.faults import GUARDED_EXCEPTIONS, incident

        rt = self.runtime
        plan = rt.fault_plan
        try:
            if plan is not None:
                plan.raise_if(site)
            return fn()
        except GUARDED_EXCEPTIONS as e:
            rt.record_incident(incident(
                site, "engine", None, e, "retry", device=rt.active_device()))
            if request is not None:
                request.retries += 1
            retrace()
            return fn()

    def _update_health(self) -> str:
        """Advance the healthy/degraded state machine; record transitions.

        Degraded while new incidents arrived since the last check or any
        config sits in quarantine; healthy once a full check window passes
        clean with an empty quarantine table.
        """
        rt = self.runtime
        count = rt.incident_count()
        fresh = count > self._incidents_seen
        self._incidents_seen = count
        state = "degraded" if (fresh or rt.quarantined()) else "healthy"
        if state != self.health:
            self.health = state
            self.health_events.append((self.steps, state))
        return state

    def maybe_rollback(self) -> RetuneEvent | None:
        """Auto-rollback watchdog for an installed-but-regressing policy.

        If :data:`rollback_threshold` incidents accumulate after a hot-swap,
        the most recent pre-swap deployment is reinstalled from the bounded
        swap history (one rollback per swap: the counter re-arms only on the
        next swap).  Compiled programs are invalidated the same way a swap
        does; in-flight requests keep their lanes.
        """
        from repro.core.faults import incident

        rt = self.runtime
        if self._incidents_at_swap is None or not self._swap_history:
            return None
        if rt.incident_count() - self._incidents_at_swap < self.rollback_threshold:
            return None
        prev = self._swap_history.pop()
        if self.device is not None and rt.active_device() == self.device:
            rt.install_for_device(self.device, prev)
        else:
            rt.install(prev)
        self.deployment = prev
        self._incidents_at_swap = None  # one rollback per swap
        rt.record_incident(incident(
            "engine.retune", "engine", None,
            f"{self.rollback_threshold}+ incidents since hot-swap",
            "rollback", device=rt.active_device()))
        rt.clear_selection_log()
        self._prefill_cache.clear()
        self._rejit_decode()
        ev = RetuneEvent(self.steps, 0.0, 0.0, True, True, 0,
                         len(prev.configs) if hasattr(prev, "configs") else 0,
                         rt.policy_epoch(), rolled_back=True)
        self.retune_events.append(ev)
        return ev

    # -- continuous tuning -----------------------------------------------------
    def maybe_retune(self, *, force: bool = False, online=None) -> RetuneEvent | None:
        """Telemetry -> drift check -> incremental retune -> policy hot-swap.

        Called between decode steps when ``retune_interval`` is set, or
        directly from an operator's background hook (the runtime's policy
        registry is lock+epoch protected, so a swap from another thread
        reaches the serving thread atomically — and only threads dispatching
        against *this engine's runtime*; other tenants' runtimes never see
        it).  Returns the :class:`RetuneEvent` when a
        drift check actually ran (``swapped=False`` if it didn't trigger),
        ``None`` when there is no deployment or not enough telemetry yet.
        ``online`` optionally names a hybrid-mode ``OnlinePolicy``: its arm
        measurements ride into the snapshot, and after a swap it adopts the
        retuned deployment as its prior (``set_prior``).

        The hot swap is zero-downtime: KV blocks, lanes, and in-flight
        requests are untouched; compiled programs for *already-traced* shapes
        keep their old kernels until natural retrace, while the cleared
        prefill/decode jit wrappers make every subsequent trace consult the
        new policy.
        """
        from repro.core.dispatch import Deployment
        from repro.core.faults import FaultError, incident
        from repro.core.retune import (
            canary_deployment,
            detect_drift_all,
            incremental_retune,
        )

        rt = self.runtime
        dep = self.deployment
        if dep is None:
            pol = rt.policy()
            dep = pol if isinstance(pol, Deployment) else None
        if dep is None:
            return None
        snap = rt.telemetry(online=online)
        if snap.n_events == 0:
            return None
        # Drift is detected per (device, family, shape): every family with
        # live traffic gets its own report against its own provenance, so an
        # ssm-only traffic shift retunes the ssm family without touching the
        # (undrifted) matmul artifact.
        reports = detect_drift_all(
            snap, dep, threshold=self.drift_threshold, min_events=self.retune_min_events
        )
        worst = max(reports.values(), key=lambda r: r.score)
        to_retune = [f for f, r in reports.items() if r.triggered]
        if force and not to_retune:
            to_retune = list(reports)
        if not to_retune:
            # n_events is the worst family's own event count: the "below
            # event floor" verdict must be judged against the per-family
            # floor drift detection actually applied, not the cross-family
            # aggregate.
            ev = RetuneEvent(self.steps, worst.score, worst.unseen_fraction,
                             False, any(r.triggered for r in reports.values()),
                             worst.n_events, len(dep.configs), rt.policy_epoch())
            self.retune_events.append(ev)
            return ev
        # Canary-gated adoption: each family's candidate must pass the
        # holdout validation (selection quality + numeric agreement with
        # ref) before it is allowed anywhere near install_for_device.  A
        # rejected candidate leaves the incumbent family tuning in place.
        new_dep = dep
        adopted: list[str] = []
        rejected: list[str] = []
        for fam in to_retune:
            try:
                if rt.fault_plan is not None:
                    rt.fault_plan.raise_if("retune.candidate", fam)
                cand = incremental_retune(
                    new_dep, snap, family=fam, report=reports[fam],
                    threshold=self.drift_threshold, min_events=self.retune_min_events,
                ).deployment
            except (FaultError, ValueError) as e:
                rejected.append(fam)
                rt.record_incident(incident(
                    "retune.candidate", fam, None, e, "candidate_failed",
                    device=rt.active_device()))
                continue
            if self.canary:
                verdict = canary_deployment(new_dep, cand, snap, family=fam, runtime=rt)
                if not verdict.ok:
                    rejected.append(fam)
                    rt.record_incident(incident(
                        f"canary.{fam}", fam, None, verdict.reason,
                        "candidate_rejected", device=rt.active_device()))
                    continue
            new_dep = cand
            adopted.append(fam)
        if not adopted:
            ev = RetuneEvent(self.steps, worst.score, worst.unseen_fraction,
                             False, any(r.triggered for r in reports.values()),
                             worst.n_events, len(dep.configs), rt.policy_epoch(),
                             rejected=tuple(rejected))
            self.retune_events.append(ev)
            return ev
        to_retune = adopted
        # Keep the incumbent in the bounded swap history and re-arm the
        # rollback watchdog: incidents from here on count against this swap.
        self._swap_history.append(dep)
        self._incidents_at_swap = rt.incident_count()
        if self.device is not None and rt.active_device() == self.device:
            rt.install_for_device(self.device, new_dep)  # registry hot-swap
        else:
            rt.install(new_dep)
        if online is not None and hasattr(online, "set_prior"):
            # A hybrid-mode OnlinePolicy must adopt the retuned deployment as
            # its prior (and drop its prior-derived attention cache with it).
            online.set_prior(new_dep)
        self.deployment = new_dep
        rt.clear_selection_log()  # fresh telemetry window for the new policy
        # Invalidate this engine's compiled programs so the next admission /
        # decode trace re-runs kernel selection under the swapped-in policy.
        # Engine state (block pool, lanes, positions) survives: in-flight
        # requests continue without a drop, paying only a retrace.
        self._prefill_cache.clear()
        self._rejit_decode()
        worst_retuned = max((reports[f] for f in to_retune), key=lambda r: r.score)
        ev = RetuneEvent(self.steps, worst_retuned.score, worst_retuned.unseen_fraction,
                         True, any(r.triggered for r in reports.values()),
                         worst_retuned.n_events, len(new_dep.configs), rt.policy_epoch(),
                         tuple(to_retune), rejected=tuple(rejected))
        self.retune_events.append(ev)
        return ev

    # -- control-plane adoption (DESIGN.md §14) --------------------------------
    def offer_deployment(self, candidate, *, source: str = "control-plane") -> None:
        """Stage an externally produced deployment for adoption.

        Thread-safe: a :class:`repro.control.PolicySubscriber` (or any other
        background delivery) calls this from its own thread; the engine
        adopts the candidate at the top of its next :meth:`step`, so the
        hot-swap always lands on a step boundary, never mid-decode.  A newer
        offer replaces an unclaimed older one (last writer wins — the
        control plane's latest artifact is the one that matters).
        """
        with self._offer_lock:
            self._offered = (candidate, source)

    def _take_offer(self):
        with self._offer_lock:
            offer, self._offered = self._offered, None
        return offer

    def adopt_deployment(
        self, candidate, *, source: str = "external"
    ) -> RetuneEvent:
        """Canary-gate and hot-swap an externally produced deployment.

        The adoption path for artifacts this engine did *not* tune itself —
        a control-plane retune pushed over the policy long-poll, an operator
        hand-off, an A/B promotion.  Every family with live traffic in the
        current telemetry window is canaried (selection quality + numeric
        ref agreement, exactly the gate :meth:`maybe_retune` applies to its
        own candidates); one failing family rejects the whole artifact — an
        external bundle swaps atomically or not at all.  On adoption the
        incumbent joins the bounded swap history and the rollback watchdog
        re-arms, so a pushed artifact that regresses in service rolls back
        the same way a local retune would.  In-flight requests are untouched
        (compiled programs re-trace lazily under the new policy).
        """
        from repro.core.faults import incident
        from repro.core.retune import canary_deployment

        rt = self.runtime
        incumbent = self.deployment
        snap = rt.telemetry()
        gated: list[str] = []
        rejected: list[str] = []
        if self.canary and incumbent is not None:
            for fam in snap.families():
                verdict = canary_deployment(
                    incumbent, candidate, snap, family=fam, runtime=rt
                )
                gated.append(fam)
                if not verdict.ok:
                    rejected.append(fam)
                    rt.record_incident(incident(
                        f"canary.{fam}", fam, None, verdict.reason,
                        "candidate_rejected", device=rt.active_device()))
        if rejected:
            ev = RetuneEvent(self.steps, 0.0, 0.0, False, False,
                             snap.n_events, len(incumbent.configs),
                             rt.policy_epoch(), rejected=tuple(rejected),
                             source=source)
            self.retune_events.append(ev)
            return ev
        if incumbent is not None:
            self._swap_history.append(incumbent)
            self._incidents_at_swap = rt.incident_count()
        if self.device is not None and rt.active_device() == self.device:
            rt.install_for_device(self.device, candidate)  # registry hot-swap
        else:
            rt.install(candidate)
        self.deployment = candidate
        rt.clear_selection_log()  # fresh telemetry window for the new policy
        self._prefill_cache.clear()
        self._rejit_decode()
        ev = RetuneEvent(self.steps, 0.0, 0.0, True, True, snap.n_events,
                         len(candidate.configs) if hasattr(candidate, "configs") else 0,
                         rt.policy_epoch(), families=tuple(gated), source=source)
        self.retune_events.append(ev)
        return ev

    # -- public ---------------------------------------------------------------
    def submit(
        self,
        prompt,
        *,
        max_new_tokens: int = 16,
        eos_id: int | None = None,
        priority: int = 0,
        latency_target_ms: float | None = None,
        uid: int | None = None,
    ) -> Ticket:
        """Enqueue one prompt; returns a streaming :class:`Ticket`."""
        req = Request(
            uid=uid if uid is not None else next(self._uid),
            prompt=np.asarray(prompt, dtype=np.int32),
            max_new_tokens=max_new_tokens,
            eos_id=eos_id,
            priority=priority,
            latency_target_ms=latency_target_ms,
        )
        return self.submit_request(req)

    def submit_request(self, req: Request) -> Ticket:
        """Enqueue a pre-built :class:`Request` (advanced / legacy path)."""
        self.scheduler.submit(req, step=self.steps)
        self._epoch_requests.append(req)
        return Ticket(req, self)

    def pending(self) -> bool:
        """Work remains: requests waiting or lanes mid-decode."""
        return bool(len(self.scheduler) or any(s is not None for s in self.slots))

    def step(self) -> bool:
        """One scheduling round (admit -> grow/preempt -> decode -> watchdogs).

        Returns False when no progress was possible (nothing admitted and no
        active lane decoded) — callers looping on ``step()`` should stop.
        """
        t0 = self._clock()
        # A control-plane offer adopts on the step boundary: before any
        # admission or decode of this round, so the whole step runs under one
        # policy and no in-flight request straddles the swap mid-trace.
        offer = self._take_offer()
        if offer is not None:
            self.adopt_deployment(offer[0], source=offer[1])
        # SLO check runs BEFORE admission: it sees the same step-time history
        # it would at the end of the previous step, but entering now means
        # this step's admissions and traces already run under the cap and the
        # latency objective (no full-width burst right as a target lands).
        self._update_slo()
        self.scheduler.begin_step()  # fresh prefill-token budget
        emitted: list[Request] = []
        preempted_once = False
        while len(self.scheduler):
            lane = self._free_lane()
            if lane is None or self._admit_blocked():
                break
            req = self.scheduler.pop_next(self.steps, fits=self._fits)
            if req is None:
                if preempted_once:
                    break
                preempted_once = True
                req = self._preempt_for_admission()
                if req is None:
                    break
                lane = self._free_lane()
            if self._admit(req, lane):
                emitted.append(req)  # legacy prefill emitted the first token
        # Budgeted chunk work for prefilling lanes; a lane whose final chunk
        # landed this step emits its first token here and joins this step's
        # batched decode below (streaming parity with legacy: a small prompt
        # admitted this step still answers this step).
        progressed, activated = self._advance_prefills()
        emitted.extend(activated)
        self._grow_active()
        decoded = self._decode_active()
        emitted.extend(decoded)
        self._update_health()
        self.maybe_rollback()
        if (
            self.retune_interval is not None
            and self.steps - self._last_retune_check >= self.retune_interval
        ):
            self._last_retune_check = self.steps
            self.maybe_retune()
        dt_ms = (self._clock() - t0) * 1e3
        self._step_ms.append(dt_ms)
        for r in emitted:
            r.token_ms.append(dt_ms)
        # Chunk work without a token is still progress: streaming callers
        # (Ticket.tokens) must keep stepping while a long prompt prefills.
        return bool(emitted or progressed)

    def status(self) -> EngineStatus:
        """Live snapshot over this serving epoch (since the last drain).

        Every outstanding request is counted exactly once:
        ``completed + in_flight + queued + preempted`` partitions the epoch.
        Evicted waiters show up in ``preempted`` (state ``"preempted"``), not
        in ``queued``; once re-admitted they move back to ``in_flight``.
        """
        reqs = self._epoch_requests
        waiting = self.scheduler.waiting()
        preempted_now = sum(1 for r in waiting if r.state == "preempted")
        in_flight = sum(s is not None for s in self.slots)
        return EngineStatus(
            completed=sum(r.done for r in reqs),
            in_flight=in_flight,
            queued=len(waiting) - preempted_now,
            steps=self.steps,
            exhausted=bool(waiting or in_flight),
            health=self.health,
            preempted=preempted_now,
            **self._pool_health(),
        )

    def _pool_health(self) -> dict:
        ps = self.pool.stats()
        return {
            "pool_utilization": ps["utilization"],
            "pool_fragmentation": ps["fragmentation"],
            "shared_blocks": ps["shared_blocks"],
            "prefix_hits": ps["prefix_hits"],
            "prefix_lookups": ps["prefix_lookups"],
        }

    def prefix_overlap(self, prompt) -> int:
        """Tokens of ``prompt`` this engine could serve from cached blocks.

        A read-only probe (hit-rate counters untouched) used by the Router's
        prefix-affinity dispatch; 0 when sharing is inactive here.
        """
        if not self._sharing:
            return 0
        body = np.asarray(prompt, dtype=np.int32)[:-1]
        return len(self.pool.match_prefix(body, peek=True)) * self.pool.block_size

    def drain(self, *, max_steps: int = 10_000) -> EngineStatus:
        """Serve everything submitted until done or the step budget runs out.

        When the ``max_steps`` budget is exhausted, unfinished requests are
        NOT silently returned as results: in-flight ones keep
        ``state="active"`` and waiting ones (queued or preempted) are marked
        ``state="starved"`` and dropped from the queue (both stay
        ``done=False``), so callers can retry or surface them even though
        partial ``output`` tokens exist.  Closes the serving epoch: the next
        drain reports only requests submitted after this one (in-flight
        survivors carry over).  The terminal ``preempted`` field reports how
        many of the epoch's requests were evicted at least once (live
        :meth:`status` snapshots instead count requests *currently* awaiting
        re-admission).
        """
        while self.pending() and self.steps < max_steps:
            if not self.step():
                break
        exhausted = self.pending()
        starved = self.scheduler.clear()
        for r in starved:
            r.state = "starved"
        self._update_health()
        reqs = self._epoch_requests
        status = EngineStatus(
            completed=sum(r.done for r in reqs),
            in_flight=sum(s is not None for s in self.slots),
            queued=len(starved),
            steps=self.steps,
            exhausted=exhausted,
            health=self.health,
            preempted=sum(1 for r in reqs if r.preemptions),
            **self._pool_health(),
        )
        self._epoch_requests = [r for r in reqs if r.state == "active"]
        return status

    def run(self, requests: list[Request], *, max_steps: int = 10_000) -> EngineStatus:
        """Deprecated batch API: submit every request, then drain.

        Byte-identical to the seed engine's loop (admission order, bucketing,
        decode semantics); new code should use :meth:`submit` / :meth:`step` /
        :meth:`drain` (or a :class:`repro.serve.Router` across devices).
        """
        warnings.warn(
            "ServingEngine.run(requests) is deprecated; use "
            "engine.submit(...) -> Ticket plus engine.drain() "
            "(repro.serve submit/stream API)",
            DeprecationWarning,
            stacklevel=2,
        )
        for r in requests:
            self.submit_request(r)
        return self.drain(max_steps=max_steps)


def _batch_extra(key: str, v) -> jax.Array:
    """Shape one extra input for the batch-1 prefill, explicitly per rank.

    Extras come in two layouts: already batched with a leading batch-1 axis
    (``(1, n, d)``) which pass through, or per-sequence without a batch axis
    (``(n, d)``, or a scalar) which gain one.  A leading axis > 1 that is not
    batch-1 is treated as per-sequence data; an explicit batch > 1 cannot be
    meant for a single-sequence prefill, so there is nothing to guess.
    """
    v = jnp.asarray(v)
    if v.ndim == 0:
        return v[None]  # scalar -> (1,)
    if v.shape[0] == 1:
        return v  # already batched (batch-1 leading axis)
    return v[None]  # per-sequence -> add the batch axis


def _scatter_slot(full: jax.Array, one: jax.Array, slot: int, max_batch: int) -> jax.Array:
    """Write a batch-1 cache entry into batch slot ``slot`` of a dense pool.

    Cache leaves carry batch either at axis 0 (B, ...) or axis 1 (L, B, ...);
    the batch axis is the one sized ``max_batch`` in the pool and 1 in the
    prefill output.  Matching against the *pool size* (not shape inequality)
    keeps the write live when ``max_batch == 1``, where pool and prefill
    shapes coincide and an inequality guard silently drops the cache.

    (The engine itself now scatters through :class:`KVPool`, whose probe
    classification generalises this axis guessing; kept as the dense
    reference semantics — tests assert KVPool parity against it.)
    """
    if one.ndim != full.ndim:
        raise ValueError(f"cache rank mismatch {one.shape} vs {full.shape}")
    for axis in (0, 1):
        if one.ndim > axis and one.shape[axis] == 1 and full.shape[axis] == max_batch:
            idx = [slice(None)] * full.ndim
            idx[axis] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(one)
    # replicated leaf (e.g. shared encoder memory broadcast across slots): keep.
    return full
