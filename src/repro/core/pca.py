"""Principal component analysis, from scratch (paper §3.3, Fig. 3).

SVD-based PCA used both to (a) estimate how many deployed kernels are needed
(variance concentration, Fig. 3) and (b) as a pre-transform for k-means
clustering (paper §4.1.2).
"""
from __future__ import annotations

import numpy as np


class PCA:
    """Mean-centred SVD PCA.

    Parameters
    ----------
    n_components:
        Number of principal components to keep. ``None`` keeps all.
    """

    def __init__(self, n_components: int | None = None):
        self.n_components = n_components
        self.mean_: np.ndarray | None = None
        self.components_: np.ndarray | None = None  # (k, n_features)
        self.explained_variance_: np.ndarray | None = None
        self.explained_variance_ratio_: np.ndarray | None = None

    def fit(self, x: np.ndarray) -> "PCA":
        x = np.asarray(x, dtype=np.float64)
        if x.ndim != 2:
            raise ValueError(f"PCA expects 2-D data, got shape {x.shape}")
        n, _ = x.shape
        self.mean_ = x.mean(axis=0)
        xc = x - self.mean_
        # Economy SVD: xc = U S Vt, principal axes are rows of Vt.
        _, s, vt = np.linalg.svd(xc, full_matrices=False)
        var = (s**2) / max(n - 1, 1)
        total = var.sum()
        ratio = var / total if total > 0 else np.zeros_like(var)
        k = self.n_components or len(s)
        k = min(k, len(s))
        self.components_ = vt[:k]
        self.explained_variance_ = var[:k]
        self.explained_variance_ratio_ = ratio[:k]
        self._full_ratio = ratio
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        if self.components_ is None:
            raise RuntimeError("PCA.transform called before fit")
        x = np.asarray(x, dtype=np.float64)
        return (x - self.mean_) @ self.components_.T

    def fit_transform(self, x: np.ndarray) -> np.ndarray:
        return self.fit(x).transform(x)

    def inverse_transform(self, z: np.ndarray) -> np.ndarray:
        if self.components_ is None:
            raise RuntimeError("PCA.inverse_transform called before fit")
        return np.asarray(z) @ self.components_ + self.mean_

    def n_components_for_variance(self, fraction: float) -> int:
        """Smallest number of components whose cumulative variance >= fraction."""
        if self.components_ is None:
            raise RuntimeError("fit first")
        cum = np.cumsum(self._full_ratio)
        return int(np.searchsorted(cum, fraction) + 1)
