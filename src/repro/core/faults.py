"""Fault containment: deterministic chaos injection + the failure-model types.

The paper's premise is that a general-purpose library must serve *every*
input a user throws at it.  The tuned path built in PRs 1-5 quietly assumed
the opposite: every deployed Pallas config compiles, fits in memory, and
returns finite numbers on every shape, and every retune hot-swap is an
improvement.  A production selection system (the model-driven-library line,
arXiv:1806.07060, and the paper's own successor, arXiv:2003.06795) needs a
misbehaving kernel config, a corrupt bundle, or a regressed retune to degrade
gracefully to the reference path — never to take down serving.

This module is the substrate of that failure model (DESIGN.md §11):

  * :class:`FaultPlan` — a seeded, runtime-scoped fault-injection registry.
    A plan is attached to one :class:`~repro.core.runtime.KernelRuntime`
    (``rt.set_fault_plan(plan)``) and fires *deterministically* at named
    sites: kernel compile errors, simulated OOM, NaN/Inf output corruption,
    latency spikes, and corrupt bundle bytes.  Every firing is recorded in
    ``plan.events`` so a chaos test can assert exactly what was injected.
  * Structured fault types (:class:`FaultError` and friends) that the ops
    guard, the serving engine, and the bundle loader agree on.
  * The incident record schema (:func:`incident`) shared by the guard and
    the engine's health state machine.
  * Training-side fault tolerance, folded in from the former
    ``repro.ft.runtime`` module: :class:`PreemptionGuard`,
    :class:`StragglerDetector` (also consulted by the dispatch guard for
    latency-spike incidents), and :func:`elastic_plan`.

Sites are dotted names; the registered injection points are::

    dispatch.<family>    ops-layer guarded kernel execution (per dispatch)
    canary.<family>      retune canary's numeric-agreement probe
    retune.candidate     incremental_retune output (degrade the candidate)
    bundle.load          bundle text corruption at install time
    engine.prefill       whole-program prefill trace (engine-level retry)
    engine.decode        whole-program decode trace (engine-level retry)

Determinism: a spec fires on its matching-call counter (``after`` skips, then
``times`` firings) — no wall clock, no global RNG.  ``p < 1`` draws from the
plan's own seeded generator, so a given (seed, call sequence) always injects
the same faults.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from collections import deque

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "ElasticPlan",
    "FaultError",
    "FaultEvent",
    "FaultPlan",
    "FaultSpec",
    "GUARDED_EXCEPTIONS",
    "InjectedCompileError",
    "InjectedOOMError",
    "NonFiniteOutputError",
    "PreemptionGuard",
    "StragglerDetector",
    "elastic_plan",
    "incident",
]


# ---------------------------------------------------------------------------
# fault types (what the guard catches and what injection raises)
# ---------------------------------------------------------------------------
class FaultError(RuntimeError):
    """Base class for injected (and injection-shaped) kernel faults."""


class InjectedCompileError(FaultError):
    """Simulated Pallas compile/lowering failure for one kernel config."""


class InjectedOOMError(FaultError):
    """Simulated out-of-memory: the config's tiles do not fit this device."""


class NonFiniteOutputError(FaultError):
    """A guarded kernel call produced NaN/Inf on a concrete output."""


def _guarded_exceptions() -> tuple[type[BaseException], ...]:
    """Exception types the dispatch guard may contain (fall back to ref).

    Injected faults always; real XLA/Pallas runtime errors when the jaxlib
    types are importable.  Deliberately excludes TypeError/ValueError — a
    shape mismatch is a caller bug the ref path would reproduce anyway.
    """
    kinds: list[type[BaseException]] = [FaultError]
    try:  # pragma: no cover - depends on jaxlib version
        from jax.errors import JaxRuntimeError

        kinds.append(JaxRuntimeError)
    except Exception:
        pass
    try:  # pragma: no cover - depends on jaxlib version
        from jaxlib.xla_extension import XlaRuntimeError

        kinds.append(XlaRuntimeError)
    except Exception:
        pass
    return tuple(kinds)


GUARDED_EXCEPTIONS: tuple[type[BaseException], ...] = _guarded_exceptions()

FAULT_KINDS = ("compile_error", "oom", "nan", "inf", "latency", "corrupt")


# ---------------------------------------------------------------------------
# fault plan
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FaultSpec:
    """One deterministic injection rule.

    ``site`` is the dotted injection point (exact match, or a prefix when it
    ends with ``.``); ``match`` optionally restricts firing to context keys
    (config names, device slugs) containing the substring.  The spec skips
    its first ``after`` matching calls, then fires ``times`` times (``None``
    = unlimited), each firing subject to probability ``p`` from the plan's
    seeded generator.  ``value`` parameterizes the kind (sleep seconds for
    ``latency``, corrupted-character count for ``corrupt``).
    """

    site: str
    kind: str
    times: int | None = 1
    after: int = 0
    p: float = 1.0
    match: str | None = None
    value: float = 0.0
    # mutable firing state (owned by the plan's lock)
    seen: int = 0
    fired: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One firing of one spec (the plan's audit record)."""

    seq: int
    site: str
    kind: str
    key: str


class FaultPlan:
    """Seeded, deterministic fault-injection schedule for one runtime.

    Thread-safe: dispatch may consult the plan from many threads; firing
    counters and the event log are lock-protected.  The plan itself is inert
    until attached to a runtime (``rt.set_fault_plan(plan)``) — nothing in
    the library consults a free-standing plan.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._specs: list[FaultSpec] = []
        self._seq = 0
        self.events: list[FaultEvent] = []

    def __repr__(self) -> str:
        return (f"FaultPlan(seed={self.seed}, specs={len(self._specs)}, "
                f"fired={len(self.events)})")

    # -- authoring -----------------------------------------------------------
    def inject(self, site: str, kind: str, *, times: int | None = 1, after: int = 0,
               p: float = 1.0, match: str | None = None, value: float = 0.0) -> FaultSpec:
        """Register one injection rule; returns the live spec (counters visible)."""
        spec = FaultSpec(site=site, kind=kind, times=times, after=after, p=p,
                         match=match, value=value)
        with self._lock:
            self._specs.append(spec)
        return spec

    @staticmethod
    def parse(text: str, *, seed: int = 0) -> "FaultPlan":
        """Build a plan from a compact CLI spec string.

        ``"site:kind[:times[:after]]"`` entries joined by ``,`` — e.g.
        ``"dispatch.matmul:nan:2,engine.prefill:compile_error:1:3"``.
        """
        plan = FaultPlan(seed=seed)
        for entry in filter(None, (e.strip() for e in text.split(","))):
            parts = entry.split(":")
            if len(parts) < 2:
                raise ValueError(f"bad fault spec {entry!r} (want site:kind[:times[:after]])")
            site, kind = parts[0], parts[1]
            times = int(parts[2]) if len(parts) > 2 else 1
            after = int(parts[3]) if len(parts) > 3 else 0
            plan.inject(site, kind, times=None if times < 0 else times, after=after)
        return plan

    def specs(self) -> list[FaultSpec]:
        with self._lock:
            return list(self._specs)

    @property
    def active(self) -> bool:
        """True while any spec can still fire (cheap armed check)."""
        with self._lock:
            return any(s.times is None or s.fired < s.times for s in self._specs)

    # -- firing --------------------------------------------------------------
    def _matches(self, spec: FaultSpec, site: str, key: str) -> bool:
        if spec.site.endswith("."):
            if not site.startswith(spec.site) and site != spec.site[:-1]:
                return False
        elif spec.site != site:
            return False
        return spec.match is None or spec.match in key

    def fire(self, site: str, key: str = "") -> FaultSpec | None:
        """The first eligible spec for (site, key), advancing its counters.

        Returns ``None`` when nothing fires.  At most one spec fires per
        call — injection points are single-fault sites.
        """
        with self._lock:
            for spec in self._specs:
                if not self._matches(spec, site, key):
                    continue
                spec.seen += 1
                if spec.seen <= spec.after:
                    continue
                if spec.times is not None and spec.fired >= spec.times:
                    continue
                if spec.p < 1.0 and self._rng.random() >= spec.p:
                    continue
                spec.fired += 1
                self._seq += 1
                self.events.append(FaultEvent(self._seq, site, spec.kind, key))
                return spec
        return None

    # -- kind-specific helpers (what injection *does*) -----------------------
    def raise_if(self, site: str, key: str = "") -> FaultSpec | None:
        """Fire at ``site``; raising kinds raise, ``latency`` sleeps.

        Returns the non-raising spec (``nan``/``inf``/``corrupt``) so the
        caller can apply it to its own payload, or ``None``.
        """
        spec = self.fire(site, key)
        if spec is None:
            return None
        if spec.kind == "compile_error":
            raise InjectedCompileError(f"injected compile failure at {site} [{key}]")
        if spec.kind == "oom":
            raise InjectedOOMError(f"injected OOM at {site} [{key}]")
        if spec.kind == "latency":
            time.sleep(max(float(spec.value), 0.0))
            return spec
        return spec

    @staticmethod
    def corrupt_array(spec: FaultSpec, out):
        """Poison one array (or pytree leaf-0) per the spec's kind.

        Concrete arrays only: a tracer passes through untouched.  Poisoning
        a traced value would bake the NaN into the compiled program for
        every subsequent call — uncontainable by design (the §11 guard
        cannot inspect values inside a trace), so injecting there would
        silently break the containment contract instead of testing it.
        """
        import jax
        import jax.numpy as jnp

        tracer = getattr(jax.core, "Tracer", None)
        if tracer is not None and isinstance(out, tracer):
            return out
        bad = jnp.nan if spec.kind != "inf" else jnp.inf
        if isinstance(out, tuple):
            return (FaultPlan.corrupt_array(spec, out[0]),) + tuple(out[1:])
        return jnp.asarray(out).at[...].set(bad) if hasattr(out, "at") else out

    def corrupt_text(self, site: str, text: str, key: str = "") -> str:
        """Deterministically mangle ``text`` when a ``corrupt`` spec fires.

        Flips ``value`` characters (default 16) at seeded positions — the
        "bit rot / truncated upload" shape a bundle checksum must catch.
        """
        spec = self.fire(site, key)
        if spec is None or spec.kind != "corrupt":
            return text
        n = int(spec.value) or 16
        chars = list(text)
        # seeded positions away from the very start (keep it a JSON-ish blob)
        positions = self._rng.integers(1, max(len(chars) - 1, 2), size=n)
        for pos in positions:
            chars[int(pos)] = "#"
        return "".join(chars)


# ---------------------------------------------------------------------------
# incident records (guard -> telemetry -> engine health)
# ---------------------------------------------------------------------------
def incident(site: str, family: str, config, error: BaseException | str,
             action: str, *, device: str | None = None, seq: int = 0) -> dict:
    """The structured incident record the guard emits and telemetry carries.

    ``action`` names what containment did: ``fallback_ref`` (this call served
    the reference path), ``quarantined`` (the config entered the circuit
    breaker), ``reprobe_failed``, ``absolved`` (a re-probe succeeded),
    ``retry`` (engine-level request retry), ``rollback`` (policy rolled back).
    """
    name = config.name() if hasattr(config, "name") and callable(config.name) else (
        None if config is None else str(config))
    return {
        "seq": int(seq),
        "site": site,
        "family": family,
        "config": name,
        "device": device,
        "error": f"{type(error).__name__}: {error}" if isinstance(error, BaseException) else str(error),
        "action": action,
    }


# ---------------------------------------------------------------------------
# training-side fault tolerance (folded in from repro.ft.runtime)
# ---------------------------------------------------------------------------
class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful 'save and exit' request (poll per step)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = threading.Event()
        self._signals = signals
        self._prev = {}

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False

    def _handler(self, signum, frame):
        self._requested.set()

    def request(self) -> None:  # for tests / in-process triggers
        self._requested.set()

    @property
    def preempted(self) -> bool:
        return self._requested.is_set()


class StragglerDetector:
    """Rolling step-time stats; flags steps slower than threshold x median.

    Used two ways: the trainer times whole steps (``start``/``stop``), and
    the dispatch guard feeds per-kernel wall times via :meth:`observe` to
    turn injected/real latency spikes into incidents.
    """

    def __init__(self, window: int = 50, threshold: float = 2.0, warmup: int = 5):
        self.window = window
        self.threshold = threshold
        self.warmup = warmup
        self.times: deque[float] = deque(maxlen=window)
        self.flagged: list[tuple[int, float, float]] = []  # (step, t, median)
        self._step = 0
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record the step; returns True if it was a straggler step."""
        assert self._t0 is not None, "stop() without start()"
        dt = time.monotonic() - self._t0
        self._t0 = None
        self._step += 1
        is_straggler = self.observe(dt)
        return is_straggler

    def observe(self, dt: float) -> bool:
        med = self.median()
        straggler = (
            len(self.times) >= self.warmup and med > 0 and dt > self.threshold * med
        )
        if straggler:
            self.flagged.append((self._step, dt, med))
        self.times.append(dt)
        return straggler

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    ok: bool
    reason: str
    data: object | None = None  # DataConfig on ok=True


def elastic_plan(data, new_host_index: int, new_host_count: int) -> ElasticPlan:
    """Resume plan after the fleet grows/shrinks.

    The checkpoint needs no conversion (sharding-agnostic). The only
    constraint is global-batch divisibility across the new host count.
    """
    from repro.data.pipeline import reshard

    if new_host_count <= 0:
        return ElasticPlan(False, "host count must be positive")
    if data.global_batch % new_host_count != 0:
        return ElasticPlan(
            False,
            f"global_batch={data.global_batch} not divisible by {new_host_count} hosts",
        )
    if not (0 <= new_host_index < new_host_count):
        return ElasticPlan(False, f"host index {new_host_index} out of range")
    return ElasticPlan(True, "ok", reshard(data, new_host_index, new_host_count))
