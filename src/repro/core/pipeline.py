"""Staged tuning pipeline: candidates -> prune -> transfer -> measure -> select -> classify.

The monolithic ``tuner.tune`` path assumed every (device, family) pair is
harvested from scratch: a dense benchmark table over the full config space,
measured before anything else happens.  That is the right thing for the
paper's two-device study and the wrong thing for a fleet — measurement is the
expensive stage, and most of it is predictable.  This module breaks the tune
into explicit, composable stages with per-stage results:

  1. :func:`generate_candidates` — harvest the problems and enumerate the
     config space for one family (free).
  2. :func:`prune_candidates` — rank configs by the family's *model-side*
     perf predictor (``KernelFamily.model_matrix``: the untextured analytic
     roofline — what is knowable without running anything) and drop the ones
     predicted far off the roofline everywhere.  Nothing has been measured
     yet.
  3. transfer warm-start (:func:`as_transfer_prior` + :func:`plan_measurements`)
     — when a tuned *sibling* device exists (``devices.FALLBACKS``), reuse its
     chosen subset as ``cluster.kmeans(init_centers=...)`` seeds and its
     classifier as a prior: a problem row is only measured where the model
     and the sibling *disagree* about the best surviving config.
  4. :func:`run_measurements` — execute the plan; unmeasured cells are
     model-filled, measured cells come from the family's real benchmark
     source (``perf_matrix``).  The measured-cell count is the honest cost.
  5. cluster-select + classify (:func:`run_family_pipeline`) — the paper
     pipeline (normalize, ``cluster.select_configs``, fit the family tree)
     over the hybrid table.

Every run stamps a *tuning lineage* record (source device, prune ratio,
measured fraction, predicted-vs-measured model error) that rides into
``Deployment.meta["tuning_lineage"]`` and bundle provenance, so an operator
can always answer "what evidence is this artifact actually based on?".

``tuner.tune`` / ``tune_family`` / ``tune_fleet`` are thin shims over
:func:`tune_dataset` / :func:`run_family_pipeline`; with every stage knob at
its default the pipeline reproduces the legacy monolith bit-for-bit (one
full-space ``perf_matrix`` call, cold clustering, seed-0 classifier).
``retune.incremental_retune`` reuses :func:`warm_start_centers` — a retune is
just a transfer from the deployment's own past.  See DESIGN.md §12.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .cluster import select_configs
from .dataset import TuningDataset
from .dispatch import Deployment, classifier_fraction, train_deployment
from .families import KernelFamily, family_names, get_family
from .normalize import normalize
from .selection import achievable_fraction, geomean_fraction, select_from_dataset


# ---------------------------------------------------------------------------
# per-stage results
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CandidateStage:
    """Stage 1: the full search space for one (family, device) tune."""

    family: str
    device: str | None
    problems: list[tuple]
    configs: list  # the full config space, in registry order


@dataclasses.dataclass(frozen=True)
class PruneStage:
    """Stage 2: the model-guided cut of the config space.

    ``kept`` are indices into ``CandidateStage.configs`` (ascending, so
    downstream matrices keep a stable column order); ``predicted`` is the
    model-side perf table over the *full* space (None when the family has no
    ``model_matrix`` or no stage needed it); ``ratio`` is the surviving
    fraction of the space.
    """

    kept: tuple[int, ...]
    predicted: np.ndarray | None
    ratio: float


@dataclasses.dataclass(frozen=True)
class TransferPrior:
    """A tuned sibling's artifact, normalized for warm-starting.

    ``configs``/``tree`` are the donor's deployed subset and classifier for
    the family being tuned; ``source_device`` is recorded in lineage.
    """

    configs: list
    tree: object | None
    source_device: str | None = None


@dataclasses.dataclass(frozen=True)
class MeasurePlan:
    """Stage 3: which (problem, kept-config) cells to actually measure.

    ``mask`` is (n_problems, n_kept) booleans; ``agreed_rows`` counts the
    problems where model and donor agreed (skipped entirely); ``capped_rows``
    counts planned rows dropped to honor ``measure_budget``.
    """

    mask: np.ndarray
    agreed_rows: int = 0
    capped_rows: int = 0


@dataclasses.dataclass(frozen=True)
class MeasureStage:
    """Stage 4: the hybrid benchmark table and its honest cost accounting.

    ``perf`` is (n_problems, n_kept): measured where the plan said so,
    model-filled elsewhere.  ``full_cost`` is what a from-scratch harvest
    would have measured (n_problems x the *full* config space), so
    ``measured_fraction`` is directly the paper-facing cost saving.
    ``model_error`` is the mean relative |predicted - measured| / measured
    over the cells where both exist — the lineage record's calibration
    figure.
    """

    perf: np.ndarray
    measured_mask: np.ndarray
    n_measured: int
    full_cost: int
    measured_fraction: float
    model_error: float | None


@dataclasses.dataclass
class FamilyPipelineResult:
    """One family through all six stages, with every intermediate kept."""

    family: str
    device: str | None
    candidates: CandidateStage
    prune: PruneStage
    transfer: TransferPrior | None
    measure: MeasureStage
    chosen: list[int]  # indices into the FULL config space
    configs: list  # the deployed subset (objects)
    tree: object
    oracle_fraction: float
    classifier_fraction: float
    lineage: dict

    def to_family_result(self):
        """The legacy ``tuner.FamilyTuneResult`` view of this run."""
        from .tuner import FamilyTuneResult

        return FamilyTuneResult(
            family=self.family,
            configs=self.configs,
            tree=self.tree,
            problems=self.candidates.problems,
            oracle_fraction=self.oracle_fraction,
            classifier_fraction=self.classifier_fraction,
            lineage=self.lineage,
        )


# ---------------------------------------------------------------------------
# stage 1: candidates
# ---------------------------------------------------------------------------
def generate_candidates(
    family: str | KernelFamily,
    arch_ids: list[str] | None = None,
    *,
    problems: list[tuple] | None = None,
    device_name: str | None = None,
) -> CandidateStage:
    """Harvest the problems and enumerate the config space for one family."""
    fam = family if isinstance(family, KernelFamily) else get_family(family)
    space = list(fam.config_space())
    problems = list(problems if problems is not None else fam.harvest(arch_ids))
    if not problems:
        raise ValueError(f"no benchmark problems harvested for family {fam.name!r}")
    return CandidateStage(family=fam.name, device=device_name, problems=problems, configs=space)


# ---------------------------------------------------------------------------
# stage 2: model-guided pruning
# ---------------------------------------------------------------------------
def prune_candidates(
    cand: CandidateStage,
    *,
    prune_ratio: float | None = None,
    keep_configs: list | tuple = (),
    with_model: bool = False,
) -> PruneStage:
    """Drop configs the family's perf model predicts are never competitive.

    Each config is scored by its best predicted fraction-of-roofline-best
    over all problems; the top ``ceil(prune_ratio * n_space)`` survive.  The
    family's default config and every entry of ``keep_configs`` (a transfer
    donor's deployed subset) are always kept — pruning must never make the
    donor's prior unexpressable.  ``with_model=True`` computes the model
    table even when no pruning happens (later stages need it for
    disagreement planning and model-fill).  A family without a
    ``model_matrix`` keeps everything.
    """
    fam = get_family(cand.family)
    n_space = len(cand.configs)
    pruning = (
        prune_ratio is not None and 0.0 < prune_ratio < 1.0 and fam.model_matrix is not None
    )
    predicted = None
    if (pruning or with_model) and fam.model_matrix is not None:
        predicted = np.asarray(
            fam.model_matrix(cand.problems, cand.configs, cand.device), dtype=np.float64
        )
    if not pruning:
        return PruneStage(kept=tuple(range(n_space)), predicted=predicted, ratio=1.0)

    best = predicted.max(axis=1, keepdims=True)
    frac = np.where(best > 0, predicted / np.maximum(best, 1e-30), 0.0)
    score = frac.max(axis=0)  # best-case competitiveness of each config
    n_keep = min(n_space, max(int(math.ceil(prune_ratio * n_space)), 1))
    order = np.argsort(-score, kind="stable")
    kept = set(int(j) for j in order[:n_keep])
    forced = list(keep_configs)
    if fam.default_config is not None:
        forced.append(fam.default_config)
    for cfg in forced:
        j = _config_index(cand.configs, cfg)
        if j is not None:
            kept.add(j)
    kept_t = tuple(sorted(kept))
    return PruneStage(kept=kept_t, predicted=predicted, ratio=len(kept_t) / max(n_space, 1))


def _config_index(configs: list, cfg) -> int | None:
    try:
        return configs.index(cfg)
    except ValueError:
        return None


# ---------------------------------------------------------------------------
# stage 3: the measurement plan (where model and prior disagree)
# ---------------------------------------------------------------------------
def plan_measurements(
    cand: CandidateStage,
    prune: PruneStage,
    *,
    donor: TransferPrior | None = None,
    measure_budget: float | None = None,
) -> MeasurePlan:
    """Decide which cells of the kept (problems x configs) table to measure.

    Without a model table every kept cell is measured (there is nothing to
    fill the gaps with, so ``measure_budget`` cannot apply).  With a model
    but no donor, all kept cells are planned and the budget drops the rows
    whose predicted perf *spread* is smallest (the model is confident the
    choice barely matters there).  With a donor, a row is planned only when
    the model's best surviving config and the donor classifier's pick
    disagree — agreement means two independent priors concur and the row is
    served model-filled; the budget keeps the rows with the largest
    predicted cost of picking wrong.

    ``measure_budget`` is a fraction of the *full-harvest* cell count
    (n_problems x full config space), matching the lineage accounting.
    """
    n = len(cand.problems)
    m = len(prune.kept)
    mask = np.ones((n, m), dtype=bool)
    if prune.predicted is None:
        return MeasurePlan(mask=mask)

    pred_kept = prune.predicted[:, list(prune.kept)]
    agreed = 0
    if donor is not None and donor.configs:
        fam = get_family(cand.family)
        donor_col = _donor_columns(fam, cand, prune, donor)
        model_col = pred_kept.argmax(axis=1)
        # Stakes of a wrong pick, per row: predicted loss of taking the
        # donor's config instead of the model's best surviving one.
        best = pred_kept[np.arange(n), model_col]
        donor_pred = np.where(
            donor_col >= 0, pred_kept[np.arange(n), np.maximum(donor_col, 0)], 0.0
        )
        gap = np.where(best > 0, 1.0 - donor_pred / np.maximum(best, 1e-30), 1.0)
        agree = (donor_col == model_col) & (donor_col >= 0)
        mask[agree] = False
        agreed = int(agree.sum())
        priority = np.where(agree, -1.0, gap)
    else:
        # No donor: the budget keeps the rows where config choice matters
        # most (largest predicted relative spread among valid configs).
        pos = np.where(pred_kept > 0, pred_kept, np.nan)
        with np.errstate(invalid="ignore"):
            lo = np.nanmin(pos, axis=1)
            hi = np.nanmax(pos, axis=1)
        priority = np.where(np.isfinite(hi) & (hi > 0), 1.0 - lo / np.maximum(hi, 1e-30), 0.0)

    capped = 0
    if measure_budget is not None and 0.0 < measure_budget < 1.0:
        budget_cells = int(measure_budget * n * len(cand.configs))
        planned_rows = np.where(mask.any(axis=1))[0]
        max_rows = budget_cells // max(m, 1)
        if len(planned_rows) > max_rows:
            order = planned_rows[np.argsort(-priority[planned_rows], kind="stable")]
            for i in order[max_rows:]:
                mask[i] = False
            capped = len(planned_rows) - max_rows
    return MeasurePlan(mask=mask, agreed_rows=agreed, capped_rows=capped)


def _donor_columns(
    fam: KernelFamily, cand: CandidateStage, prune: PruneStage, donor: TransferPrior
) -> np.ndarray:
    """Per-problem kept-column index of the donor classifier's pick (-1 = n/a)."""
    kept_cfgs = [cand.configs[j] for j in prune.kept]
    col_of = {}
    for col, cfg in enumerate(kept_cfgs):
        try:
            col_of.setdefault(cfg, col)
        except TypeError:  # unhashable config type: fall back to .index below
            col_of = None
            break
    feats = fam.features(cand.problems)
    if donor.tree is not None:
        idx = np.clip(np.asarray(donor.tree.predict(feats), dtype=int), 0, len(donor.configs) - 1)
    else:
        idx = np.zeros(len(cand.problems), dtype=int)
    out = np.full(len(cand.problems), -1, dtype=int)
    for i, di in enumerate(idx):
        cfg = donor.configs[int(di)]
        if col_of is not None:
            out[i] = col_of.get(cfg, -1)
        else:
            j = _config_index(kept_cfgs, cfg)
            out[i] = -1 if j is None else j
    return out


# ---------------------------------------------------------------------------
# stage 4: measurement
# ---------------------------------------------------------------------------
def run_measurements(
    cand: CandidateStage, prune: PruneStage, plan: MeasurePlan
) -> MeasureStage:
    """Execute the plan: measured cells from ``perf_matrix``, rest model-filled."""
    fam = get_family(cand.family)
    kept = list(prune.kept)
    kept_cfgs = [cand.configs[j] for j in kept]
    n = len(cand.problems)
    full_cost = n * len(cand.configs)
    mask = plan.mask

    if mask.all():
        # The legacy full-harvest path: one dense perf_matrix call, so a
        # stage-free pipeline run is bit-identical to the old monolith.
        perf = np.asarray(fam.perf_matrix(cand.problems, kept_cfgs, cand.device), dtype=np.float64)
    else:
        if prune.predicted is None:
            raise ValueError("partial measurement plans require a family model_matrix")
        perf = prune.predicted[:, kept].copy()
        for i in np.where(mask.any(axis=1))[0]:
            cols = np.where(mask[i])[0]
            row = fam.perf_matrix(
                [cand.problems[i]], [kept_cfgs[c] for c in cols], cand.device
            )
            perf[i, cols] = np.asarray(row, dtype=np.float64)[0]

    n_measured = int(mask.sum())
    model_error = None
    if prune.predicted is not None and n_measured:
        pred = prune.predicted[:, kept]
        sel = mask & (perf > 0) & (pred > 0)
        if sel.any():
            model_error = float(np.mean(np.abs(pred[sel] - perf[sel]) / perf[sel]))
    return MeasureStage(
        perf=perf,
        measured_mask=mask,
        n_measured=n_measured,
        full_cost=full_cost,
        measured_fraction=n_measured / max(full_cost, 1),
        model_error=model_error,
    )


# ---------------------------------------------------------------------------
# transfer priors + warm starts
# ---------------------------------------------------------------------------
def as_transfer_prior(obj, family: str) -> TransferPrior | None:
    """Normalize anything tuned into a :class:`TransferPrior` for ``family``.

    Accepts a :class:`TransferPrior`, a ``Deployment`` (or anything with a
    ``.deployment``, e.g. a ``TuneResult``), a ``FamilyTuneResult`` /
    ``FamilyTuning``, or a bare ``(configs, tree)`` tuple.  Returns ``None``
    for ``None`` or an empty prior.
    """
    if obj is None:
        return None
    if isinstance(obj, TransferPrior):
        return obj if obj.configs else None
    dep = getattr(obj, "deployment", obj)
    if isinstance(dep, Deployment):
        cfgs, tree = dep.family_tuning(family)
        if not cfgs:
            return None
        return TransferPrior(list(cfgs), tree, source_device=dep.device)
    if hasattr(obj, "configs") and hasattr(obj, "tree"):
        cfgs = list(obj.configs)
        if not cfgs:
            return None
        return TransferPrior(cfgs, obj.tree, source_device=getattr(obj, "source_device", None))
    cfgs, tree = obj  # bare (configs, tree)
    return TransferPrior(list(cfgs), tree, None) if cfgs else None


def warm_start_centers(
    norm_perf: np.ndarray, all_configs: list, perf: np.ndarray, deployed_configs: list
) -> np.ndarray | None:
    """Perf-space centroids implied by an existing deployed kernel subset.

    Problems are grouped by which *deployed* config is best for them (the
    clustering the prior artifact effectively shipped); each group's mean
    normalized perf vector seeds one k-means center.  Deployed configs
    missing from the config space are skipped (k-means++ tops up).  Shared
    by the transfer warm-start here and ``retune.incremental_retune`` — a
    retune is a transfer from the deployment's own past.
    """
    cols = []
    for cfg in deployed_configs:
        j = _config_index(all_configs, cfg)
        if j is not None:
            cols.append(j)
    if not cols:
        return None
    owner = np.asarray(perf)[:, cols].argmax(axis=1)
    centers = []
    for j in range(len(cols)):
        members = norm_perf[owner == j]
        if len(members):
            centers.append(members.mean(axis=0))
    return np.stack(centers) if centers else None


def _lineage_record(
    measure: MeasureStage, prune: PruneStage, donor: TransferPrior | None
) -> dict:
    """JSON-ready provenance for one family's tune (bundle ``tuning_lineage``)."""
    return {
        "source_device": donor.source_device if donor is not None else None,
        "prune_ratio": round(float(prune.ratio), 6),
        "measured_fraction": round(float(measure.measured_fraction), 6),
        "model_error": (
            round(float(measure.model_error), 6) if measure.model_error is not None else None
        ),
        "n_measured": int(measure.n_measured),
        "full_cost": int(measure.full_cost),
    }


# ---------------------------------------------------------------------------
# measure-budget auto-sizing
# ---------------------------------------------------------------------------
AUTO_BUDGET_FLOOR = 0.10
AUTO_BUDGET_CEIL = 0.75
AUTO_BUDGET_DEFAULT = 0.35


def auto_measure_budget(
    model_error: float | None,
    *,
    floor: float = AUTO_BUDGET_FLOOR,
    ceil: float = AUTO_BUDGET_CEIL,
    default: float = AUTO_BUDGET_DEFAULT,
) -> float:
    """Size a measurement budget from a donor's recorded model error.

    The staged pipeline stamps each family's transfer-model quality into
    ``tuning_lineage.model_error`` (mean relative error of the perf model on
    held-out measured cells).  A low error means the donor's model predicts
    this device pair well, so few confirmation measurements are needed; a
    high error means the transfer is unreliable and the budget should grow
    toward a full harvest.  The mapping is linear — ``0.05 + 3 * error`` —
    clipped to ``[floor, ceil]``; with no recorded error we fall back to a
    conservative ``default``.
    """
    if model_error is None:
        return default
    return min(ceil, max(floor, 0.05 + 3.0 * float(model_error)))


def donor_model_error(transfer_from, family: str = "matmul") -> float | None:
    """Pull ``tuning_lineage[family].model_error`` out of a donor, if stamped."""
    if transfer_from is None:
        return None
    dep = getattr(transfer_from, "deployment", transfer_from)
    meta = getattr(dep, "meta", None)
    if not isinstance(meta, dict):
        return None
    record = (meta.get("tuning_lineage") or {}).get(family)
    if not isinstance(record, dict):
        return None
    err = record.get("model_error")
    return float(err) if err is not None else None


def resolve_measure_budget(
    measure_budget, transfer_from=None, *, family: str = "matmul"
) -> float | None:
    """Resolve the ``"auto"`` sentinel into a concrete budget fraction.

    Floats and ``None`` pass through untouched.  ``"auto"`` resolves per
    device pair: with no donor there is nothing to transfer from, so the
    root of the bring-up order measures in full (``None``); with a donor,
    the budget is sized by :func:`auto_measure_budget` from the lineage
    ``model_error`` the donor's own tune recorded for ``family``.
    """
    if measure_budget != "auto":
        return measure_budget
    if transfer_from is None:
        return None
    return auto_measure_budget(donor_model_error(transfer_from, family))


# ---------------------------------------------------------------------------
# stages 5+6: the full per-family pipeline
# ---------------------------------------------------------------------------
def run_family_pipeline(
    family: str | KernelFamily,
    arch_ids: list[str] | None = None,
    *,
    problems: list[tuple] | None = None,
    device_name: str | None = None,
    n_kernels: int | None = None,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    seed: int = 0,
    prune_ratio: float | None = None,
    measure_budget: float | str | None = None,
    transfer_from=None,
) -> FamilyPipelineResult:
    """All six stages for one registered family (any family, matmul included).

    With every stage knob at its default (no prune, no budget, no donor)
    this reproduces the legacy ``tune_family`` monolith exactly.  The donor
    (``transfer_from``, anything :func:`as_transfer_prior` accepts) supplies
    both the k-means warm start and the measure-only-disagreements plan.
    ``measure_budget="auto"`` sizes the budget from the donor's recorded
    lineage via :func:`resolve_measure_budget`.
    """
    fam = family if isinstance(family, KernelFamily) else get_family(family)
    measure_budget = resolve_measure_budget(measure_budget, transfer_from, family=fam.name)
    cand = generate_candidates(fam, arch_ids, problems=problems, device_name=device_name)
    donor = as_transfer_prior(transfer_from, fam.name)
    need_model = donor is not None or (
        measure_budget is not None and 0.0 < measure_budget < 1.0
    )
    prune = prune_candidates(
        cand,
        prune_ratio=prune_ratio,
        keep_configs=donor.configs if donor is not None else (),
        with_model=need_model,
    )
    plan = plan_measurements(cand, prune, donor=donor, measure_budget=measure_budget)
    measure = run_measurements(cand, prune, plan)

    kept_cfgs = [cand.configs[j] for j in prune.kept]
    norm = normalize(measure.perf, normalization)
    feats = fam.features(cand.problems)
    k = min(n_kernels or fam.default_n_kernels, len(kept_cfgs))
    init_centers = None
    if donor is not None:
        init_centers = warm_start_centers(norm, kept_cfgs, measure.perf, donor.configs)
    chosen_local = select_configs(
        norm, k, method, features=feats, seed=seed, init_centers=init_centers
    )
    labels = measure.perf[:, chosen_local].argmax(axis=1)
    tree = fam.make_tree(seed).fit(feats, labels)
    pred = np.clip(tree.predict(feats), 0, len(chosen_local) - 1)
    picked = measure.perf[np.arange(len(cand.problems)), [chosen_local[i] for i in pred]]
    return FamilyPipelineResult(
        family=fam.name,
        device=device_name,
        candidates=cand,
        prune=prune,
        transfer=donor,
        measure=measure,
        chosen=[int(prune.kept[i]) for i in chosen_local],
        configs=[kept_cfgs[i] for i in chosen_local],
        tree=tree,
        oracle_fraction=achievable_fraction(measure.perf, chosen_local),
        classifier_fraction=geomean_fraction(picked, measure.perf.max(axis=1)),
        lineage=_lineage_record(measure, prune, donor),
    )


def staged_matmul_dataset(
    problems: list[tuple],
    device_name: str,
    *,
    prune_ratio: float | None = None,
    measure_budget: float | None = None,
    transfer_from=None,
) -> tuple[TuningDataset, dict, TransferPrior | None]:
    """The matmul benchmark table via the staged pipeline, plus its lineage.

    ``tune_for_archs`` calls this instead of ``build_model_dataset`` when any
    stage knob is active: the returned :class:`TuningDataset` covers the
    *kept* configs with a measured/model-filled hybrid table, and the
    lineage record carries the cost accounting into ``Deployment.meta``.
    """
    donor = as_transfer_prior(transfer_from, "matmul")
    cand = generate_candidates("matmul", problems=problems, device_name=device_name)
    need_model = donor is not None or (
        measure_budget is not None and 0.0 < measure_budget < 1.0
    )
    prune = prune_candidates(
        cand,
        prune_ratio=prune_ratio,
        keep_configs=donor.configs if donor is not None else (),
        with_model=need_model,
    )
    plan = plan_measurements(cand, prune, donor=donor, measure_budget=measure_budget)
    measure = run_measurements(cand, prune, plan)
    ds = TuningDataset(
        device=device_name,
        problems=list(problems),
        configs=[cand.configs[j] for j in prune.kept],
        perf=measure.perf,
        source="pipeline",
        family="matmul",
    )
    return ds, _lineage_record(measure, prune, donor), donor


# ---------------------------------------------------------------------------
# the dataset-anchored tune (the old tune() body, staged)
# ---------------------------------------------------------------------------
def tune_dataset(
    dataset: TuningDataset,
    *,
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    test_fraction: float = 0.25,
    seed: int = 0,
    arch_ids: list[str] | None = None,
    attn_arch_ids: list[str] | None = None,
    n_attn_kernels: int = 4,
    attn_tuning: tuple | None = None,
    families: list[str] | None = None,
    family_tunings: dict | None = None,
    transfer_from=None,
    prune_ratio: float | None = None,
    measure_budget: float | None = None,
    lineage: dict | None = None,
):
    """The full paper pipeline on a benchmark dataset — every family, staged.

    This is ``tuner.tune``'s implementation; the knobs beyond ``tune()``'s
    public signature are the staged-pipeline extensions: ``transfer_from``
    warm-starts the matmul clustering from a sibling's deployed subset,
    ``prune_ratio``/``measure_budget`` thread into every non-matmul family's
    :func:`run_family_pipeline`, and ``lineage`` carries the matmul cost
    record from :func:`staged_matmul_dataset`.  All defaults reproduce the
    legacy monolith exactly.
    """
    from .retune import train_distribution
    from .tuner import FamilyTuneResult, TuneResult, tune_family

    train, test = dataset.split(test_fraction=test_fraction, seed=seed)
    donor = as_transfer_prior(transfer_from, "matmul")
    if donor is not None:
        norm = normalize(train.perf, normalization)
        centers = warm_start_centers(norm, train.configs, train.perf, donor.configs)
        chosen = select_configs(
            norm, n_kernels, method, features=train.features, seed=seed, init_centers=centers
        )
    else:
        chosen = select_from_dataset(train, n_kernels, method, normalization, seed=seed)
    deployment = train_deployment(
        train,
        chosen,
        classifier,
        seed=seed,
        meta={
            "method": method,
            "normalization": normalization,
            "n_kernels": n_kernels,
            "seed": seed,
            "source": dataset.source,
            # Provenance for the continuous tuning loop (DESIGN.md §8): the
            # shape distribution this artifact was tuned against, so a
            # serving host can detect when live traffic drifts away from it.
            "train_distribution": train_distribution(train.problems),
        },
    )
    # Every other registered family through the same pipeline (the paper's
    # future-work direction, generalized): attention, wkv, ssm_scan, ...
    precomputed = dict(family_tunings or {})
    if attn_tuning is not None:
        precomputed.setdefault("attention", attn_tuning)
    harvest_archs = arch_ids if arch_ids is not None else attn_arch_ids
    wanted = [f for f in (families if families is not None else family_names()) if f != "matmul"]
    family_results: dict[str, FamilyTuneResult] = {}
    family_dists: dict[str, dict] = {}
    lineage_out: dict[str, dict] = {}
    for fname in wanted:
        got = precomputed.get(fname)
        if got is None:
            fam = get_family(fname)
            probs = fam.harvest(harvest_archs)
            if not probs:
                continue  # none of the assigned archs launch this op: stays untuned
            got = tune_family(
                fname, problems=probs, method=method, normalization=normalization,
                seed=seed, n_kernels=n_attn_kernels if fname == "attention" else None,
                # Device-insensitive families tune against their single model
                # target everywhere (tune, fleet sharing, AND retune use the
                # same perf surface); device-sensitive ones follow the dataset.
                device_name=dataset.device if fam.device_sensitive else None,
                prune_ratio=prune_ratio, measure_budget=measure_budget,
            )
        if isinstance(got, FamilyTuneResult):
            deployment.set_family_tuning(fname, got.configs, got.tree)
            family_results[fname] = got
            family_dists[fname] = train_distribution(got.problems)
            if got.lineage:
                lineage_out[fname] = got.lineage
        else:  # bare (configs, tree): no problem list, so no provenance
            configs, tree = tuple(got)
            deployment.set_family_tuning(fname, list(configs), tree)
    if family_dists:
        deployment.meta["family_distributions"] = family_dists
    # Tuning lineage: how much evidence this artifact is actually based on.
    matmul_record = dict((lineage or {}).get("matmul") or {})
    if not matmul_record:
        n_cells = int(np.asarray(dataset.perf).size)
        matmul_record = {
            "source_device": donor.source_device if donor is not None else None,
            "prune_ratio": 1.0,
            "measured_fraction": 1.0,
            "model_error": None,
            "n_measured": n_cells,
            "full_cost": n_cells,
        }
    lineage_out["matmul"] = matmul_record
    deployment.meta["tuning_lineage"] = {k: lineage_out[k] for k in sorted(lineage_out)}
    return TuneResult(
        deployment=deployment,
        chosen=chosen,
        oracle_fraction=achievable_fraction(test.perf, chosen),
        classifier_fraction=classifier_fraction(test, chosen, deployment),
        train=train,
        test=test,
        family_results=family_results,
    )
