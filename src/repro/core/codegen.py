"""Decision-tree serialization + nested-if code generation.

The paper integrates the trained decision tree into the SYCL launcher as a
series of nested ``if`` statements (§5.1).  We do the same: a fitted
``DecisionTreeClassifier`` can be (a) round-tripped through JSON (what the
deployment artifact stores) and (b) emitted as standalone Python source with
zero dependencies — the literal launcher embedding.

Two interchangeable JSON tree formats (DESIGN.md §5):
  v1 ``{"n_classes", "root": {...nested...}}`` — recursive dicts, what seed
     deployments shipped; still read forever.
  v2 ``{"n_classes", "format": "flat", "feature": [...], ...}`` — the
     :class:`FlatTree` structure-of-arrays, what ``Deployment.save`` now
     emits (compact, loads straight into the vectorized predict path).
"""
from __future__ import annotations

from .classify import DecisionTreeClassifier, _Node
from .dataset import FEATURE_NAMES
from .flattree import FlatTree


def tree_to_dict(tree: DecisionTreeClassifier) -> dict:
    """v1 nested-dict serialization (kept for back-compat round-trips)."""
    if not isinstance(tree, DecisionTreeClassifier):
        raise TypeError(
            f"only decision trees are shippable launcher classifiers, got {type(tree).__name__}"
        )

    def rec(node: _Node) -> dict:
        if node.left is None:
            return {"label": int(node.label)}
        return {
            "feature": int(node.feature),
            "threshold": float(node.threshold),
            "left": rec(node.left),
            "right": rec(node.right),
        }

    return {"n_classes": tree.n_classes_, "root": rec(tree.root_)}


def tree_to_flat_dict(tree: DecisionTreeClassifier) -> dict:
    """v2 flat-array serialization — ships arrays, not recursive dicts."""
    if not isinstance(tree, DecisionTreeClassifier):
        raise TypeError(
            f"only decision trees are shippable launcher classifiers, got {type(tree).__name__}"
        )
    blob = tree._ensure_flat().to_dict()
    blob.pop("counts", None)  # launcher blobs ship labels only
    return blob


def dict_to_tree(blob: dict) -> DecisionTreeClassifier:
    """Parse either tree format back into a classifier.

    v2 blobs load directly into the flat fast path; the nested node graph is
    reconstructed too so codegen (``tree_to_python``) keeps working.
    """
    tree = DecisionTreeClassifier()
    tree.n_classes_ = int(blob["n_classes"])
    if blob.get("format") == "flat":
        tree.flat_ = FlatTree.from_dict(blob)
        tree.root_ = tree.flat_.to_node(_Node)
        return tree
    if "root" not in blob:
        raise ValueError(f"unrecognized tree blob (keys: {sorted(blob)})")

    def rec(d: dict) -> _Node:
        node = _Node()
        if "label" in d:
            node.label = int(d["label"])
            return node
        node.feature = int(d["feature"])
        node.threshold = float(d["threshold"])
        node.left = rec(d["left"])
        node.right = rec(d["right"])
        node.label = 0
        return node

    tree.root_ = rec(blob["root"])
    tree.flat_ = FlatTree.from_node(tree.root_, tree.n_classes_)
    return tree


def bundle_to_python(bundle, func_name: str = "select_kernel") -> str:
    """Emit a whole :class:`DeploymentBundle` as standalone launcher source.

    One nested-if selector per device (``select_kernel_tpu_v5e``, ...), a
    ``DEVICE_SELECTORS`` table keyed by canonical device name, a ``FALLBACKS``
    copy of the nearest-device chains, and a dispatching ``select_kernel``
    that routes by device with the same fallback-order semantics as
    ``repro.core.devices.resolve_device`` — the multi-target analogue of the
    paper's launcher embedding, with zero repro imports at use time.
    """
    import re

    from .devices import FALLBACKS

    sections: list[str] = []
    names: dict[str, str] = {}
    for device in sorted(bundle.deployments):
        slug = re.sub(r"[^0-9a-zA-Z_]", "_", device)
        fn = f"{func_name}_{slug}"
        names[device] = fn
        sections.append(tree_to_python(bundle.deployments[device].classifier, fn))
    table = ",\n".join(f"    {d!r}: {fn}" for d, fn in sorted(names.items()))
    chains = ",\n".join(
        f"    {d!r}: {tuple(c for c in chain if c in names)!r}"
        for d, chain in sorted(FALLBACKS.items())
    )
    args = ", ".join(FEATURE_NAMES)
    sections.append(
        "\n".join(
            [
                "import re as _re",
                "",
                "DEVICE_SELECTORS = {",
                table,
                "}",
                "",
                "FALLBACKS = {",
                chains,
                "}",
                "",
                "def _canon_device(device):",
                '    """Normalize a raw device_kind string to the canonical slug keys above."""',
                "    low = str(device).strip().lower()",
                "    if low in ('cpu', 'host_cpu'):",
                "        return 'host_cpu'",
                r"    m = _re.search(r'tpu[\s_-]*v(\d+)[\s_-]*(lite|e|p|i)?', low)",
                "    if m:",
                "        variant = {'lite': 'e', 'i': ''}.get(m.group(2) or '', m.group(2) or '')",
                "        return 'tpu_v' + m.group(1) + variant",
                r"    return _re.sub(r'[^a-z0-9]+', '_', low).strip('_') or 'unknown'",
                "",
                f"def {func_name}(device, {args}):",
                '    """Route to the deployed selector for this device (nearest-sibling fallback)."""',
                "    device = _canon_device(device)",
                "    fn = DEVICE_SELECTORS.get(device)",
                "    if fn is None:",
                "        for cand in FALLBACKS.get(device, ()):",
                "            if cand in DEVICE_SELECTORS:",
                "                fn = DEVICE_SELECTORS[cand]",
                "                break",
                "    if fn is None:",
                "        fam = device.split('_', 1)[0]",
                "        for cand in sorted(DEVICE_SELECTORS):",
                "            if cand.split('_', 1)[0] == fam:",
                "                fn = DEVICE_SELECTORS[cand]",
                "                break",
                "    if fn is None:",
                "        fn = DEVICE_SELECTORS[sorted(DEVICE_SELECTORS)[0]]",
                f"    return fn({args})",
            ]
        )
    )
    return "\n\n".join(sections) + "\n"


def tree_to_python(tree: DecisionTreeClassifier, func_name: str = "select_kernel") -> str:
    """Emit the tree as nested-if Python source (the launcher embedding)."""
    lines = [
        f"def {func_name}({', '.join(FEATURE_NAMES)}):",
        '    """Auto-generated kernel-selection decision tree."""',
    ]

    def rec(node: _Node, indent: int) -> None:
        pad = "    " * indent
        if node.left is None:
            lines.append(f"{pad}return {int(node.label)}")
            return
        lines.append(f"{pad}if {FEATURE_NAMES[node.feature]} <= {node.threshold!r}:")
        rec(node.left, indent + 1)
        lines.append(f"{pad}else:")
        rec(node.right, indent + 1)

    rec(tree.root_, 1)
    return "\n".join(lines) + "\n"
