"""Decision-tree serialization + nested-if code generation.

The paper integrates the trained decision tree into the SYCL launcher as a
series of nested ``if`` statements (§5.1).  We do the same: a fitted
``DecisionTreeClassifier`` can be (a) round-tripped through JSON (what the
deployment artifact stores) and (b) emitted as standalone Python source with
zero dependencies — the literal launcher embedding.

Two interchangeable JSON tree formats (DESIGN.md §5):
  v1 ``{"n_classes", "root": {...nested...}}`` — recursive dicts, what seed
     deployments shipped; still read forever.
  v2 ``{"n_classes", "format": "flat", "feature": [...], ...}`` — the
     :class:`FlatTree` structure-of-arrays, what ``Deployment.save`` now
     emits (compact, loads straight into the vectorized predict path).
"""
from __future__ import annotations

from .classify import DecisionTreeClassifier, _Node
from .dataset import FEATURE_NAMES
from .flattree import FlatTree


def tree_to_dict(tree: DecisionTreeClassifier) -> dict:
    """v1 nested-dict serialization (kept for back-compat round-trips)."""
    if not isinstance(tree, DecisionTreeClassifier):
        raise TypeError(
            f"only decision trees are shippable launcher classifiers, got {type(tree).__name__}"
        )

    def rec(node: _Node) -> dict:
        if node.left is None:
            return {"label": int(node.label)}
        return {
            "feature": int(node.feature),
            "threshold": float(node.threshold),
            "left": rec(node.left),
            "right": rec(node.right),
        }

    return {"n_classes": tree.n_classes_, "root": rec(tree.root_)}


def tree_to_flat_dict(tree: DecisionTreeClassifier) -> dict:
    """v2 flat-array serialization — ships arrays, not recursive dicts."""
    if not isinstance(tree, DecisionTreeClassifier):
        raise TypeError(
            f"only decision trees are shippable launcher classifiers, got {type(tree).__name__}"
        )
    blob = tree._ensure_flat().to_dict()
    blob.pop("counts", None)  # launcher blobs ship labels only
    return blob


def dict_to_tree(blob: dict) -> DecisionTreeClassifier:
    """Parse either tree format back into a classifier.

    v2 blobs load directly into the flat fast path; the nested node graph is
    reconstructed too so codegen (``tree_to_python``) keeps working.
    """
    tree = DecisionTreeClassifier()
    tree.n_classes_ = int(blob["n_classes"])
    if blob.get("format") == "flat":
        tree.flat_ = FlatTree.from_dict(blob)
        tree.root_ = tree.flat_.to_node(_Node)
        return tree
    if "root" not in blob:
        raise ValueError(f"unrecognized tree blob (keys: {sorted(blob)})")

    def rec(d: dict) -> _Node:
        node = _Node()
        if "label" in d:
            node.label = int(d["label"])
            return node
        node.feature = int(d["feature"])
        node.threshold = float(d["threshold"])
        node.left = rec(d["left"])
        node.right = rec(d["right"])
        node.label = 0
        return node

    tree.root_ = rec(blob["root"])
    tree.flat_ = FlatTree.from_node(tree.root_, tree.n_classes_)
    return tree


def tree_to_python(tree: DecisionTreeClassifier, func_name: str = "select_kernel") -> str:
    """Emit the tree as nested-if Python source (the launcher embedding)."""
    lines = [
        f"def {func_name}({', '.join(FEATURE_NAMES)}):",
        '    """Auto-generated kernel-selection decision tree."""',
    ]

    def rec(node: _Node, indent: int) -> None:
        pad = "    " * indent
        if node.left is None:
            lines.append(f"{pad}return {int(node.label)}")
            return
        lines.append(f"{pad}if {FEATURE_NAMES[node.feature]} <= {node.threshold!r}:")
        rec(node.left, indent + 1)
        lines.append(f"{pad}else:")
        rec(node.right, indent + 1)

    rec(tree.root_, 1)
    return "\n".join(lines) + "\n"
