"""Decision-tree serialization + nested-if code generation.

The paper integrates the trained decision tree into the SYCL launcher as a
series of nested ``if`` statements (§5.1).  We do the same: a fitted
``DecisionTreeClassifier`` can be (a) round-tripped through JSON (what the
deployment artifact stores) and (b) emitted as standalone Python source with
zero dependencies — the literal launcher embedding.

Two interchangeable JSON tree formats (DESIGN.md §5):
  v1 ``{"n_classes", "root": {...nested...}}`` — recursive dicts, what seed
     deployments shipped; still read forever.
  v2 ``{"n_classes", "format": "flat", "feature": [...], ...}`` — the
     :class:`FlatTree` structure-of-arrays, what ``Deployment.save`` now
     emits (compact, loads straight into the vectorized predict path).
"""
from __future__ import annotations

from .classify import DecisionTreeClassifier, _Node
from .dataset import FEATURE_NAMES
from .flattree import FlatTree


def tree_to_dict(tree: DecisionTreeClassifier) -> dict:
    """v1 nested-dict serialization (kept for back-compat round-trips)."""
    if not isinstance(tree, DecisionTreeClassifier):
        raise TypeError(
            f"only decision trees are shippable launcher classifiers, got {type(tree).__name__}"
        )

    def rec(node: _Node) -> dict:
        if node.left is None:
            return {"label": int(node.label)}
        return {
            "feature": int(node.feature),
            "threshold": float(node.threshold),
            "left": rec(node.left),
            "right": rec(node.right),
        }

    return {"n_classes": tree.n_classes_, "root": rec(tree.root_)}


def tree_to_flat_dict(tree: DecisionTreeClassifier) -> dict:
    """v2 flat-array serialization — ships arrays, not recursive dicts."""
    if not isinstance(tree, DecisionTreeClassifier):
        raise TypeError(
            f"only decision trees are shippable launcher classifiers, got {type(tree).__name__}"
        )
    blob = tree._ensure_flat().to_dict()
    blob.pop("counts", None)  # launcher blobs ship labels only
    return blob


def dict_to_tree(blob: dict) -> DecisionTreeClassifier:
    """Parse either tree format back into a classifier.

    v2 blobs load directly into the flat fast path; the nested node graph is
    reconstructed too so codegen (``tree_to_python``) keeps working.
    """
    tree = DecisionTreeClassifier()
    tree.n_classes_ = int(blob["n_classes"])
    if blob.get("format") == "flat":
        tree.flat_ = FlatTree.from_dict(blob)
        tree.root_ = tree.flat_.to_node(_Node)
        return tree
    if "root" not in blob:
        raise ValueError(f"unrecognized tree blob (keys: {sorted(blob)})")

    def rec(d: dict) -> _Node:
        node = _Node()
        if "label" in d:
            node.label = int(d["label"])
            return node
        node.feature = int(d["feature"])
        node.threshold = float(d["threshold"])
        node.left = rec(d["left"])
        node.right = rec(d["right"])
        node.label = 0
        return node

    tree.root_ = rec(blob["root"])
    tree.flat_ = FlatTree.from_node(tree.root_, tree.n_classes_)
    return tree


def bundle_to_python(bundle, func_name: str = "select_kernel") -> str:
    """Emit a whole :class:`DeploymentBundle` as standalone launcher source.

    One nested-if selector per (kernel family, device) —
    ``select_kernel_tpu_v5e`` for matmul (name kept for compat),
    ``select_attention_tpu_v5e`` / ``select_wkv_...`` / ... for every other
    family with a shipped tree — plus routing tables: ``DEVICE_SELECTORS``
    (matmul, keyed by canonical device name), ``FAMILY_SELECTORS`` (family ->
    device -> selector), a ``FALLBACKS`` copy of the nearest-device chains, a
    dispatching ``select_kernel`` and family-generic ``select_kernel_family``
    that route by device with the same fallback-order semantics as
    ``repro.core.devices.resolve_device`` — the multi-target analogue of the
    paper's launcher embedding, with zero repro imports at use time.
    """
    import re

    from .devices import FALLBACKS
    from .families import get_family

    sections: list[str] = []
    names: dict[str, str] = {}
    family_names_tbl: dict[str, dict[str, str]] = {}
    for device in sorted(bundle.deployments):
        dep = bundle.deployments[device]
        slug = re.sub(r"[^0-9a-zA-Z_]", "_", device)
        fn = f"{func_name}_{slug}"
        names[device] = fn
        family_names_tbl.setdefault("matmul", {})[device] = fn
        sections.append(tree_to_python(dep.classifier, fn))
        for fam_name in dep.family_names():
            if fam_name == "matmul":
                continue
            configs, tree = dep.family_tuning(fam_name)
            if not isinstance(tree, DecisionTreeClassifier):
                continue  # untuned / non-tree family: nothing to embed
            fam = get_family(fam_name)
            ffn = f"select_{re.sub(r'[^0-9a-zA-Z_]', '_', fam_name)}_{slug}"
            family_names_tbl.setdefault(fam_name, {})[device] = ffn
            sections.append(tree_to_python(tree, ffn, feature_names=fam.feature_names))
    table = ",\n".join(f"    {d!r}: {fn}" for d, fn in sorted(names.items()))
    fam_table = ",\n".join(
        "    {!r}: {{{}}}".format(
            fam, ", ".join(f"{d!r}: {fn}" for d, fn in sorted(devs.items()))
        )
        for fam, devs in sorted(family_names_tbl.items())
    )
    chains = ",\n".join(
        f"    {d!r}: {tuple(c for c in chain if c in names)!r}"
        for d, chain in sorted(FALLBACKS.items())
    )
    args = ", ".join(FEATURE_NAMES)
    sections.append(
        "\n".join(
            [
                "import re as _re",
                "",
                "DEVICE_SELECTORS = {",
                table,
                "}",
                "",
                "FAMILY_SELECTORS = {",
                fam_table,
                "}",
                "",
                "FALLBACKS = {",
                chains,
                "}",
                "",
                "def _canon_device(device):",
                '    """Normalize a raw device_kind string to the canonical slug keys above."""',
                "    low = str(device).strip().lower()",
                "    if low in ('cpu', 'host_cpu'):",
                "        return 'host_cpu'",
                r"    m = _re.search(r'tpu[\s_-]*v(\d+)[\s_-]*(lite|e|p|i)?', low)",
                "    if m:",
                "        variant = {'lite': 'e', 'i': ''}.get(m.group(2) or '', m.group(2) or '')",
                "        return 'tpu_v' + m.group(1) + variant",
                r"    return _re.sub(r'[^a-z0-9]+', '_', low).strip('_') or 'unknown'",
                "",
                "def _resolve(table, device):",
                '    """Nearest-sibling device resolution over one selector table."""',
                "    device = _canon_device(device)",
                "    fn = table.get(device)",
                "    if fn is None:",
                "        for cand in FALLBACKS.get(device, ()):",
                "            if cand in table:",
                "                fn = table[cand]",
                "                break",
                "    if fn is None:",
                "        fam = device.split('_', 1)[0]",
                "        for cand in sorted(table):",
                "            if cand.split('_', 1)[0] == fam:",
                "                fn = table[cand]",
                "                break",
                "    if fn is None:",
                "        fn = table[sorted(table)[0]]",
                "    return fn",
                "",
                f"def {func_name}(device, {args}):",
                '    """Route to the deployed matmul selector for this device."""',
                f"    return _resolve(DEVICE_SELECTORS, device)({args})",
                "",
                f"def {func_name}_family(family, device, *features):",
                '    """Route any kernel family (matmul, attention, wkv, ssm_scan, ...).',
                "",
                "    ``features`` are the family's own featurization, in its declared",
                "    order; raises KeyError for a family this bundle does not ship.",
                '    """',
                "    table = FAMILY_SELECTORS[family]",
                "    return _resolve(table, device)(*features)",
            ]
        )
    )
    return "\n\n".join(sections) + "\n"


def tree_to_python(
    tree: DecisionTreeClassifier,
    func_name: str = "select_kernel",
    feature_names: tuple[str, ...] = FEATURE_NAMES,
) -> str:
    """Emit the tree as nested-if Python source (the launcher embedding).

    ``feature_names`` are the argument names of the generated selector —
    each kernel family passes its own (``repro.core.families``).
    """
    lines = [
        f"def {func_name}({', '.join(feature_names)}):",
        '    """Auto-generated kernel-selection decision tree."""',
    ]

    def rec(node: _Node, indent: int) -> None:
        pad = "    " * indent
        if node.left is None:
            lines.append(f"{pad}return {int(node.label)}")
            return
        lines.append(f"{pad}if {feature_names[node.feature]} <= {node.threshold!r}:")
        rec(node.left, indent + 1)
        lines.append(f"{pad}else:")
        rec(node.right, indent + 1)

    rec(tree.root_, 1)
    return "\n".join(lines) + "\n"
