"""Performance-data normalization schemes (paper §3.4, Fig. 4).

Each scheme maps a vector of raw per-config performances (gigaflops/s, higher
is better) for ONE problem instance to values in [0, 1], with the best
performing kernels near 1.  Rows of zeros (e.g. a problem where every config
failed) normalize to zeros.

Schemes (names follow the paper):
  * ``standard``    — divide by the per-problem max ("standard scaled").
  * ``raw_cutoff``  — like standard, but values < cutoff clamped to 0 (values
                      keep their raw scale, giving sparsity without rescaling).
  * ``cutoff``      — raw_cutoff then rescaled so surviving values span [0,1]
                      ("standard cutoff").
  * ``sigmoid``     — f(x) = 1 / (1 + exp(50 * (0.85 - x))) applied to the
                      standard-scaled values: 85 % of peak -> 0.5, <80 % -> <0.1.
"""
from __future__ import annotations

import numpy as np

NORMALIZATIONS = ("standard", "raw_cutoff", "cutoff", "sigmoid")

_DEFAULT_CUTOFF = 0.9


def _scale_rows(perf: np.ndarray) -> np.ndarray:
    perf = np.asarray(perf, dtype=np.float64)
    mx = perf.max(axis=-1, keepdims=True)
    safe = np.where(mx > 0, mx, 1.0)
    return np.where(mx > 0, perf / safe, 0.0)


def normalize(perf: np.ndarray, method: str = "standard", cutoff: float = _DEFAULT_CUTOFF) -> np.ndarray:
    """Normalize raw performance rows; ``perf`` is (n_problems, n_configs) or 1-D."""
    scaled = _scale_rows(perf)
    if method == "standard":
        return scaled
    if method == "raw_cutoff":
        return np.where(scaled >= cutoff, scaled, 0.0)
    if method == "cutoff":
        clipped = np.where(scaled >= cutoff, scaled, 0.0)
        # Rescale surviving values from [cutoff, 1] to [0, 1] per row.
        out = np.where(clipped > 0, (clipped - cutoff) / (1.0 - cutoff), 0.0)
        return out
    if method == "sigmoid":
        sig = 1.0 / (1.0 + np.exp(50.0 * (0.85 - scaled)))
        # Keep exact zeros (failed configs) at zero.
        return np.where(scaled > 0, sig, 0.0)
    raise ValueError(f"unknown normalization {method!r}; expected one of {NORMALIZATIONS}")
