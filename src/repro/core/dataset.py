"""Tuning dataset: benchmark problems, features, and the perf table.

The paper harvests GEMM shapes from VGG/ResNet/MobileNet (300 problems); we
harvest them from the 10 assigned architectures x their input shapes (every
projection / MLP / vocab / expert GEMM the frameworks will actually launch),
via ``repro.configs.registry.gemm_problems``.

A problem is ``(m, k, n, batch)``.  Classifier features are log2 sizes plus
shape-character ratios (aspect, arithmetic intensity) — cheap to compute in a
launcher, expressive enough for the shape regimes (square/skinny/deep) the
paper identifies.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from repro.kernels.matmul import MatmulConfig, config_space

Problem = tuple[int, int, int, int]

FEATURE_NAMES = ("log2_m", "log2_k", "log2_n", "log2_batch", "log2_mn_over_k", "log2_intensity")


def problem_features(problems: list[Problem]) -> np.ndarray:
    """(n_problems, n_features) feature matrix for classifier/tree inputs.

    Fully batched — one numpy expression over the whole problem list, so the
    dispatch/tuning paths never featurize row-by-row in Python.
    """
    p = np.asarray(problems, dtype=np.float64).reshape(-1, 4)
    if p.size == 0:
        return np.zeros((0, len(FEATURE_NAMES)))
    m, k, n, batch = p.T
    flops = 2.0 * m * k * n * batch
    bytes_min = 2.0 * (m * k + k * n + m * n) * batch
    return np.column_stack(
        [
            np.log2(m),
            np.log2(k),
            np.log2(n),
            np.log2(batch),
            np.log2((m * n) / k),
            np.log2(flops / bytes_min),
        ]
    )


@dataclasses.dataclass
class TuningDataset:
    """Raw benchmark table for one device (problems x configs, gflops/s).

    ``family`` names the kernel family the table belongs to (a key of the
    ``repro.core.families`` registry); featurization and config parsing
    route through that family, so the same container carries matmul GEMMs,
    attention shapes, or any future op's benchmark data.
    """

    device: str
    problems: list[Problem]
    configs: list[MatmulConfig]
    perf: np.ndarray  # raw gflops/s, (n_problems, n_configs)
    source: str = "model"  # 'model' (analytic) or 'measured'
    family: str = "matmul"

    def __post_init__(self):
        self.perf = np.asarray(self.perf, dtype=np.float64)
        assert self.perf.shape == (len(self.problems), len(self.configs)), (
            self.perf.shape,
            len(self.problems),
            len(self.configs),
        )

    @property
    def features(self) -> np.ndarray:
        if self.family == "matmul":
            return problem_features(self.problems)
        from .families import get_family

        return get_family(self.family).features(self.problems)

    def split(self, test_fraction: float = 0.25, seed: int = 0) -> tuple["TuningDataset", "TuningDataset"]:
        rng = np.random.default_rng(seed)
        n = len(self.problems)
        order = rng.permutation(n)
        n_test = max(1, int(round(n * test_fraction)))
        test_idx = np.sort(order[:n_test])
        train_idx = np.sort(order[n_test:])
        mk = lambda idx: TuningDataset(
            device=self.device,
            problems=[self.problems[i] for i in idx],
            configs=self.configs,
            perf=self.perf[idx],
            source=self.source,
            family=self.family,
        )
        return mk(train_idx), mk(test_idx)

    # -- persistence --------------------------------------------------------
    def save(self, path: str | Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(
            path,
            perf=self.perf,
            problems=np.asarray(self.problems, dtype=np.int64),
            meta=json.dumps(
                {
                    "device": self.device,
                    "source": self.source,
                    "family": self.family,
                    "configs": [c.to_dict() for c in self.configs],
                }
            ),
        )

    @staticmethod
    def load(path: str | Path) -> "TuningDataset":
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            family = meta.get("family", "matmul")
            if family == "matmul":
                config_cls = MatmulConfig
            else:
                from .families import get_family

                config_cls = get_family(family).config_cls
            return TuningDataset(
                device=meta["device"],
                problems=[tuple(int(v) for v in row) for row in z["problems"]],
                configs=[config_cls.from_dict(d) for d in meta["configs"]],
                perf=z["perf"],
                source=meta["source"],
                family=family,
            )


def harvest_problems(arch_ids: list[str] | None = None, *, dedup: bool = True, max_problems: int | None = None) -> list[Problem]:
    """GEMM problems from the assigned architectures (lazy configs import)."""
    from repro.configs import registry

    arch_ids = arch_ids or list(registry.ARCHS)
    problems: list[Problem] = []
    seen = set()
    for arch in arch_ids:
        for shape in registry.shapes_for(arch):
            for p in registry.gemm_problems(arch, shape):
                if dedup and p in seen:
                    continue
                seen.add(p)
                problems.append(p)
    problems.sort()
    if max_problems is not None and len(problems) > max_problems:
        rng = np.random.default_rng(0)
        idx = np.sort(rng.choice(len(problems), size=max_problems, replace=False))
        problems = [problems[i] for i in idx]
    return problems


def synthetic_problems(n: int = 300, seed: int = 0) -> list[Problem]:
    """Paper-flavoured synthetic problem mix (square / rectangular / skinny)."""
    rng = np.random.default_rng(seed)
    out: list[Problem] = []
    pows = [2**e for e in range(3, 14)]
    for _ in range(n):
        kind = rng.random()
        if kind < 0.4:  # squarish
            m = int(rng.choice(pows[3:9]))
            n_ = int(rng.choice(pows[3:9]))
            k = int(rng.choice(pows[3:10]))
        elif kind < 0.7:  # rectangular, deep k
            m = int(rng.choice(pows[3:8]))
            n_ = int(rng.choice(pows[3:8]))
            k = int(rng.choice(pows[7:]))
        else:  # tall-skinny (decode-like)
            m = int(rng.choice([1, 2, 4, 8, 16, 32]))
            n_ = int(rng.choice(pows[4:10]))
            k = int(rng.choice(pows[5:11]))
        batch = int(rng.choice([1, 1, 1, 8, 16, 32]))
        out.append((m, k, n_, batch))
    return sorted(set(out))


def build_model_dataset(
    problems: list[Problem] | None = None,
    device_name: str = "tpu_v5e",
    configs: list[MatmulConfig] | None = None,
) -> TuningDataset:
    """Dense analytic-model benchmark table (the 'AMD GPU' analogue)."""
    from .perfmodel import DEVICES, build_perf_matrix

    problems = problems if problems is not None else synthetic_problems()
    configs = list(configs if configs is not None else config_space())
    device = DEVICES[device_name]
    perf = build_perf_matrix(problems, configs, device)
    return TuningDataset(device=device.name, problems=problems, configs=configs, perf=perf, source="model")
