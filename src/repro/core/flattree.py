"""Flat structure-of-arrays decision trees — the compiled selection fast path.

The paper's launcher embeds the decision tree as a handful of nested ``if``
statements (§5.1), so selection costs nanoseconds.  The nested ``_Node``
object graph we train on is the opposite: per-row Python pointer chasing.
:class:`FlatTree` is the deployable middle ground — five parallel arrays
(feature / threshold / left / right / label) laid out in preorder, with a
fully vectorized batch ``predict`` that descends one *frontier level* per
iteration instead of one Python node per row.  Every fitted tree compiles
into this form after ``fit``; it is also deployment blob format v2
(see DESIGN.md §5).

Numpy-only, no imports from the rest of ``repro.core`` (classify/codegen
import *us*, not the other way round).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FlatTree"]

_LEAF = -1


@dataclasses.dataclass
class FlatTree:
    """Preorder flat arrays for a binary decision tree.

    ``feature[i] == -1`` marks node ``i`` as a leaf; ``left``/``right`` hold
    child node indices for internal nodes (and ``-1`` on leaves).  ``counts``
    (optional) carries the per-node class-count vectors needed by random
    forests' soft voting.
    """

    feature: np.ndarray  # (n_nodes,) int32, -1 for leaves
    threshold: np.ndarray  # (n_nodes,) float64
    left: np.ndarray  # (n_nodes,) int32
    right: np.ndarray  # (n_nodes,) int32
    label: np.ndarray  # (n_nodes,) int32
    n_classes: int
    counts: np.ndarray | None = None  # (n_nodes, n_classes) float64

    def __post_init__(self):
        self.feature = np.asarray(self.feature, dtype=np.int32)
        self.threshold = np.asarray(self.threshold, dtype=np.float64)
        self.left = np.asarray(self.left, dtype=np.int32)
        self.right = np.asarray(self.right, dtype=np.int32)
        self.label = np.asarray(self.label, dtype=np.int32)
        if self.counts is not None:
            self.counts = np.asarray(self.counts, dtype=np.float64)

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    def n_leaves(self) -> int:
        return int((self.feature == _LEAF).sum())

    # -- construction -------------------------------------------------------
    @staticmethod
    def from_node(root, n_classes: int) -> "FlatTree":
        """Compile a nested node graph (``.feature/.threshold/.left/.right/
        .label/.counts`` duck type) into flat arrays, iteratively."""
        feature: list[int] = []
        threshold: list[float] = []
        left: list[int] = []
        right: list[int] = []
        label: list[int] = []
        counts: list[np.ndarray | None] = []

        def alloc(node) -> int:
            idx = len(feature)
            is_leaf = node.left is None
            feature.append(_LEAF if is_leaf else int(node.feature))
            threshold.append(0.0 if is_leaf else float(node.threshold))
            left.append(_LEAF)
            right.append(_LEAF)
            label.append(int(node.label))
            counts.append(getattr(node, "counts", None))
            return idx

        stack = [(root, alloc(root))]
        while stack:
            node, idx = stack.pop()
            if node.left is None:
                continue
            li = alloc(node.left)
            ri = alloc(node.right)
            left[idx], right[idx] = li, ri
            stack.append((node.left, li))
            stack.append((node.right, ri))

        cmat = None
        if all(c is not None for c in counts):
            cmat = np.zeros((len(counts), n_classes))
            for i, c in enumerate(counts):
                cmat[i, : len(c)] = c
        return FlatTree(feature, threshold, left, right, label, n_classes, cmat)

    def to_node(self, node_factory):
        """Reconstruct the nested node graph (for codegen / back-compat)."""
        nodes = [node_factory() for _ in range(self.n_nodes)]
        for i, node in enumerate(nodes):
            node.label = int(self.label[i])
            if self.counts is not None:
                node.counts = self.counts[i].copy()
            if self.feature[i] != _LEAF:
                node.feature = int(self.feature[i])
                node.threshold = float(self.threshold[i])
                node.left = nodes[self.left[i]]
                node.right = nodes[self.right[i]]
        return nodes[0]

    # -- inference ----------------------------------------------------------
    def apply(self, x: np.ndarray) -> np.ndarray:
        """Leaf node index per row — iterative frontier descent.

        Each iteration advances every still-internal row one level, so the
        loop runs ``depth`` times total regardless of batch size (no per-row
        Python recursion).
        """
        x = np.asarray(x, dtype=np.float64)
        idx = np.zeros(len(x), dtype=np.int32)
        while True:
            feat = self.feature[idx]
            live = feat != _LEAF
            if not live.any():
                return idx
            rows = np.nonzero(live)[0]
            at = idx[rows]
            go_left = x[rows, feat[rows]] <= self.threshold[at]
            idx[rows] = np.where(go_left, self.left[at], self.right[at])

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.label[self.apply(x)].astype(int)

    def predict_counts(self, x: np.ndarray) -> np.ndarray:
        """Per-row leaf class-count vectors, normalized (forest soft votes)."""
        if self.counts is None:
            raise ValueError("tree was built without class counts")
        leaf = self.apply(x)
        c = self.counts[leaf]
        return c / np.maximum(c.sum(axis=1, keepdims=True), 1e-12)

    # -- serialization (deployment blob format v2) ---------------------------
    def to_dict(self) -> dict:
        blob = {
            "format": "flat",
            "n_classes": int(self.n_classes),
            "feature": self.feature.tolist(),
            "threshold": self.threshold.tolist(),
            "left": self.left.tolist(),
            "right": self.right.tolist(),
            "label": self.label.tolist(),
        }
        if self.counts is not None:
            blob["counts"] = self.counts.tolist()
        return blob

    @staticmethod
    def from_dict(blob: dict) -> "FlatTree":
        tree = FlatTree(
            feature=blob["feature"],
            threshold=blob["threshold"],
            left=blob["left"],
            right=blob["right"],
            label=blob["label"],
            n_classes=int(blob["n_classes"]),
            counts=blob.get("counts"),
        )
        tree.validate()
        return tree

    def validate(self) -> None:
        """Structural sanity: child indices in range, leaves consistent, no
        cycles — a corrupt blob must fail here, not hang ``predict``."""
        n = self.n_nodes
        if not (len(self.threshold) == len(self.left) == len(self.right) == len(self.label) == n):
            raise ValueError("flat tree arrays have mismatched lengths")
        if n == 0:
            raise ValueError("flat tree is empty")
        internal = self.feature != _LEAF
        parents = np.nonzero(internal)[0]
        kids = np.concatenate([self.left[parents], self.right[parents]])
        if kids.size and (kids.min() < 0 or kids.max() >= n):
            raise ValueError("flat tree child index out of range")
        # Preorder property: children strictly follow their parent, so every
        # root-to-leaf walk has strictly increasing indices (terminates), and
        # each node is the child of at most one parent.
        if np.any(self.left[parents] <= parents) or np.any(self.right[parents] <= parents):
            raise ValueError("flat tree child index does not follow its parent (cycle?)")
        if kids.size != np.unique(kids).size:
            raise ValueError("flat tree node referenced by multiple parents")
        if np.any(self.left[~internal] != _LEAF) or np.any(self.right[~internal] != _LEAF):
            raise ValueError("flat tree leaf with children")
        if self.counts is not None and self.counts.shape != (n, self.n_classes):
            raise ValueError(
                f"flat tree counts shape {self.counts.shape} != ({n}, {self.n_classes})"
            )

    def max_leaf_label(self) -> int:
        """Largest label reachable at a leaf (for deployment validation)."""
        leaves = self.feature == _LEAF
        return int(self.label[leaves].max())
