"""Online (dynamic) kernel selection — the paper's §2.2 comparison point.

TensorFlow/MXNet-style cuDNN launcher autotuning: measure candidate kernels
the first time a problem shape appears at runtime, then commit to the winner
for the rest of the process lifetime.  The paper argues offline
clustering+classifier tuning avoids this warm-up cost; this module makes the
comparison concrete inside the same framework:

  * :class:`OnlinePolicy` wraps any deployment (or the full config space) and
    implements the same ``KernelPolicy`` protocol;
  * first ``n_trials`` encounters of a shape bucket measure different
    candidates (explore), after which the best-measured config is committed;
  * a measurement hook makes it testable without hardware (and pluggable
    with real timers on device).

The hybrid mode — explore only among the *deployed* subset chosen by the
offline pipeline — combines both papers' worlds: the classifier provides the
prior, online measurement corrects residual mispredictions at the cost of a
bounded warm-up.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, defaultdict
from typing import Callable, Sequence

from repro.kernels.matmul import MatmulConfig, config_space


def shape_bucket(problem: tuple[int, ...]) -> tuple[int, ...]:
    """log2 shape bucket: nearby shapes share measurements (paper's regimes).

    Shared vocabulary of the telemetry pipeline: ``repro.core.retune`` keys
    its traffic histograms and drift detection on the same buckets.
    """
    return tuple(max(int(v), 1).bit_length() for v in problem)


_bucket = shape_bucket  # historical private name


@dataclasses.dataclass
class _Arm:
    config: MatmulConfig
    trials: int = 0
    total_time: float = 0.0

    @property
    def mean(self) -> float:
        return self.total_time / self.trials if self.trials else float("inf")


class OnlinePolicy:
    """Explore-then-commit online kernel selection (KernelPolicy protocol).

    ``measure(problem, config) -> seconds`` supplies timings: a real timer on
    hardware, the analytic model in tests/simulation.  ``candidates`` defaults
    to the full config space (pure dynamic tuning); pass a deployment's
    configs for the hybrid offline-prior + online-correction mode.
    """

    # Exploration is stateful (repeated calls for the same shape must reach
    # different arms), so the ops-layer shape cache must not memoize us; the
    # per-bucket ``_committed`` dict below is this policy's own fast path.
    cacheable = False

    def __init__(
        self,
        measure: Callable[[tuple, MatmulConfig], float],
        candidates: Sequence[MatmulConfig] | None = None,
        *,
        trials_per_arm: int = 1,
        prior: object | None = None,  # optional Deployment for the first guess
    ):
        self.measure = measure
        self.candidates = list(candidates if candidates is not None else config_space())
        self.trials_per_arm = trials_per_arm
        self.prior = prior
        self._arms: dict[tuple, list[_Arm]] = {}
        self._committed: dict[tuple, MatmulConfig] = {}
        self._attn_cache: OrderedDict[tuple, object] = OrderedDict()  # LRU, bounded
        self._attn_cache_cap = 1024
        self.stats = defaultdict(int)  # 'explore' / 'commit' counters

    # -- KernelPolicy ---------------------------------------------------------
    def select_matmul(self, m: int, k: int, n: int, batch: int) -> MatmulConfig:
        problem = (m, k, n, batch)
        b = _bucket(problem)
        if b in self._committed:
            self.stats["commit"] += 1
            return self._committed[b]
        arms = self._arms.get(b)
        if arms is None:
            # Order candidates so the prior's pick is measured first: if the
            # exploration budget is cut short, the offline prediction leads.
            cands = list(self.candidates)
            if self.prior is not None:
                first = self.prior.select_matmul(*problem)
                if first in cands:
                    cands.remove(first)
                    cands.insert(0, first)
            arms = [_Arm(c) for c in cands]
            self._arms[b] = arms
        # explore the next under-measured arm
        for arm in arms:
            if arm.trials < self.trials_per_arm:
                self.stats["explore"] += 1
                arm.total_time += self.measure(problem, arm.config)
                arm.trials += 1
                if all(a.trials >= self.trials_per_arm for a in arms):
                    best = min(arms, key=lambda a: a.mean)
                    self._committed[b] = best.config
                return arm.config
        best = min(arms, key=lambda a: a.mean)
        self._committed[b] = best.config
        self.stats["commit"] += 1
        return best.config

    def select_attention(self, sq: int, skv: int, d: int):
        key = (sq, skv, d)
        got = self._attn_cache.get(key)
        if got is not None:
            self._attn_cache.move_to_end(key)
            return got
        if self.prior is not None:
            cfg = self.prior.select_attention(sq, skv, d)
        else:
            from repro.kernels.attention import DEFAULT_ATTN_CONFIG

            cfg = DEFAULT_ATTN_CONFIG
        self._attn_cache[key] = cfg
        if len(self._attn_cache) > self._attn_cache_cap:
            self._attn_cache.popitem(last=False)
        return cfg

    def select_wkv(self, s: int, hd: int):
        """Prior passthrough (online exploration is matmul-only today)."""
        return self._prior_family_select("wkv", "select_wkv", (s, hd))

    def select_ssm(self, s: int, d: int):
        return self._prior_family_select("ssm_scan", "select_ssm", (s, d))

    def _prior_family_select(self, family: str, attr: str, problem: tuple):
        meth = getattr(self.prior, attr, None) if self.prior is not None else None
        if meth is not None:
            return meth(*problem)
        from repro.core.families import get_family

        return get_family(family).default_config

    def select_for_objective(self, family: str, problem: tuple, objective):
        """SLO-aware selection: exploration pauses under a latency target.

        Gambling a decode step on an unmeasured arm is exactly the tail-
        latency spike an SLO forbids, so a constrained selection serves the
        best *measured* arm for the bucket (committed or mid-exploration
        leader); buckets with no evidence yet defer to the prior's
        objective-aware pick (or its plain selection).  Measurements resume
        unchanged once the objective is lifted.
        """
        problem = tuple(problem)
        if family == "matmul":
            b = _bucket(problem)
            hit = self._committed.get(b)
            if hit is not None:
                self.stats["slo_commit"] += 1
                return hit
            measured = [a for a in self._arms.get(b, []) if a.trials > 0]
            if measured:
                self.stats["slo_commit"] += 1
                return min(measured, key=lambda a: a.mean).config
            if self.prior is not None:
                slo = getattr(self.prior, "select_for_objective", None)
                if slo is not None:
                    return slo(family, problem, objective)
                return self.prior.select_matmul(*problem)
            return self.candidates[0]
        if self.prior is not None:
            slo = getattr(self.prior, "select_for_objective", None)
            if slo is not None:
                return slo(family, problem, objective)
        if family == "attention":
            return self.select_attention(*problem)
        from repro.core.families import get_family

        attr = get_family(family).policy_attr
        return self._prior_family_select(family, attr, problem)

    # -- continuous tuning ----------------------------------------------------
    def set_prior(self, prior: object | None) -> None:
        """Hot-swap the offline prior (a new :class:`Deployment` from retune).

        The attention cache memoizes the *previous* prior's answers, so it
        must be invalidated here — otherwise a swapped-in deployment would
        never be consulted for already-seen attention shapes.  Matmul arm
        measurements are kept: they are real timings, still valid evidence;
        only the not-yet-explored buckets pick up the new prior's ordering.
        """
        self.prior = prior
        self._attn_cache.clear()

    def measurements(self) -> dict[tuple, list[tuple[MatmulConfig, float, int]]]:
        """Per-bucket measured arms: ``{bucket: [(config, mean_s, trials)]}``.

        The telemetry snapshot (``repro.core.retune``) folds these observed
        config timings in next to the selection-log shape histogram.
        """
        out: dict[tuple, list[tuple[MatmulConfig, float, int]]] = {}
        for b, arms in self._arms.items():
            rows = [(a.config, a.mean, a.trials) for a in arms if a.trials > 0]
            if rows:
                out[b] = rows
        return out

    # -- introspection ---------------------------------------------------------
    def warmup_cost(self) -> float:
        """Total seconds spent in exploration measurements so far."""
        return sum(a.total_time for arms in self._arms.values() for a in arms)

    def committed(self) -> dict[tuple, MatmulConfig]:
        return dict(self._committed)


def wall_clock_measure(run: Callable[[], None], reps: int = 3) -> float:
    """Median wall time of ``run`` — the real-hardware measurement hook."""
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
