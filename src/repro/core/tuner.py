"""End-to-end auto-tuning pipeline (the paper, as one function).

``tune()`` = collect benchmark table -> normalize -> cluster-select the
deployable kernel subset -> train the runtime classifier -> emit the
:class:`Deployment` artifact that ``repro.kernels.ops`` consumes.

Fully automated: given a benchmark data source for a new device, no developer
effort or expertise is needed (paper abstract) — this is the function a
framework operator runs when bringing up new hardware.

Every kernel family registered in ``repro.core.families`` rides the same
pipeline: the matmul family anchors the Deployment (its dataset is the
caller-supplied benchmark table), and :func:`tune_family` runs the identical
prune+classify loop for each other registered family (attention, wkv,
ssm_scan, and anything registered later) from its declared harvest + perf
model.  A new op needs only a ``register_family`` call to get tuned artifacts,
serving dispatch, telemetry, and retuning for free.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from .cluster import select_configs
from .dataset import TuningDataset, build_model_dataset, harvest_problems
from .dispatch import Deployment, classifier_fraction, train_deployment
from .families import KernelFamily, family_names, get_family
from .normalize import normalize
from .selection import achievable_fraction, geomean_fraction, select_from_dataset


@dataclasses.dataclass
class TuneResult:
    deployment: Deployment
    chosen: list[int]
    oracle_fraction: float  # best-achievable with the deployed subset
    classifier_fraction: float  # what the shipped classifier actually attains
    train: TuningDataset
    test: TuningDataset
    family_results: dict[str, "FamilyTuneResult"] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FamilyTuneResult:
    """One non-matmul family through the prune+classify pipeline."""

    family: str
    configs: list
    tree: object
    problems: list[tuple]
    oracle_fraction: float
    classifier_fraction: float

    # tuple-compat: ``configs, tree = tune_family(...)`` keeps working.
    def __iter__(self):
        return iter((self.configs, self.tree))


def tune_family(
    name: str | KernelFamily,
    arch_ids: list[str] | None = None,
    *,
    n_kernels: int | None = None,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    seed: int = 0,
    device_name: str | None = None,
    problems: list[tuple] | None = None,
) -> FamilyTuneResult:
    """Prune + classify one registered kernel family (the paper pipeline).

    Works for any family whose registry entry declares a harvest and a perf
    model; ``problems`` overrides the harvest (e.g. a retune's live shapes).
    """
    fam = name if isinstance(name, KernelFamily) else get_family(name)
    if fam.name == "matmul":
        raise ValueError("the matmul family is tuned via tune()/tune_for_archs")
    space = list(fam.config_space())
    problems = list(problems if problems is not None else fam.harvest(arch_ids))
    if not problems:
        raise ValueError(f"no benchmark problems harvested for family {fam.name!r}")
    perf = fam.perf_matrix(problems, space, device_name)
    norm = normalize(perf, normalization)
    feats = fam.features(problems)
    k = min(n_kernels or fam.default_n_kernels, len(space))
    chosen = select_configs(norm, k, method, features=feats, seed=seed)
    labels = perf[:, chosen].argmax(axis=1)
    tree = fam.make_tree().fit(feats, labels)
    pred = np.clip(tree.predict(feats), 0, len(chosen) - 1)
    picked = perf[np.arange(len(problems)), [chosen[i] for i in pred]]
    return FamilyTuneResult(
        family=fam.name,
        configs=[space[i] for i in chosen],
        tree=tree,
        problems=problems,
        oracle_fraction=achievable_fraction(perf, chosen),
        classifier_fraction=geomean_fraction(picked, perf.max(axis=1)),
    )


def tune(
    dataset: TuningDataset,
    *,
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    test_fraction: float = 0.25,
    seed: int = 0,
    arch_ids: list[str] | None = None,
    attn_arch_ids: list[str] | None = None,
    n_attn_kernels: int = 4,
    attn_tuning: tuple | None = None,
    families: list[str] | None = None,
    family_tunings: dict[str, "FamilyTuneResult | tuple"] | None = None,
) -> TuneResult:
    """Run the full paper pipeline on a benchmark dataset — for every family.

    ``arch_ids`` scopes EVERY non-matmul family's problem harvest (None =
    all registered architectures); a family none of those archs launch is
    skipped and serves its reference default.  ``attn_arch_ids`` is the
    pre-registry spelling of the same scope, kept as an alias.  ``families``
    selects which registered non-matmul families to tune (default: all of
    them); ``family_tunings`` supplies precomputed
    :class:`FamilyTuneResult`\\ s (or bare ``(configs, tree)`` tuples) —
    ``tune_fleet`` shares device-insensitive tunings across devices this
    way.  ``attn_tuning`` is the attention-only legacy spelling of the same.
    """
    from .retune import train_distribution

    train, test = dataset.split(test_fraction=test_fraction, seed=seed)
    chosen = select_from_dataset(train, n_kernels, method, normalization, seed=seed)
    deployment = train_deployment(
        train,
        chosen,
        classifier,
        meta={
            "method": method,
            "normalization": normalization,
            "n_kernels": n_kernels,
            "seed": seed,
            "source": dataset.source,
            # Provenance for the continuous tuning loop (DESIGN.md §8): the
            # shape distribution this artifact was tuned against, so a
            # serving host can detect when live traffic drifts away from it.
            "train_distribution": train_distribution(train.problems),
        },
    )
    # Every other registered family through the same pipeline (the paper's
    # future-work direction, generalized): attention, wkv, ssm_scan, ...
    precomputed = dict(family_tunings or {})
    if attn_tuning is not None:
        precomputed.setdefault("attention", attn_tuning)
    harvest_archs = arch_ids if arch_ids is not None else attn_arch_ids
    wanted = [f for f in (families if families is not None else family_names()) if f != "matmul"]
    family_results: dict[str, FamilyTuneResult] = {}
    family_dists: dict[str, dict] = {}
    for fname in wanted:
        got = precomputed.get(fname)
        if got is None:
            fam = get_family(fname)
            probs = fam.harvest(harvest_archs)
            if not probs:
                continue  # none of the assigned archs launch this op: stays untuned
            got = tune_family(
                fname, problems=probs, method=method, normalization=normalization,
                seed=seed, n_kernels=n_attn_kernels if fname == "attention" else None,
                # Device-insensitive families tune against their single model
                # target everywhere (tune, fleet sharing, AND retune use the
                # same perf surface); device-sensitive ones follow the dataset.
                device_name=dataset.device if fam.device_sensitive else None,
            )
        if isinstance(got, FamilyTuneResult):
            deployment.set_family_tuning(fname, got.configs, got.tree)
            family_results[fname] = got
            family_dists[fname] = train_distribution(got.problems)
        else:  # bare (configs, tree): no problem list, so no provenance
            configs, tree = got
            deployment.set_family_tuning(fname, list(configs), tree)
    if family_dists:
        deployment.meta["family_distributions"] = family_dists
    return TuneResult(
        deployment=deployment,
        chosen=chosen,
        oracle_fraction=achievable_fraction(test.perf, chosen),
        classifier_fraction=classifier_fraction(test, chosen, deployment),
        train=train,
        test=test,
        family_results=family_results,
    )


def tune_attention(
    arch_ids: list[str] | None = None,
    *,
    n_kernels: int = 4,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    seed: int = 0,
):
    """Prune + classify the flash-attention family (registry shim).

    Returns ``(configs, tree)`` like it always has; the generic
    :func:`tune_family` is the implementation.
    """
    res = tune_family(
        "attention", arch_ids, n_kernels=n_kernels, method=method,
        normalization=normalization, seed=seed,
    )
    return res.configs, res.tree


def tune_for_archs(
    arch_ids: list[str] | None = None,
    *,
    device_name: str = "tpu_v5e",
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    max_problems: int | None = 400,
    seed: int = 0,
    attn_tuning: tuple | None = None,
    families: list[str] | None = None,
    family_tunings: dict | None = None,
) -> TuneResult:
    """Tune against the GEMM shapes the assigned architectures will launch."""
    problems = harvest_problems(arch_ids, max_problems=max_problems)
    ds = build_model_dataset(problems, device_name=device_name)
    return tune(
        ds,
        n_kernels=n_kernels,
        method=method,
        normalization=normalization,
        classifier=classifier,
        seed=seed,
        arch_ids=arch_ids,
        attn_tuning=attn_tuning,
        families=families,
        family_tunings=family_tunings,
    )


def save_result(result: TuneResult, path: str | Path) -> None:
    result.deployment.meta.update(
        oracle_fraction=result.oracle_fraction,
        classifier_fraction=result.classifier_fraction,
    )
    result.deployment.save(path)


# ---------------------------------------------------------------------------
# fleet tuning: several devices, one bundle (the deploy-anywhere artifact)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FleetTuneResult:
    """Per-device tuning runs packed into one multi-device bundle."""

    bundle: "object"  # DeploymentBundle (forward ref; bundle imports tuner-adjacent code)
    results: dict[str, TuneResult]


def tune_fleet(
    arch_ids: list[str] | None = None,
    *,
    device_names: tuple[str, ...] = ("tpu_v5e", "tpu_v4"),
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    max_problems: int | None = 400,
    cpu_problems: int = 8,
    seed: int = 0,
    families: list[str] | None = None,
) -> FleetTuneResult:
    """Tune every device in one run and pack a :class:`DeploymentBundle`.

    Each ``device_name`` gets the full single-device pipeline (``host_cpu``
    measures this host via ``repro.core.cpubench``; analytic-model devices go
    through :func:`tune_for_archs`), and the resulting per-device
    ``Deployment``\\ s become one versioned artifact a serving host installs
    with ``repro.core.bundle.install_bundle``.  Device-insensitive families
    (attention, wkv, ssm_scan — their perf models have one target) are tuned
    once and shared across the fleet.
    """
    from .bundle import DeploymentBundle
    from .devices import canonical_device_name

    if not device_names:
        raise ValueError("tune_fleet needs at least one device name")
    wanted = [f for f in (families if families is not None else family_names()) if f != "matmul"]
    shared: dict[str, FamilyTuneResult] = {}
    for fname in wanted:
        if get_family(fname).device_sensitive:
            continue
        probs = get_family(fname).harvest(arch_ids)
        if probs:
            shared[fname] = tune_family(
                fname, problems=probs, method=method, normalization=normalization, seed=seed
            )
    results: dict[str, TuneResult] = {}
    for raw_name in device_names:
        name = canonical_device_name(raw_name)
        if name in results:
            continue
        if name == "host_cpu":
            from .cpubench import build_cpu_dataset
            from .cpubench import cpu_problems as cpu_problem_list

            ds = build_cpu_dataset(cpu_problem_list(cpu_problems))
            res = tune(
                ds, n_kernels=n_kernels, method=method, normalization=normalization,
                classifier=classifier, seed=seed, arch_ids=arch_ids,
                families=wanted, family_tunings=shared,
            )
        else:
            res = tune_for_archs(
                arch_ids, device_name=name, n_kernels=n_kernels, method=method,
                normalization=normalization, classifier=classifier,
                max_problems=max_problems, seed=seed, families=wanted,
                family_tunings=shared,
            )
        res.deployment.meta.update(
            oracle_fraction=res.oracle_fraction,
            classifier_fraction=res.classifier_fraction,
        )
        results[name] = res
    bundle = DeploymentBundle(
        deployments={name: r.deployment for name, r in results.items()},
        meta={
            "devices": sorted(results),
            "archs": list(arch_ids) if arch_ids else "all",
            "families": ["matmul", *wanted],
            "n_kernels": n_kernels,
            "method": method,
            "normalization": normalization,
            "seed": seed,
        },
    )
    return FleetTuneResult(bundle=bundle, results=results)


def save_fleet(result: FleetTuneResult, path: str | Path) -> None:
    result.bundle.save(path)
