"""End-to-end auto-tuning pipeline (the paper, as one function).

``tune()`` = collect benchmark table -> normalize -> cluster-select the
deployable kernel subset -> train the runtime classifier -> emit the
:class:`Deployment` artifact that ``repro.kernels.ops`` consumes.

Fully automated: given a benchmark data source for a new device, no developer
effort or expertise is needed (paper abstract) — this is the function a
framework operator runs when bringing up new hardware.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from repro.kernels.attention import attention_config_space

from .dataset import TuningDataset, build_model_dataset, harvest_problems
from .dispatch import Deployment, classifier_fraction, train_deployment
from .selection import achievable_fraction, select_from_dataset


@dataclasses.dataclass
class TuneResult:
    deployment: Deployment
    chosen: list[int]
    oracle_fraction: float  # best-achievable with the deployed subset
    classifier_fraction: float  # what the shipped classifier actually attains
    train: TuningDataset
    test: TuningDataset


def tune(
    dataset: TuningDataset,
    *,
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    test_fraction: float = 0.25,
    seed: int = 0,
    attn_arch_ids: list[str] | None = None,
    n_attn_kernels: int = 4,
) -> TuneResult:
    """Run the full paper pipeline on a benchmark dataset."""
    train, test = dataset.split(test_fraction=test_fraction, seed=seed)
    chosen = select_from_dataset(train, n_kernels, method, normalization, seed=seed)
    deployment = train_deployment(
        train,
        chosen,
        classifier,
        meta={
            "method": method,
            "normalization": normalization,
            "n_kernels": n_kernels,
            "seed": seed,
            "source": dataset.source,
        },
    )
    # Second kernel family (the paper's future-work direction): the same
    # pipeline prunes + classifies the flash-attention config space.
    configs, tree = tune_attention(
        arch_ids=attn_arch_ids, n_kernels=n_attn_kernels, method=method,
        normalization=normalization, seed=seed,
    )
    deployment.attention_configs = configs
    deployment.attention_tree = tree
    return TuneResult(
        deployment=deployment,
        chosen=chosen,
        oracle_fraction=achievable_fraction(test.perf, chosen),
        classifier_fraction=classifier_fraction(test, chosen, deployment),
        train=train,
        test=test,
    )


def tune_attention(
    arch_ids: list[str] | None = None,
    *,
    n_kernels: int = 4,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    seed: int = 0,
):
    """Prune + classify the flash-attention family (same paper pipeline)."""
    from .attnmodel import (
        attn_problem_features,
        build_attn_matrix,
        harvest_attn_problems,
    )
    from .classify import DecisionTreeClassifier
    from .cluster import select_configs
    from .normalize import normalize

    space = list(attention_config_space())
    problems = harvest_attn_problems(arch_ids)
    perf = build_attn_matrix(problems, space)
    norm = normalize(perf, normalization)
    feats = attn_problem_features(problems)
    n_kernels = min(n_kernels, len(space))
    chosen = select_configs(norm, n_kernels, method, features=feats, seed=seed)
    labels = perf[:, chosen].argmax(axis=1)
    tree = DecisionTreeClassifier(max_depth=6, min_samples_leaf=1).fit(feats, labels)
    return [space[i] for i in chosen], tree


def tune_for_archs(
    arch_ids: list[str] | None = None,
    *,
    device_name: str = "tpu_v5e",
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    max_problems: int | None = 400,
    seed: int = 0,
) -> TuneResult:
    """Tune against the GEMM shapes the assigned architectures will launch."""
    problems = harvest_problems(arch_ids, max_problems=max_problems)
    ds = build_model_dataset(problems, device_name=device_name)
    return tune(
        ds,
        n_kernels=n_kernels,
        method=method,
        normalization=normalization,
        classifier=classifier,
        seed=seed,
        attn_arch_ids=arch_ids,
    )


def save_result(result: TuneResult, path: str | Path) -> None:
    result.deployment.meta.update(
        oracle_fraction=result.oracle_fraction,
        classifier_fraction=result.classifier_fraction,
    )
    result.deployment.save(path)
