"""End-to-end auto-tuning pipeline (the paper, as one function).

``tune()`` = collect benchmark table -> normalize -> cluster-select the
deployable kernel subset -> train the runtime classifier -> emit the
:class:`Deployment` artifact that ``repro.kernels.ops`` consumes.

Fully automated: given a benchmark data source for a new device, no developer
effort or expertise is needed (paper abstract) — this is the function a
framework operator runs when bringing up new hardware.

Every kernel family registered in ``repro.core.families`` rides the same
pipeline: the matmul family anchors the Deployment (its dataset is the
caller-supplied benchmark table), and :func:`tune_family` runs the identical
prune+classify loop for each other registered family (attention, wkv,
ssm_scan, and anything registered later) from its declared harvest + perf
model.  A new op needs only a ``register_family`` call to get tuned artifacts,
serving dispatch, telemetry, and retuning for free.

Since the staged-pipeline refactor (DESIGN.md §12) the implementation lives
in ``repro.core.pipeline`` — candidate generation, model-guided pruning,
transfer warm-start, measurement planning, cluster-select, and classify are
separate composable stages.  The functions here are the stable entry points:
``tune()``'s signature is unchanged, and ``tune_family`` / ``tune_for_archs``
/ ``tune_fleet`` grew the stage knobs (``prune_ratio``, ``measure_budget``,
``transfer_from`` / ``transfer``).
"""
from __future__ import annotations

import dataclasses
import warnings
from pathlib import Path

from .dataset import TuningDataset, build_model_dataset, harvest_problems
from .dispatch import Deployment
from .families import KernelFamily, family_names, get_family


@dataclasses.dataclass
class TuneResult:
    deployment: Deployment
    chosen: list[int]
    oracle_fraction: float  # best-achievable with the deployed subset
    classifier_fraction: float  # what the shipped classifier actually attains
    train: TuningDataset
    test: TuningDataset
    family_results: dict[str, "FamilyTuneResult"] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FamilyTuneResult:
    """One non-matmul family through the prune+classify pipeline.

    ``lineage`` is the staged pipeline's cost record (source device, prune
    ratio, measured fraction, model error) — ``None`` for results built
    outside ``repro.core.pipeline``.
    """

    family: str
    configs: list
    tree: object
    problems: list[tuple]
    oracle_fraction: float
    classifier_fraction: float
    lineage: dict | None = None

    # Deprecated tuple-compat: ``configs, tree = tune_family(...)``.  Warns
    # for one release (use ``.configs`` / ``.tree``); removed next release.
    def __iter__(self):
        warnings.warn(
            "tuple-unpacking FamilyTuneResult is deprecated; use the "
            ".configs / .tree fields (shim removed next release)",
            DeprecationWarning,
            stacklevel=2,
        )
        return iter((self.configs, self.tree))


def tune_family(
    name: str | KernelFamily,
    arch_ids: list[str] | None = None,
    *,
    n_kernels: int | None = None,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    seed: int = 0,
    device_name: str | None = None,
    problems: list[tuple] | None = None,
    prune_ratio: float | None = None,
    measure_budget: float | str | None = None,
    transfer_from=None,
) -> FamilyTuneResult:
    """Prune + classify one registered kernel family (the paper pipeline).

    Works for any family whose registry entry declares a harvest and a perf
    model; ``problems`` overrides the harvest (e.g. a retune's live shapes).
    Implemented as ``pipeline.run_family_pipeline``; ``prune_ratio`` /
    ``measure_budget`` / ``transfer_from`` are its stage knobs (defaults =
    the legacy full-harvest tune, bit-for-bit).
    """
    fam = name if isinstance(name, KernelFamily) else get_family(name)
    if fam.name == "matmul":
        raise ValueError("the matmul family is tuned via tune()/tune_for_archs")
    from .pipeline import run_family_pipeline

    return run_family_pipeline(
        fam,
        arch_ids,
        problems=problems,
        device_name=device_name,
        n_kernels=n_kernels,
        method=method,
        normalization=normalization,
        seed=seed,
        prune_ratio=prune_ratio,
        measure_budget=measure_budget,
        transfer_from=transfer_from,
    ).to_family_result()


def tune(
    dataset: TuningDataset,
    *,
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    test_fraction: float = 0.25,
    seed: int = 0,
    arch_ids: list[str] | None = None,
    attn_arch_ids: list[str] | None = None,
    n_attn_kernels: int = 4,
    attn_tuning: tuple | None = None,
    families: list[str] | None = None,
    family_tunings: dict[str, "FamilyTuneResult | tuple"] | None = None,
) -> TuneResult:
    """Run the full paper pipeline on a benchmark dataset — for every family.

    ``arch_ids`` scopes EVERY non-matmul family's problem harvest (None =
    all registered architectures); a family none of those archs launch is
    skipped and serves its reference default.  ``attn_arch_ids`` is the
    pre-registry spelling of the same scope, kept as an alias.  ``families``
    selects which registered non-matmul families to tune (default: all of
    them); ``family_tunings`` supplies precomputed
    :class:`FamilyTuneResult`\\ s (or bare ``(configs, tree)`` tuples) —
    ``tune_fleet`` shares device-insensitive tunings across devices this
    way.  ``attn_tuning`` is the attention-only legacy spelling of the same.

    Implemented by ``pipeline.tune_dataset`` (the staged pipeline with every
    stage knob at its default, which reproduces the legacy monolith exactly);
    call that directly for transfer warm-starts and prune/measure budgets.
    """
    from .pipeline import tune_dataset

    return tune_dataset(
        dataset,
        n_kernels=n_kernels,
        method=method,
        normalization=normalization,
        classifier=classifier,
        test_fraction=test_fraction,
        seed=seed,
        arch_ids=arch_ids,
        attn_arch_ids=attn_arch_ids,
        n_attn_kernels=n_attn_kernels,
        attn_tuning=attn_tuning,
        families=families,
        family_tunings=family_tunings,
    )


def tune_attention(
    arch_ids: list[str] | None = None,
    *,
    n_kernels: int = 4,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    seed: int = 0,
):
    """Prune + classify the flash-attention family (registry shim).

    Returns ``(configs, tree)`` like it always has; the generic
    :func:`tune_family` is the implementation.
    """
    res = tune_family(
        "attention", arch_ids, n_kernels=n_kernels, method=method,
        normalization=normalization, seed=seed,
    )
    return res.configs, res.tree


def tune_for_archs(
    arch_ids: list[str] | None = None,
    *,
    device_name: str = "tpu_v5e",
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    max_problems: int | None = 400,
    seed: int = 0,
    attn_tuning: tuple | None = None,
    families: list[str] | None = None,
    family_tunings: dict | None = None,
    transfer_from=None,
    prune_ratio: float | None = None,
    measure_budget: float | str | None = None,
) -> TuneResult:
    """Tune against the GEMM shapes the assigned architectures will launch.

    With any staged-pipeline knob set (``transfer_from`` — anything
    ``pipeline.as_transfer_prior`` accepts, e.g. a tuned sibling's
    ``TuneResult`` or ``Deployment``; ``prune_ratio``; ``measure_budget``)
    the matmul table comes from ``pipeline.staged_matmul_dataset`` — pruned,
    measured only where model and donor disagree, model-filled elsewhere —
    and the tuning lineage is stamped into the deployment.  All-defaults is
    the legacy full-harvest tune, bit-for-bit.  ``measure_budget="auto"``
    sizes the budget from the donor's recorded ``tuning_lineage.model_error``
    (``pipeline.resolve_measure_budget``): no donor measures in full.
    """
    from .pipeline import resolve_measure_budget, staged_matmul_dataset, tune_dataset

    measure_budget = resolve_measure_budget(measure_budget, transfer_from)
    problems = harvest_problems(arch_ids, max_problems=max_problems)
    staged = (
        transfer_from is not None
        or (prune_ratio is not None and 0.0 < prune_ratio < 1.0)
        or (measure_budget is not None and 0.0 < measure_budget < 1.0)
    )
    lineage = None
    donor = transfer_from
    if staged:
        ds, matmul_lineage, donor = staged_matmul_dataset(
            problems,
            device_name,
            prune_ratio=prune_ratio,
            measure_budget=measure_budget,
            transfer_from=transfer_from,
        )
        lineage = {"matmul": matmul_lineage}
    else:
        ds = build_model_dataset(problems, device_name=device_name)
    return tune_dataset(
        ds,
        n_kernels=n_kernels,
        method=method,
        normalization=normalization,
        classifier=classifier,
        seed=seed,
        arch_ids=arch_ids,
        attn_tuning=attn_tuning,
        families=families,
        family_tunings=family_tunings,
        transfer_from=donor,
        prune_ratio=prune_ratio,
        measure_budget=measure_budget,
        lineage=lineage,
    )


def save_result(result: TuneResult, path: str | Path) -> None:
    result.deployment.meta.update(
        oracle_fraction=result.oracle_fraction,
        classifier_fraction=result.classifier_fraction,
    )
    result.deployment.save(path)


# ---------------------------------------------------------------------------
# fleet tuning: several devices, one bundle (the deploy-anywhere artifact)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FleetTuneResult:
    """Per-device tuning runs packed into one multi-device bundle."""

    bundle: "object"  # DeploymentBundle (forward ref; bundle imports tuner-adjacent code)
    results: dict[str, TuneResult]


def tune_fleet(
    arch_ids: list[str] | None = None,
    *,
    device_names: tuple[str, ...] = ("tpu_v5e", "tpu_v4"),
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    max_problems: int | None = 400,
    cpu_problems: int = 8,
    seed: int = 0,
    families: list[str] | None = None,
    transfer: bool = False,
    prune_ratio: float | None = None,
    measure_budget: float | str | None = None,
) -> FleetTuneResult:
    """Tune every device in one run and pack a :class:`DeploymentBundle`.

    Each ``device_name`` gets the full single-device pipeline (``host_cpu``
    measures this host via ``repro.core.cpubench``; analytic-model devices go
    through :func:`tune_for_archs`), and the resulting per-device
    ``Deployment``\\ s become one versioned artifact a serving host installs
    with ``repro.core.bundle.install_bundle``.  Device-insensitive families
    (attention, wkv, ssm_scan — their perf models have one target) are tuned
    once and shared across the fleet.

    Devices tune in ``devices.transfer_order`` — donors before the siblings
    that can warm-start off them — so with ``transfer=True`` each TPU device
    after the first full-tunes only where the model and its nearest tuned
    sibling (``devices.transfer_donor``) disagree; ``prune_ratio`` /
    ``measure_budget`` apply to every staged tune including the shared
    family tunings.  ``measure_budget="auto"`` sizes each device's budget
    from its donor's recorded lineage ``model_error`` (the bring-up root and
    donor-less tunes measure in full).  ``host_cpu`` always measures from
    scratch (a sibling TPU's tuning says nothing about this host's cache
    hierarchy).
    """
    from .bundle import DeploymentBundle
    from .devices import canonical_device_name, transfer_donor, transfer_order

    if not device_names:
        raise ValueError("tune_fleet needs at least one device name")
    wanted = [f for f in (families if families is not None else family_names()) if f != "matmul"]
    shared: dict[str, FamilyTuneResult] = {}
    for fname in wanted:
        if get_family(fname).device_sensitive:
            continue
        probs = get_family(fname).harvest(arch_ids)
        if probs:
            shared[fname] = tune_family(
                fname, problems=probs, method=method, normalization=normalization, seed=seed,
                prune_ratio=prune_ratio, measure_budget=measure_budget,
            )
    results: dict[str, TuneResult] = {}
    for name in transfer_order([canonical_device_name(n) for n in device_names]):
        if name in results:
            continue
        if name == "host_cpu":
            from .cpubench import build_cpu_dataset
            from .cpubench import cpu_problems as cpu_problem_list

            ds = build_cpu_dataset(cpu_problem_list(cpu_problems))
            res = tune(
                ds, n_kernels=n_kernels, method=method, normalization=normalization,
                classifier=classifier, seed=seed, arch_ids=arch_ids,
                families=wanted, family_tunings=shared,
            )
        else:
            donor = None
            if transfer:
                donor_name = transfer_donor(name, [d for d in results if d != "host_cpu"])
                donor = results[donor_name] if donor_name is not None else None
            res = tune_for_archs(
                arch_ids, device_name=name, n_kernels=n_kernels, method=method,
                normalization=normalization, classifier=classifier,
                max_problems=max_problems, seed=seed, families=wanted,
                family_tunings=shared, transfer_from=donor,
                prune_ratio=prune_ratio, measure_budget=measure_budget,
            )
        res.deployment.meta.update(
            oracle_fraction=res.oracle_fraction,
            classifier_fraction=res.classifier_fraction,
        )
        results[name] = res
    meta = {
        "devices": sorted(results),
        "archs": list(arch_ids) if arch_ids else "all",
        "families": ["matmul", *wanted],
        "n_kernels": n_kernels,
        "method": method,
        "normalization": normalization,
        "seed": seed,
    }
    if transfer:
        meta["transfer"] = True
    bundle = DeploymentBundle(
        deployments={name: r.deployment for name, r in results.items()},
        meta=meta,
    )
    return FleetTuneResult(bundle=bundle, results=results)


def save_fleet(result: FleetTuneResult, path: str | Path) -> None:
    result.bundle.save(path)
