"""End-to-end auto-tuning pipeline (the paper, as one function).

``tune()`` = collect benchmark table -> normalize -> cluster-select the
deployable kernel subset -> train the runtime classifier -> emit the
:class:`Deployment` artifact that ``repro.kernels.ops`` consumes.

Fully automated: given a benchmark data source for a new device, no developer
effort or expertise is needed (paper abstract) — this is the function a
framework operator runs when bringing up new hardware.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from repro.kernels.attention import attention_config_space

from .dataset import TuningDataset, build_model_dataset, harvest_problems
from .dispatch import Deployment, classifier_fraction, train_deployment
from .selection import achievable_fraction, select_from_dataset


@dataclasses.dataclass
class TuneResult:
    deployment: Deployment
    chosen: list[int]
    oracle_fraction: float  # best-achievable with the deployed subset
    classifier_fraction: float  # what the shipped classifier actually attains
    train: TuningDataset
    test: TuningDataset


def tune(
    dataset: TuningDataset,
    *,
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    test_fraction: float = 0.25,
    seed: int = 0,
    attn_arch_ids: list[str] | None = None,
    n_attn_kernels: int = 4,
    attn_tuning: tuple | None = None,
) -> TuneResult:
    """Run the full paper pipeline on a benchmark dataset.

    ``attn_tuning`` optionally supplies a precomputed ``(configs, tree)``
    attention tuning (``tune_fleet`` shares one across devices instead of
    recomputing an identical result per device).
    """
    train, test = dataset.split(test_fraction=test_fraction, seed=seed)
    chosen = select_from_dataset(train, n_kernels, method, normalization, seed=seed)
    from .retune import train_distribution

    deployment = train_deployment(
        train,
        chosen,
        classifier,
        meta={
            "method": method,
            "normalization": normalization,
            "n_kernels": n_kernels,
            "seed": seed,
            "source": dataset.source,
            # Provenance for the continuous tuning loop (DESIGN.md §8): the
            # shape distribution this artifact was tuned against, so a
            # serving host can detect when live traffic drifts away from it.
            "train_distribution": train_distribution(train.problems),
        },
    )
    # Second kernel family (the paper's future-work direction): the same
    # pipeline prunes + classifies the flash-attention config space.
    if attn_tuning is None:
        attn_tuning = tune_attention(
            arch_ids=attn_arch_ids, n_kernels=n_attn_kernels, method=method,
            normalization=normalization, seed=seed,
        )
    configs, tree = attn_tuning
    deployment.attention_configs = configs
    deployment.attention_tree = tree
    return TuneResult(
        deployment=deployment,
        chosen=chosen,
        oracle_fraction=achievable_fraction(test.perf, chosen),
        classifier_fraction=classifier_fraction(test, chosen, deployment),
        train=train,
        test=test,
    )


def tune_attention(
    arch_ids: list[str] | None = None,
    *,
    n_kernels: int = 4,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    seed: int = 0,
):
    """Prune + classify the flash-attention family (same paper pipeline)."""
    from .attnmodel import (
        attn_problem_features,
        build_attn_matrix,
        harvest_attn_problems,
    )
    from .classify import DecisionTreeClassifier
    from .cluster import select_configs
    from .normalize import normalize

    space = list(attention_config_space())
    problems = harvest_attn_problems(arch_ids)
    perf = build_attn_matrix(problems, space)
    norm = normalize(perf, normalization)
    feats = attn_problem_features(problems)
    n_kernels = min(n_kernels, len(space))
    chosen = select_configs(norm, n_kernels, method, features=feats, seed=seed)
    labels = perf[:, chosen].argmax(axis=1)
    tree = DecisionTreeClassifier(max_depth=6, min_samples_leaf=1).fit(feats, labels)
    return [space[i] for i in chosen], tree


def tune_for_archs(
    arch_ids: list[str] | None = None,
    *,
    device_name: str = "tpu_v5e",
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    max_problems: int | None = 400,
    seed: int = 0,
    attn_tuning: tuple | None = None,
) -> TuneResult:
    """Tune against the GEMM shapes the assigned architectures will launch."""
    problems = harvest_problems(arch_ids, max_problems=max_problems)
    ds = build_model_dataset(problems, device_name=device_name)
    return tune(
        ds,
        n_kernels=n_kernels,
        method=method,
        normalization=normalization,
        classifier=classifier,
        seed=seed,
        attn_arch_ids=arch_ids,
        attn_tuning=attn_tuning,
    )


def save_result(result: TuneResult, path: str | Path) -> None:
    result.deployment.meta.update(
        oracle_fraction=result.oracle_fraction,
        classifier_fraction=result.classifier_fraction,
    )
    result.deployment.save(path)


# ---------------------------------------------------------------------------
# fleet tuning: several devices, one bundle (the deploy-anywhere artifact)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FleetTuneResult:
    """Per-device tuning runs packed into one multi-device bundle."""

    bundle: "object"  # DeploymentBundle (forward ref; bundle imports tuner-adjacent code)
    results: dict[str, TuneResult]


def tune_fleet(
    arch_ids: list[str] | None = None,
    *,
    device_names: tuple[str, ...] = ("tpu_v5e", "tpu_v4"),
    n_kernels: int = 8,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    classifier: str = "DecisionTreeA",
    max_problems: int | None = 400,
    cpu_problems: int = 8,
    seed: int = 0,
) -> FleetTuneResult:
    """Tune every device in one run and pack a :class:`DeploymentBundle`.

    Each ``device_name`` gets the full single-device pipeline (``host_cpu``
    measures this host via ``repro.core.cpubench``; analytic-model devices go
    through :func:`tune_for_archs`), and the resulting per-device
    ``Deployment``\\ s become one versioned artifact a serving host installs
    with ``repro.core.bundle.install_bundle``.
    """
    from .bundle import DeploymentBundle
    from .devices import canonical_device_name

    if not device_names:
        raise ValueError("tune_fleet needs at least one device name")
    # The attention tuning is device-independent today (the attn perf model
    # has a single target): compute it once and share across the fleet.
    attn_tuning = tune_attention(
        arch_ids=arch_ids, method=method, normalization=normalization, seed=seed
    )
    results: dict[str, TuneResult] = {}
    for raw_name in device_names:
        name = canonical_device_name(raw_name)
        if name in results:
            continue
        if name == "host_cpu":
            from .cpubench import build_cpu_dataset
            from .cpubench import cpu_problems as cpu_problem_list

            ds = build_cpu_dataset(cpu_problem_list(cpu_problems))
            res = tune(
                ds, n_kernels=n_kernels, method=method, normalization=normalization,
                classifier=classifier, seed=seed, attn_tuning=attn_tuning,
            )
        else:
            res = tune_for_archs(
                arch_ids, device_name=name, n_kernels=n_kernels, method=method,
                normalization=normalization, classifier=classifier,
                max_problems=max_problems, seed=seed, attn_tuning=attn_tuning,
            )
        res.deployment.meta.update(
            oracle_fraction=res.oracle_fraction,
            classifier_fraction=res.classifier_fraction,
        )
        results[name] = res
    bundle = DeploymentBundle(
        deployments={name: r.deployment for name, r in results.items()},
        meta={
            "devices": sorted(results),
            "archs": list(arch_ids) if arch_ids else "all",
            "n_kernels": n_kernels,
            "method": method,
            "normalization": normalization,
            "seed": seed,
        },
    )
    return FleetTuneResult(bundle=bundle, results=results)


def save_fleet(result: FleetTuneResult, path: str | Path) -> None:
    result.bundle.save(path)
