"""Multi-device deployment bundle: one artifact, one Deployment per device.

A single :class:`~repro.core.dispatch.Deployment` is what ships for ONE
device.  The portability story of the paper needs the library to carry the
tuned artifacts for *every* target it may land on and route by detected
hardware — the per-target tuned subsets of the companion study
(arXiv:2003.06795).  :class:`DeploymentBundle` is that carrier:

  * keyed by canonical device name (``repro.core.devices``);
  * serialized as a **v3** blob that embeds the existing v2 (or v1)
    per-device ``Deployment`` blobs verbatim, so single-device tooling keeps
    understanding the payloads;
  * :meth:`DeploymentBundle.load` also accepts a plain v1/v2 single-device
    file and wraps it into a one-entry bundle — every old artifact remains a
    valid (degenerate) bundle;
  * :func:`install_bundle` registers each per-device policy with
    ``repro.kernels.ops`` and activates the one resolved for the detected
    (or requested) device, degrading to the nearest tuned sibling via
    :func:`repro.core.devices.resolve_device`.

Format (DESIGN.md §7-§9)::

    {"version": 5, "format": "bundle",
     "deployments": {"tpu_v5e": {<v5 blob>}, "tpu_v4": {<v5 blob>}, ...},
     "provenance": {"tpu_v5e": {"train_distribution": {...},
                                "family_distributions": {...},
                                "retune_count": 0}, ...},
     "meta": {...}}

v4 added the per-device ``provenance`` block consumed by the continuous
tuning loop (``repro.core.retune``): the shape distribution each deployment
was tuned against plus its retune lineage.  v5 embeds per-device blobs that
carry a per-family section (``repro.core.families``) and extends provenance
with per-family training distributions.  v1-v4 artifacts load unchanged (no
provenance -> drift detection treats all live traffic as unseen; no family
section -> extra families fall back to reference implementations).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from .devices import canonical_device_name, resolve_device
from .dispatch import Deployment

BUNDLE_VERSION = 5

# Deployment.meta keys that form the v4+ top-level provenance block.
_PROVENANCE_KEYS = (
    "train_distribution", "family_distributions", "retune_count", "retune", "retune_log",
)


@dataclasses.dataclass
class DeploymentBundle:
    """Versioned pack of per-device deployments (the deploy-anywhere artifact)."""

    deployments: dict[str, Deployment]
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.deployments:
            raise ValueError("a DeploymentBundle needs at least one deployment")
        # Keys are canonical device slugs; normalize so lookup and tuning-time
        # naming can't drift apart ("TPU v4" and "tpu_v4" are the same entry).
        self.deployments = {
            canonical_device_name(name): dep for name, dep in self.deployments.items()
        }

    # -- access --------------------------------------------------------------
    @property
    def devices(self) -> list[str]:
        return sorted(self.deployments)

    def add(self, deployment: Deployment, device: str | None = None) -> None:
        self.deployments[canonical_device_name(device or deployment.device)] = deployment

    def deployment_for(self, device: str, *, strict: bool = False) -> tuple[Deployment, str]:
        """(deployment, resolved device name) serving ``device``.

        Exact match first, then the nearest-device fallback order of
        ``repro.core.devices.resolve_device``; ``strict=True`` raises
        ``KeyError`` instead of degrading across platform families.
        """
        resolved = resolve_device(device, self.devices, strict=strict)
        if resolved is None:
            raise KeyError(f"no deployment for device {device!r} in bundle {self.devices}")
        return self.deployments[resolved], resolved

    def runtime(self, device: str | None = None, *, strict: bool = False,
                name: str | None = None):
        """A fresh :class:`~repro.core.runtime.KernelRuntime` serving this bundle.

        The multi-tenant entry point: each call builds an isolated runtime
        with this bundle's per-device policies installed and the one resolved
        for ``device`` (default: detected host) activated — two bundles (or
        two calls) can serve different tunings concurrently in one process::

            rt = repro.load_bundle("bundle.json").runtime(device="tpu_v5e")
            engine = rt.serve(model, params)
        """
        from .runtime import KernelRuntime

        rt = KernelRuntime(name=name or f"bundle[{'+'.join(self.devices)}]")
        rt.install_bundle(self, device, strict=strict)
        return rt

    def provenance(self) -> dict[str, dict]:
        """Per-device tuning provenance (the v4+ top-level block).

        Extracted from each deployment's meta; devices tuned before
        provenance existed simply have no entry.
        """
        out: dict[str, dict] = {}
        for name, dep in sorted(self.deployments.items()):
            ent = {k: dep.meta[k] for k in _PROVENANCE_KEYS if k in dep.meta}
            if ent:
                out[name] = ent
        return out

    # -- persistence ---------------------------------------------------------
    def to_blob(self, *, tree_format: str = "flat") -> dict:
        return {
            "version": BUNDLE_VERSION,
            "format": "bundle",
            "deployments": {
                name: dep.to_blob(tree_format=tree_format)
                for name, dep in sorted(self.deployments.items())
            },
            "provenance": self.provenance(),
            "meta": self.meta,
        }

    def save(self, path: str | Path, *, tree_format: str = "flat") -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_blob(tree_format=tree_format), indent=1))

    @staticmethod
    def from_blob(blob: dict) -> "DeploymentBundle":
        """Parse a v3-v5 bundle blob — or wrap a v1/v2/v5 single-device blob."""
        if blob.get("format") == "bundle" or "deployments" in blob:
            version = int(blob.get("version", BUNDLE_VERSION))
            if version > BUNDLE_VERSION:
                raise ValueError(f"bundle version {version} is newer than supported v{BUNDLE_VERSION}")
            deps = {
                name: Deployment.from_blob(sub)
                for name, sub in blob["deployments"].items()
            }
            # v4: reattach the top-level provenance block to each deployment
            # (authoritative for tooling that rewrote it without touching the
            # embedded per-device blobs; older per-device meta wins nothing).
            by_canonical = {canonical_device_name(n): d for n, d in deps.items()}
            for name, ent in (blob.get("provenance") or {}).items():
                dep = by_canonical.get(canonical_device_name(name))
                if dep is not None:
                    dep.meta.update(ent)
            return DeploymentBundle(deployments=deps, meta=blob.get("meta", {}))
        # v1/v2 single-device file: a degenerate one-entry bundle.
        dep = Deployment.from_blob(blob)
        return DeploymentBundle(deployments={dep.device: dep}, meta=dict(dep.meta))

    @staticmethod
    def load(path: str | Path) -> "DeploymentBundle":
        return DeploymentBundle.from_blob(json.loads(Path(path).read_text()))


def install_bundle(
    bundle: "DeploymentBundle | str | Path",
    device: str | None = None,
    *,
    strict: bool = False,
    runtime=None,
) -> Deployment:
    """Install the bundle into a runtime: its policies become the registry.

    ``runtime`` names the target :class:`~repro.core.runtime.KernelRuntime`
    (default: the current — usually the process default — runtime; prefer
    :meth:`DeploymentBundle.runtime` for an isolated handle).  Any previously
    registered per-device policies of that runtime are replaced (installing a
    bundle is authoritative — resolution must agree between the bundle and
    the registry, so stale entries from an earlier install cannot shadow this
    bundle's fallback choice).  ``device=None`` detects the host
    (``REPRO_DEVICE`` override first); an untuned host degrades to the
    nearest tuned sibling rather than the untuned ``FixedPolicy`` baseline.
    Returns the activated ``Deployment``; whether a fallback happened is
    readable from the runtime's ``device_resolution()`` (the shared
    ``Deployment`` objects are never mutated).
    """
    from .runtime import current_runtime

    rt = runtime if runtime is not None else current_runtime()
    return rt.install_bundle(bundle, device, strict=strict)
