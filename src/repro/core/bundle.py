"""Multi-device deployment bundle: one artifact, one Deployment per device.

A single :class:`~repro.core.dispatch.Deployment` is what ships for ONE
device.  The portability story of the paper needs the library to carry the
tuned artifacts for *every* target it may land on and route by detected
hardware — the per-target tuned subsets of the companion study
(arXiv:2003.06795).  :class:`DeploymentBundle` is that carrier:

  * keyed by canonical device name (``repro.core.devices``);
  * serialized as a **v3** blob that embeds the existing v2 (or v1)
    per-device ``Deployment`` blobs verbatim, so single-device tooling keeps
    understanding the payloads;
  * :meth:`DeploymentBundle.load` also accepts a plain v1/v2 single-device
    file and wraps it into a one-entry bundle — every old artifact remains a
    valid (degenerate) bundle;
  * :func:`install_bundle` registers each per-device policy with
    ``repro.kernels.ops`` and activates the one resolved for the detected
    (or requested) device, degrading to the nearest tuned sibling via
    :func:`repro.core.devices.resolve_device`.

Format (DESIGN.md §7-§9)::

    {"version": 5, "format": "bundle",
     "deployments": {"tpu_v5e": {<v5 blob>}, "tpu_v4": {<v5 blob>}, ...},
     "provenance": {"tpu_v5e": {"train_distribution": {...},
                                "family_distributions": {...},
                                "retune_count": 0}, ...},
     "meta": {...}}

v4 added the per-device ``provenance`` block consumed by the continuous
tuning loop (``repro.core.retune``): the shape distribution each deployment
was tuned against plus its retune lineage.  v5 embeds per-device blobs that
carry a per-family section (``repro.core.families``) and extends provenance
with per-family training distributions.  v6 (DESIGN.md §11) adds a
``checksums`` block of per-section CRC32s — one over each device blob's core
(everything but its ``families`` section), one per family section, one over
the provenance block — so bit rot or a truncated upload is detected at load
time and contained at section granularity: a corrupt family section drops
only that family (the op serves its reference path), a corrupt device core
drops only that device (lookups for it recover through the
``devices.FALLBACKS`` chain to the nearest surviving sibling), and only a
bundle with *no* surviving device raises (:class:`BundleIntegrityError`).
Anything dropped is recorded in ``DeploymentBundle.load_errors``.  v1-v5
artifacts load unchanged (no checksums -> nothing to verify; no provenance ->
drift detection treats all live traffic as unseen; no family section ->
extra families fall back to reference implementations).

Malformed input — truncated files, garbage JSON, a blob missing required
sections — raises :class:`BundleFormatError` (a ``ValueError``) carrying the
failing ``section`` and, for JSON syntax errors, the byte ``offset``.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from pathlib import Path

from .devices import canonical_device_name, resolve_device
from .dispatch import Deployment

BUNDLE_VERSION = 6

# Deployment.meta keys that form the v4+ top-level provenance block.
_PROVENANCE_KEYS = (
    "train_distribution", "family_distributions", "retune_count", "retune", "retune_log",
    "tuning_lineage",
)


class BundleError(ValueError):
    """Base of all structured bundle load/validate failures.

    Subclasses ``ValueError`` so pre-v6 callers catching ``ValueError``
    around ``DeploymentBundle.load`` keep working.
    """


class BundleFormatError(BundleError):
    """The blob is structurally unreadable (truncated, garbage, missing keys).

    ``section`` names the part of the blob being parsed when the failure hit
    (``None`` for whole-file errors); ``offset`` is the byte offset for JSON
    syntax errors (``None`` otherwise).
    """

    def __init__(self, message: str, *, section: str | None = None,
                 offset: int | None = None):
        at = []
        if section is not None:
            at.append(f"section={section!r}")
        if offset is not None:
            at.append(f"offset={offset}")
        super().__init__(f"{message} [{', '.join(at)}]" if at else message)
        self.section = section
        self.offset = offset


class BundleIntegrityError(BundleError):
    """Checksum verification left nothing servable (every device dropped)."""


def parse_registry_uri(uri: str) -> tuple[str, str, str]:
    """Split ``registry://host:port/name[/version]`` into (base_url, name, version).

    ``base_url`` is the plain HTTP root of the control-plane service;
    ``version`` defaults to ``"latest"``.
    """
    rest = uri[len("registry://"):]
    netloc, _, tail = rest.partition("/")
    parts = [p for p in tail.split("/") if p]
    if not netloc or not parts or len(parts) > 2:
        raise BundleFormatError(
            f"malformed registry URI {uri!r} "
            "(expected registry://host:port/name[/version])", section="uri")
    name = parts[0]
    version = parts[1] if len(parts) == 2 else "latest"
    return f"http://{netloc}", name, version


def _fetch_uri(uri: str) -> str:
    """GET a bundle (or registry envelope) over HTTP; registry:// resolves first."""
    import urllib.error
    import urllib.request

    if uri.startswith("registry://"):
        base, name, version = parse_registry_uri(uri)
        url = f"{base}/artifacts/{name}/{version}"
    else:
        url = uri
    try:
        with urllib.request.urlopen(url, timeout=30.0) as resp:
            return resp.read().decode("utf-8")
    except urllib.error.HTTPError as e:
        raise BundleFormatError(
            f"registry fetch of {uri} failed: HTTP {e.code} {e.reason}",
            section="uri") from e
    except (urllib.error.URLError, OSError, TimeoutError) as e:
        raise BundleFormatError(
            f"registry fetch of {uri} failed: {e}", section="uri") from e


def _section_checksum(obj) -> str:
    """CRC32 over the section's canonical JSON, as 8 hex chars."""
    payload = json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()
    return f"{zlib.crc32(payload) & 0xFFFFFFFF:08x}"


def _blob_checksums(deployments_blob: dict, provenance: dict) -> dict[str, str]:
    """The v6 ``checksums`` block for a serialized bundle.

    Keys: ``deployments.<dev>`` (device blob core, families excluded),
    ``deployments.<dev>.families.<fam>`` (one per family section), and
    ``provenance`` (the whole block).
    """
    sums: dict[str, str] = {}
    for name, sub in deployments_blob.items():
        core = {k: v for k, v in sub.items() if k != "families"}
        sums[f"deployments.{name}"] = _section_checksum(core)
        for fam, fam_blob in (sub.get("families") or {}).items():
            sums[f"deployments.{name}.families.{fam}"] = _section_checksum(fam_blob)
    if provenance:
        sums["provenance"] = _section_checksum(provenance)
    return sums


def _verify_device_blob(
    name: str, sub, sums: dict[str, str], load_errors: list[dict]
):
    """Checksum one device blob; returns the (possibly reduced) blob or None.

    A corrupt device core drops the whole device (``None`` — lookups recover
    via ``devices.FALLBACKS``); a corrupt or missing family section drops
    only that family (its op serves the reference path).  Sections without a
    checksum entry (pre-v6 blobs, hand-edited extras) are not judged.
    """
    key = f"deployments.{name}"
    if not isinstance(sub, dict):
        load_errors.append({
            "section": key, "error": f"not an object ({type(sub).__name__})",
            "action": "device dropped (FALLBACKS recovery)",
        })
        return None
    if key in sums:
        core = {k: v for k, v in sub.items() if k != "families"}
        if _section_checksum(core) != sums[key]:
            load_errors.append({
                "section": key, "error": "checksum mismatch",
                "action": "device dropped (FALLBACKS recovery)",
            })
            return None
    fams = sub.get("families")
    present = set(fams) if isinstance(fams, dict) else set()
    if isinstance(fams, dict):
        kept = {}
        for fam, fam_blob in fams.items():
            fkey = f"{key}.families.{fam}"
            if fkey in sums and _section_checksum(fam_blob) != sums[fkey]:
                load_errors.append({
                    "section": fkey, "error": "checksum mismatch",
                    "action": "family dropped (reference path)",
                })
                continue
            kept[fam] = fam_blob
        if len(kept) != len(fams):
            sub = dict(sub, families=kept)
    prefix = f"{key}.families."
    for fkey in sums:
        if fkey.startswith(prefix) and fkey[len(prefix):] not in present:
            load_errors.append({
                "section": fkey, "error": "checksummed section missing",
                "action": "family dropped (reference path)",
            })
    return sub


def _parse_deployment(sub: dict, section: str) -> Deployment:
    """``Deployment.from_blob`` with bare struct errors wrapped as format errors."""
    try:
        return Deployment.from_blob(sub)
    except BundleError:
        raise
    except (KeyError, TypeError, AttributeError, IndexError) as e:
        raise BundleFormatError(
            f"malformed deployment blob: {type(e).__name__}: {e}", section=section
        ) from e
    except ValueError as e:
        raise BundleFormatError(str(e), section=section) from e


@dataclasses.dataclass
class DeploymentBundle:
    """Versioned pack of per-device deployments (the deploy-anywhere artifact).

    ``load_errors`` records sections a v6 checksum pass dropped during load
    (empty for a clean or pre-v6 artifact) — the bundle still serves with
    whatever survived, recovering dropped devices via ``devices.FALLBACKS``.
    """

    deployments: dict[str, Deployment]
    meta: dict = dataclasses.field(default_factory=dict)
    load_errors: list[dict] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.deployments:
            raise ValueError("a DeploymentBundle needs at least one deployment")
        # Keys are canonical device slugs; normalize so lookup and tuning-time
        # naming can't drift apart ("TPU v4" and "tpu_v4" are the same entry).
        self.deployments = {
            canonical_device_name(name): dep for name, dep in self.deployments.items()
        }

    # -- access --------------------------------------------------------------
    @property
    def devices(self) -> list[str]:
        return sorted(self.deployments)

    def add(self, deployment: Deployment, device: str | None = None) -> None:
        self.deployments[canonical_device_name(device or deployment.device)] = deployment

    def deployment_for(self, device: str, *, strict: bool = False) -> tuple[Deployment, str]:
        """(deployment, resolved device name) serving ``device``.

        Exact match first, then the nearest-device fallback order of
        ``repro.core.devices.resolve_device``; ``strict=True`` raises
        ``KeyError`` instead of degrading across platform families.
        """
        resolved = resolve_device(device, self.devices, strict=strict)
        if resolved is None:
            raise KeyError(f"no deployment for device {device!r} in bundle {self.devices}")
        return self.deployments[resolved], resolved

    def runtime(self, device: str | None = None, *, strict: bool = False,
                name: str | None = None):
        """A fresh :class:`~repro.core.runtime.KernelRuntime` serving this bundle.

        The multi-tenant entry point: each call builds an isolated runtime
        with this bundle's per-device policies installed and the one resolved
        for ``device`` (default: detected host) activated — two bundles (or
        two calls) can serve different tunings concurrently in one process::

            rt = repro.load_bundle("bundle.json").runtime(device="tpu_v5e")
            engine = rt.serve(model, params)
        """
        from .runtime import KernelRuntime

        rt = KernelRuntime(name=name or f"bundle[{'+'.join(self.devices)}]")
        rt.install_bundle(self, device, strict=strict)
        return rt

    def router(self, model, params, *, devices=None, strict: bool = False,
               name: str | None = None, **engine_kwargs):
        """A fleet :class:`~repro.serve.router.Router` over this bundle.

        One isolated :class:`~repro.core.runtime.KernelRuntime` **per tuned
        device** (or the given ``devices`` subset), each driving its own
        :class:`~repro.serve.engine.ServingEngine` on that device's tuning —
        SLO objectives, retunes, and quarantines on one engine never leak
        into another.  The four-line fleet lifecycle::

            bundle = repro.tune(["granite-8b"], devices=("tpu_v5e", "tpu_v4"))
            router = bundle.router(model, params, max_batch=8, block_size=16)
            ticket = router.submit(prompt, latency_target_ms=8.0)
            print(ticket.result(), router.drain())

        ``engine_kwargs`` flow to every engine ctor (``max_batch``,
        ``cache_len``, ``block_size``, ``retune_interval``, ...).
        """
        from repro.serve.router import Router

        devs = list(devices) if devices is not None else list(self.devices)
        if not devs:
            raise ValueError("bundle has no tuned devices to route across")
        label = name or "router"
        engines = {}
        for dev in devs:
            rt = self.runtime(device=dev, strict=strict, name=f"{label}[{dev}]")
            engines[rt.active_device() or dev] = rt.serve(
                model, params, device=rt.active_device(), **engine_kwargs
            )
        return Router(engines, name=label)

    def provenance(self) -> dict[str, dict]:
        """Per-device tuning provenance (the v4+ top-level block).

        Extracted from each deployment's meta; devices tuned before
        provenance existed simply have no entry.
        """
        out: dict[str, dict] = {}
        for name, dep in sorted(self.deployments.items()):
            ent = {k: dep.meta[k] for k in _PROVENANCE_KEYS if k in dep.meta}
            if ent:
                out[name] = ent
        return out

    # -- persistence ---------------------------------------------------------
    def to_blob(self, *, tree_format: str = "flat") -> dict:
        deployments = {
            name: dep.to_blob(tree_format=tree_format)
            for name, dep in sorted(self.deployments.items())
        }
        provenance = self.provenance()
        return {
            "version": BUNDLE_VERSION,
            "format": "bundle",
            "deployments": deployments,
            "provenance": provenance,
            "checksums": _blob_checksums(deployments, provenance),
            "meta": self.meta,
        }

    def save(self, path: str | Path, *, tree_format: str = "flat") -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_blob(tree_format=tree_format), indent=1))

    @staticmethod
    def from_blob(blob: dict) -> "DeploymentBundle":
        """Parse a v3-v6 bundle blob — or wrap a v1/v2/v5 single-device blob.

        v6 blobs get the per-section checksum pass (corrupt family sections
        and device cores are dropped, not fatal — see ``load_errors``);
        structurally unreadable input raises :class:`BundleFormatError` with
        the failing section, never a bare ``KeyError``/``TypeError``.
        """
        if not isinstance(blob, dict):
            raise BundleFormatError(
                f"bundle blob must be a JSON object, got {type(blob).__name__}"
            )
        if blob.get("format") == "bundle" or "deployments" in blob:
            try:
                version = int(blob.get("version", BUNDLE_VERSION))
            except (TypeError, ValueError):
                raise BundleFormatError(
                    f"bundle version is not an integer: {blob.get('version')!r}",
                    section="version") from None
            if version > BUNDLE_VERSION:
                raise BundleFormatError(
                    f"bundle version {version} is newer than supported v{BUNDLE_VERSION}",
                    section="version")
            dep_blobs = blob.get("deployments")
            if not isinstance(dep_blobs, dict) or not dep_blobs:
                raise BundleFormatError(
                    "bundle has no readable 'deployments' section",
                    section="deployments")
            sums = blob.get("checksums") or {}
            load_errors: list[dict] = []
            deps: dict[str, Deployment] = {}
            for name, sub in dep_blobs.items():
                sub = _verify_device_blob(name, sub, sums, load_errors)
                if sub is None:
                    continue
                deps[name] = _parse_deployment(sub, f"deployments.{name}")
            if not deps:
                raise BundleIntegrityError(
                    "no deployment in the bundle survived checksum verification: "
                    + "; ".join(e["section"] for e in load_errors)
                )
            provenance = blob.get("provenance") or {}
            if provenance and "provenance" in sums and (
                _section_checksum(provenance) != sums["provenance"]
            ):
                load_errors.append({
                    "section": "provenance", "error": "checksum mismatch",
                    "action": "provenance dropped",
                })
                provenance = {}
            # v4: reattach the top-level provenance block to each deployment
            # (authoritative for tooling that rewrote it without touching the
            # embedded per-device blobs; older per-device meta wins nothing).
            by_canonical = {canonical_device_name(n): d for n, d in deps.items()}
            for name, ent in provenance.items():
                dep = by_canonical.get(canonical_device_name(name))
                if dep is not None and isinstance(ent, dict):
                    dep.meta.update(ent)
            bundle = DeploymentBundle(deployments=deps, meta=blob.get("meta", {}))
            bundle.load_errors = load_errors
            return bundle
        # v1/v2 single-device file: a degenerate one-entry bundle.
        dep = _parse_deployment(blob, "deployment")
        return DeploymentBundle(deployments={dep.device: dep}, meta=dict(dep.meta))

    @staticmethod
    def load(path: str | Path) -> "DeploymentBundle":
        """Load a bundle from a file path — or a control-plane URI.

        ``registry://host:port/name[/version]`` fetches the artifact from a
        running :class:`repro.control.ControlPlane`'s registry (version
        defaults to ``latest``); plain ``http(s)://`` URLs fetch whatever
        bundle (or registry envelope) the endpoint serves.  Fetched text
        rides the same chaos site (``bundle.load``) and checksum pass as a
        file read, so a corrupted wire transfer degrades exactly like bit
        rot on disk.
        """
        path_str = str(path)
        if path_str.startswith(("registry://", "http://", "https://")):
            text = _fetch_uri(path_str)
        else:
            text = Path(path).read_text()
        from .runtime import current_runtime

        plan = current_runtime().fault_plan
        if plan is not None:  # chaos site: simulate bit rot on the wire
            text = plan.corrupt_text("bundle.load", text, key=path_str)
        try:
            blob = json.loads(text)
        except json.JSONDecodeError as e:
            raise BundleFormatError(
                f"bundle file {path} is not valid JSON: {e.msg}", offset=e.pos
            ) from e
        if isinstance(blob, dict) and blob.get("format") == "artifact" and "blob" in blob:
            blob = blob["blob"]  # registry envelope: unwrap to the bundle blob
        return DeploymentBundle.from_blob(blob)


def install_bundle(
    bundle: "DeploymentBundle | str | Path",
    device: str | None = None,
    *,
    strict: bool = False,
    runtime=None,
) -> Deployment:
    """Install the bundle into a runtime: its policies become the registry.

    ``runtime`` names the target :class:`~repro.core.runtime.KernelRuntime`
    (default: the current — usually the process default — runtime; prefer
    :meth:`DeploymentBundle.runtime` for an isolated handle).  Any previously
    registered per-device policies of that runtime are replaced (installing a
    bundle is authoritative — resolution must agree between the bundle and
    the registry, so stale entries from an earlier install cannot shadow this
    bundle's fallback choice).  ``device=None`` detects the host
    (``REPRO_DEVICE`` override first); an untuned host degrades to the
    nearest tuned sibling rather than the untuned ``FixedPolicy`` baseline.
    Returns the activated ``Deployment``; whether a fallback happened is
    readable from the runtime's ``device_resolution()`` (the shared
    ``Deployment`` objects are never mutated).
    """
    from .runtime import current_runtime

    rt = runtime if runtime is not None else current_runtime()
    return rt.install_bundle(bundle, device, strict=strict)
