"""The paper's contribution: ML-guided kernel selection for deployment.

Pipeline:  benchmark table -> normalize -> cluster-select deployable subset
           -> train runtime classifier -> Deployment artifact (KernelPolicy).
"""
from .bundle import DeploymentBundle, install_bundle
from .classify import CLASSIFIERS, make_classifier
from .devices import canonical_device_name, detect_device, resolve_device
from .flattree import FlatTree
from .cluster import CLUSTER_METHODS, select_configs
from .dataset import TuningDataset, build_model_dataset, harvest_problems, problem_features, synthetic_problems
from .dispatch import Deployment, classifier_fraction, train_deployment
from .faults import FaultError, FaultPlan, FaultSpec
from .families import (
    FamilyTuning,
    KernelFamily,
    build_family_dataset,
    families,
    family_names,
    get_family,
    register_family,
)
from .normalize import NORMALIZATIONS, normalize
from .pca import PCA
from .pipeline import (
    FamilyPipelineResult,
    TransferPrior,
    run_family_pipeline,
    tune_dataset,
)
from .retune import TelemetrySnapshot
from .runtime import KernelRuntime, current_runtime, default_runtime, reset_default_runtime
from .selection import achievable_fraction, evaluate_methods, select_from_dataset
from .tuner import FleetTuneResult, TuneResult, save_fleet, tune, tune_family, tune_fleet, tune_for_archs

__all__ = [
    "CLASSIFIERS",
    "CLUSTER_METHODS",
    "NORMALIZATIONS",
    "PCA",
    "Deployment",
    "DeploymentBundle",
    "FamilyPipelineResult",
    "FamilyTuning",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "FlatTree",
    "FleetTuneResult",
    "KernelFamily",
    "KernelRuntime",
    "TelemetrySnapshot",
    "TransferPrior",
    "TuneResult",
    "TuningDataset",
    "achievable_fraction",
    "build_family_dataset",
    "build_model_dataset",
    "canonical_device_name",
    "classifier_fraction",
    "current_runtime",
    "default_runtime",
    "detect_device",
    "evaluate_methods",
    "families",
    "family_names",
    "get_family",
    "harvest_problems",
    "install_bundle",
    "make_classifier",
    "normalize",
    "problem_features",
    "register_family",
    "reset_default_runtime",
    "resolve_device",
    "run_family_pipeline",
    "save_fleet",
    "select_configs",
    "select_from_dataset",
    "synthetic_problems",
    "train_deployment",
    "tune",
    "tune_dataset",
    "tune_family",
    "tune_fleet",
    "tune_for_archs",
]
