"""The paper's contribution: ML-guided kernel selection for deployment.

Pipeline:  benchmark table -> normalize -> cluster-select deployable subset
           -> train runtime classifier -> Deployment artifact (KernelPolicy).
"""
from .classify import CLASSIFIERS, make_classifier
from .flattree import FlatTree
from .cluster import CLUSTER_METHODS, select_configs
from .dataset import TuningDataset, build_model_dataset, harvest_problems, problem_features, synthetic_problems
from .dispatch import Deployment, classifier_fraction, train_deployment
from .normalize import NORMALIZATIONS, normalize
from .pca import PCA
from .selection import achievable_fraction, evaluate_methods, select_from_dataset
from .tuner import TuneResult, tune, tune_for_archs

__all__ = [
    "CLASSIFIERS",
    "CLUSTER_METHODS",
    "NORMALIZATIONS",
    "PCA",
    "Deployment",
    "FlatTree",
    "TuneResult",
    "TuningDataset",
    "achievable_fraction",
    "build_model_dataset",
    "classifier_fraction",
    "evaluate_methods",
    "harvest_problems",
    "make_classifier",
    "normalize",
    "problem_features",
    "select_configs",
    "select_from_dataset",
    "synthetic_problems",
    "train_deployment",
    "tune",
    "tune_for_archs",
]
