"""Runtime kernel-selection classifiers (paper §5, Tables 1-2).

Maps problem-size features -> index of the deployed kernel config to launch.
All classifiers implement ``fit(x, y)`` / ``predict(x)`` and are numpy-only.

The classifier zoo mirrors the paper: three decision trees with increasing
regularization (A: unlimited; B: depth<=6, leaf>=3; C: depth<=3, leaf>=4),
k-nearest-neighbours (k = 1, 3, 7), linear and RBF SVMs (Pegasos-style SGD on
the hinge loss — primal for linear, kernelized dual for RBF), a random forest,
and a small MLP.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "DecisionTreeClassifier",
    "KNeighborsClassifier",
    "LinearSVM",
    "RadialSVM",
    "RandomForestClassifier",
    "MLPClassifier",
    "make_classifier",
    "CLASSIFIERS",
]


def _standardize_fit(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    mu = x.mean(0)
    sd = x.std(0)
    sd = np.where(sd > 1e-12, sd, 1.0)
    return mu, sd


# ---------------------------------------------------------------------------
# Decision tree (CART, gini)
# ---------------------------------------------------------------------------
class _Node:
    __slots__ = ("feature", "threshold", "left", "right", "label", "counts")

    def __init__(self):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.label = 0
        self.counts = None


class DecisionTreeClassifier:
    def __init__(self, max_depth: int | None = None, min_samples_leaf: int = 1, seed: int = 0):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.seed = seed
        self.root_: _Node | None = None
        self.n_classes_ = 0
        self.max_features: int | None = None  # set by RandomForest

    # -- training ---------------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray, sample_weight: np.ndarray | None = None):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self.n_classes_ = int(y.max()) + 1 if y.size else 1
        rng = np.random.default_rng(self.seed)
        w = np.ones(len(y)) if sample_weight is None else np.asarray(sample_weight, float)
        self.root_ = self._grow(x, y, w, depth=0, rng=rng)
        return self

    def _gini(self, counts: np.ndarray) -> float:
        tot = counts.sum()
        if tot <= 0:
            return 0.0
        p = counts / tot
        return float(1.0 - (p**2).sum())

    def _grow(self, x: np.ndarray, y: np.ndarray, w: np.ndarray, depth: int, rng) -> _Node:
        node = _Node()
        counts = np.bincount(y, weights=w, minlength=self.n_classes_)
        node.counts = counts
        node.label = int(counts.argmax())
        if (
            (self.max_depth is not None and depth >= self.max_depth)
            or len(y) < 2 * self.min_samples_leaf
            or counts.max() == counts.sum()
        ):
            return node
        nf = x.shape[1]
        feats = np.arange(nf)
        if self.max_features is not None and self.max_features < nf:
            feats = rng.choice(nf, size=self.max_features, replace=False)
        best = None  # (gini, feature, threshold)
        parent_gini = self._gini(counts)
        for f in feats:
            order = np.argsort(x[:, f], kind="stable")
            xs, ys, ws = x[order, f], y[order], w[order]
            onehot = np.zeros((len(ys), self.n_classes_))
            onehot[np.arange(len(ys)), ys] = ws
            left_csum = np.cumsum(onehot, axis=0)
            total = left_csum[-1]
            for i in range(self.min_samples_leaf, len(ys) - self.min_samples_leaf + 1):
                if i < len(ys) and xs[i - 1] == xs[min(i, len(ys) - 1)]:
                    continue
                lc = left_csum[i - 1]
                rc = total - lc
                nl, nr = lc.sum(), rc.sum()
                if nl <= 0 or nr <= 0:
                    continue
                g = (nl * self._gini(lc) + nr * self._gini(rc)) / (nl + nr)
                if best is None or g < best[0]:
                    thr = 0.5 * (xs[i - 1] + xs[min(i, len(ys) - 1)])
                    best = (g, int(f), float(thr))
        if best is None or best[0] >= parent_gini - 1e-12:
            return node
        _, f, thr = best
        mask = x[:, f] <= thr
        if mask.sum() < self.min_samples_leaf or (~mask).sum() < self.min_samples_leaf:
            return node
        node.feature, node.threshold = f, thr
        node.left = self._grow(x[mask], y[mask], w[mask], depth + 1, rng)
        node.right = self._grow(x[~mask], y[~mask], w[~mask], depth + 1, rng)
        return node

    # -- inference --------------------------------------------------------
    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        out = np.empty(len(x), dtype=int)
        for i, row in enumerate(x):
            node = self.root_
            while node.left is not None:
                node = node.left if row[node.feature] <= node.threshold else node.right
            out[i] = node.label
        return out

    def predict_counts(self, x: np.ndarray) -> np.ndarray:
        """Per-sample class-count vectors at the reached leaf (for forests)."""
        x = np.asarray(x, dtype=np.float64)
        out = np.zeros((len(x), self.n_classes_))
        for i, row in enumerate(x):
            node = self.root_
            while node.left is not None:
                node = node.left if row[node.feature] <= node.threshold else node.right
            c = node.counts
            out[i, : len(c)] = c / max(c.sum(), 1e-12)
        return out

    # -- depth / size introspection (for codegen & tests) ------------------
    def depth(self) -> int:
        def d(n):
            return 0 if n is None or n.left is None else 1 + max(d(n.left), d(n.right))

        return d(self.root_)

    def n_leaves(self) -> int:
        def c(n):
            return 1 if n.left is None else c(n.left) + c(n.right)

        return c(self.root_)


# ---------------------------------------------------------------------------
# k nearest neighbours
# ---------------------------------------------------------------------------
class KNeighborsClassifier:
    def __init__(self, k: int = 3):
        self.k = k

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        self._mu, self._sd = _standardize_fit(x)
        self._x = (x - self._mu) / self._sd
        self._y = np.asarray(y, dtype=int)
        self.n_classes_ = int(self._y.max()) + 1 if self._y.size else 1
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = (np.asarray(x, dtype=np.float64) - self._mu) / self._sd
        d2 = ((x[:, None, :] - self._x[None, :, :]) ** 2).sum(-1)
        k = min(self.k, len(self._y))
        nn = np.argpartition(d2, k - 1, axis=1)[:, :k]
        out = np.empty(len(x), dtype=int)
        for i in range(len(x)):
            out[i] = np.bincount(self._y[nn[i]], minlength=self.n_classes_).argmax()
        return out


# ---------------------------------------------------------------------------
# SVMs (Pegasos SGD on hinge loss, one-vs-rest)
# ---------------------------------------------------------------------------
class LinearSVM:
    def __init__(self, lam: float = 1e-3, epochs: int = 60, seed: int = 0):
        self.lam, self.epochs, self.seed = lam, epochs, seed

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self._mu, self._sd = _standardize_fit(x)
        xs = (x - self._mu) / self._sd
        xs = np.hstack([xs, np.ones((len(xs), 1))])  # bias feature
        self.n_classes_ = int(y.max()) + 1
        n, d = xs.shape
        rng = np.random.default_rng(self.seed)
        self._w = np.zeros((self.n_classes_, d))
        for c in range(self.n_classes_):
            t = 0
            yc = np.where(y == c, 1.0, -1.0)
            w = np.zeros(d)
            for _ in range(self.epochs):
                for i in rng.permutation(n):
                    t += 1
                    eta = 1.0 / (self.lam * t)
                    margin = yc[i] * (w @ xs[i])
                    w *= 1 - eta * self.lam
                    if margin < 1:
                        w += eta * yc[i] * xs[i]
            self._w[c] = w
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xs = (np.asarray(x, dtype=np.float64) - self._mu) / self._sd
        xs = np.hstack([xs, np.ones((len(xs), 1))])
        return (xs @ self._w.T).argmax(1)


class RadialSVM:
    """Kernelized Pegasos (RBF) one-vs-rest SVM."""

    def __init__(self, lam: float = 1e-2, epochs: int = 40, gamma: float | None = None, seed: int = 0):
        self.lam, self.epochs, self.gamma, self.seed = lam, epochs, gamma, seed

    def _kernel(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return np.exp(-self._g * d2)

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self._mu, self._sd = _standardize_fit(x)
        self._x = (x - self._mu) / self._sd
        n = len(y)
        if self.gamma is None:
            d2 = ((self._x[:, None, :] - self._x[None, :, :]) ** 2).sum(-1)
            nz = d2[d2 > 0]
            self._g = 1.0 / max(np.median(nz), 1e-12) if nz.size else 1.0
        else:
            self._g = self.gamma
        gram = self._kernel(self._x, self._x)
        self.n_classes_ = int(y.max()) + 1
        self._alpha = np.zeros((self.n_classes_, n))
        rng = np.random.default_rng(self.seed)
        for c in range(self.n_classes_):
            yc = np.where(y == c, 1.0, -1.0)
            a = np.zeros(n)
            t = 0
            for _ in range(self.epochs):
                for i in rng.permutation(n):
                    t += 1
                    f = (a * yc) @ gram[:, i] / (self.lam * t)
                    if yc[i] * f < 1:
                        a[i] += 1
            self._alpha[c] = a * yc / (self.lam * max(t, 1))
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xs = (np.asarray(x, dtype=np.float64) - self._mu) / self._sd
        k = self._kernel(xs, self._x)
        return (k @ self._alpha.T).argmax(1)


# ---------------------------------------------------------------------------
# Random forest
# ---------------------------------------------------------------------------
class RandomForestClassifier:
    def __init__(self, n_trees: int = 30, max_depth: int | None = None, seed: int = 0):
        self.n_trees, self.max_depth, self.seed = n_trees, max_depth, seed
        self.trees_: list[DecisionTreeClassifier] = []

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        rng = np.random.default_rng(self.seed)
        n, nf = x.shape
        self.n_classes_ = int(y.max()) + 1
        self.trees_ = []
        mf = max(1, int(np.sqrt(nf)))
        for t in range(self.n_trees):
            idx = rng.integers(0, n, size=n)  # bootstrap
            tree = DecisionTreeClassifier(max_depth=self.max_depth, seed=self.seed + t)
            tree.max_features = mf
            tree.n_classes_ = self.n_classes_
            tree.fit(x[idx], y[idx])
            tree.n_classes_ = self.n_classes_
            self.trees_.append(tree)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        votes = np.zeros((len(x), self.n_classes_))
        for tree in self.trees_:
            pc = tree.predict_counts(x)
            votes[:, : pc.shape[1]] += pc
        return votes.argmax(1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
class MLPClassifier:
    def __init__(self, hidden: int = 32, epochs: int = 400, lr: float = 1e-2, seed: int = 0):
        self.hidden, self.epochs, self.lr, self.seed = hidden, epochs, lr, seed

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self._mu, self._sd = _standardize_fit(x)
        xs = (x - self._mu) / self._sd
        n, d = xs.shape
        c = int(y.max()) + 1
        self.n_classes_ = c
        rng = np.random.default_rng(self.seed)
        w1 = rng.normal(0, np.sqrt(2.0 / d), (d, self.hidden))
        b1 = np.zeros(self.hidden)
        w2 = rng.normal(0, np.sqrt(2.0 / self.hidden), (self.hidden, c))
        b2 = np.zeros(c)
        onehot = np.zeros((n, c))
        onehot[np.arange(n), y] = 1.0
        # Adam
        ms = [np.zeros_like(p) for p in (w1, b1, w2, b2)]
        vs = [np.zeros_like(p) for p in (w1, b1, w2, b2)]
        params = [w1, b1, w2, b2]
        for t in range(1, self.epochs + 1):
            h = np.maximum(xs @ params[0] + params[1], 0.0)
            logits = h @ params[2] + params[3]
            logits -= logits.max(1, keepdims=True)
            p = np.exp(logits)
            p /= p.sum(1, keepdims=True)
            g_logits = (p - onehot) / n
            gw2 = h.T @ g_logits
            gb2 = g_logits.sum(0)
            gh = g_logits @ params[2].T
            gh[h <= 0] = 0.0
            gw1 = xs.T @ gh
            gb1 = gh.sum(0)
            grads = [gw1, gb1, gw2, gb2]
            b1m, b2m = 0.9, 0.999
            for j, g in enumerate(grads):
                ms[j] = b1m * ms[j] + (1 - b1m) * g
                vs[j] = b2m * vs[j] + (1 - b2m) * g * g
                mh = ms[j] / (1 - b1m**t)
                vh = vs[j] / (1 - b2m**t)
                params[j] -= self.lr * mh / (np.sqrt(vh) + 1e-8)
        self._params = params
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xs = (np.asarray(x, dtype=np.float64) - self._mu) / self._sd
        w1, b1, w2, b2 = self._params
        h = np.maximum(xs @ w1 + b1, 0.0)
        return (h @ w2 + b2).argmax(1)


# ---------------------------------------------------------------------------
# registry (paper Tables 1-2 rows)
# ---------------------------------------------------------------------------
CLASSIFIERS: dict[str, callable] = {
    "DecisionTreeA": lambda: DecisionTreeClassifier(max_depth=None, min_samples_leaf=1),
    "DecisionTreeB": lambda: DecisionTreeClassifier(max_depth=6, min_samples_leaf=3),
    "DecisionTreeC": lambda: DecisionTreeClassifier(max_depth=3, min_samples_leaf=4),
    "1NearestNeighbor": lambda: KNeighborsClassifier(k=1),
    "3NearestNeighbor": lambda: KNeighborsClassifier(k=3),
    "7NearestNeighbor": lambda: KNeighborsClassifier(k=7),
    "LinearSVM": lambda: LinearSVM(),
    "RadialSVM": lambda: RadialSVM(),
    "RandomForest": lambda: RandomForestClassifier(n_trees=30),
    "MLP": lambda: MLPClassifier(),
}


def make_classifier(name: str):
    try:
        return CLASSIFIERS[name]()
    except KeyError:
        raise ValueError(f"unknown classifier {name!r}; expected one of {sorted(CLASSIFIERS)}") from None
