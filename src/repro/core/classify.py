"""Runtime kernel-selection classifiers (paper §5, Tables 1-2).

Maps problem-size features -> index of the deployed kernel config to launch.
All classifiers implement ``fit(x, y)`` / ``predict(x)`` and are numpy-only.

The classifier zoo mirrors the paper: three decision trees with increasing
regularization (A: unlimited; B: depth<=6, leaf>=3; C: depth<=3, leaf>=4),
k-nearest-neighbours (k = 1, 3, 7), linear and RBF SVMs (Pegasos-style SGD on
the hinge loss — primal for linear, kernelized dual for RBF), a random forest,
and a small MLP.
"""
from __future__ import annotations

import numpy as np

from .flattree import FlatTree

__all__ = [
    "FlatTree",
    "DecisionTreeClassifier",
    "KNeighborsClassifier",
    "LinearSVM",
    "RadialSVM",
    "RandomForestClassifier",
    "MLPClassifier",
    "make_classifier",
    "fit_weighted",
    "CLASSIFIERS",
]


def fit_weighted(clf, x: np.ndarray, y: np.ndarray, sample_weight: np.ndarray | None = None):
    """Fit ``clf`` with per-sample traffic weights (the retune path).

    Decision trees take ``sample_weight`` natively; classifiers without the
    parameter get an equivalent dataset with rows replicated in proportion to
    weight (bounded at 4 copies of the heaviest row per original row, enough
    resolution for a traffic histogram without quadratic blow-up).
    """
    if sample_weight is None:
        return clf.fit(x, y)
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=int)
    w = np.asarray(sample_weight, dtype=np.float64)
    if w.shape != (len(y),):
        raise ValueError(f"sample_weight shape {w.shape} != ({len(y)},)")
    try:
        return clf.fit(x, y, sample_weight=w)
    except TypeError:
        pass
    pos = w[w > 0]
    if pos.size == 0:
        return clf.fit(x, y)
    reps = np.clip(np.round(4.0 * w / pos.max()), 0, 4).astype(int)
    reps[w > 0] = np.maximum(reps[w > 0], 1)  # every observed row survives
    idx = np.repeat(np.arange(len(y)), reps)
    return clf.fit(x[idx], y[idx])


def _standardize_fit(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    mu = x.mean(0)
    sd = x.std(0)
    sd = np.where(sd > 1e-12, sd, 1.0)
    return mu, sd


# ---------------------------------------------------------------------------
# Decision tree (CART, gini)
# ---------------------------------------------------------------------------
class _Node:
    __slots__ = ("feature", "threshold", "left", "right", "label", "counts")

    def __init__(self):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.label = 0
        self.counts = None


class DecisionTreeClassifier:
    def __init__(self, max_depth: int | None = None, min_samples_leaf: int = 1, seed: int = 0):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.seed = seed
        self.root_: _Node | None = None
        self.flat_: FlatTree | None = None  # compiled after fit (fast path)
        self.n_classes_ = 0
        self.max_features: int | None = None  # set by RandomForest

    # -- training ---------------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray, sample_weight: np.ndarray | None = None):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self.n_classes_ = int(y.max()) + 1 if y.size else 1
        rng = np.random.default_rng(self.seed)
        w = np.ones(len(y)) if sample_weight is None else np.asarray(sample_weight, float)
        n = len(y)
        onehot = np.zeros((n, self.n_classes_))
        if n:
            onehot[np.arange(n), y] = w
        self.root_, self.flat_ = self._grow_levels(x, onehot, rng)
        return self

    def _gini(self, counts: np.ndarray) -> float:
        tot = counts.sum()
        if tot <= 0:
            return 0.0
        p = counts / tot
        return float(1.0 - (p**2).sum())

    def _grow_levels(self, x: np.ndarray, onehot: np.ndarray, rng) -> tuple[_Node, FlatTree]:
        """Level-synchronous CART growth — the vectorized training fast path.

        Features are sorted once; every deeper level re-groups the sorted row
        orders by node with a stable partition.  The split search for ALL
        nodes of a level runs as one segmented cumulative-class-count sweep:
        prefix sums (reset at node boundaries) give left/right Gini impurity
        at every candidate threshold of every node in closed form, so the
        Python/numpy call count scales with tree *depth*, not node count.
        The compiled :class:`FlatTree` is assembled in the same pass (BFS
        layout — children always follow parents, as ``validate`` requires).
        """
        n, nf = x.shape
        c = onehot.shape[1]
        ml = max(self.min_samples_leaf, 1)
        root = _Node()
        root.counts = onehot.sum(0)
        root.label = int(root.counts.argmax())
        # flat arrays, filled alongside the node graph (index 0 = root)
        f_feature = [-1]
        f_thr = [0.0]
        f_left = [-1]
        f_right = [-1]
        f_label = [root.label]
        f_counts = [root.counts]

        def finish() -> tuple[_Node, FlatTree]:
            flat = FlatTree(f_feature, f_thr, f_left, f_right, f_label,
                            self.n_classes_, np.stack(f_counts))
            return root, flat

        if n == 0:
            return finish()
        sub_features = self.max_features is not None and self.max_features < nf
        # Sort once per feature; stable partitions preserve this order below.
        order = np.argsort(x, axis=0, kind="stable")  # (n_rows, nf), row ids
        cols = np.arange(nf)[None, :]
        nodes = [root]  # active (still-splittable-candidate) nodes, in row order
        flat_idx = [0]  # flat-array index of each active node
        sizes = np.array([n])
        node_counts = root.counts[None, :]
        depth = 0
        while nodes:
            # -- per-node stopping rules (bulk, then a cheap python filter) --
            w_tot = node_counts.sum(1)
            can_split = ~(
                (node_counts.max(1) == w_tot)
                | (sizes < 2 * ml)
                | (np.zeros(len(nodes), bool) if self.max_depth is None else np.full(len(nodes), depth >= self.max_depth))
            )
            if not can_split.any():
                break
            if not can_split.all():
                row_keep = np.repeat(can_split, sizes)
                order = order[row_keep]
                nodes = [nd for nd, ok_ in zip(nodes, can_split) if ok_]
                flat_idx = [fi for fi, ok_ in zip(flat_idx, can_split) if ok_]
                node_counts = node_counts[can_split]
                sizes = sizes[can_split]
                w_tot = w_tot[can_split]
            k = len(nodes)
            na = order.shape[0]
            starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
            seg_rep = np.repeat(np.arange(k), sizes)

            # -- segmented prefix class counts ------------------------------
            csum = np.cumsum(onehot[order], axis=0)  # (na, nf, c)
            base = csum[starts - 1]  # prefix just before each segment...
            base[0] = 0.0  # ...with segment 0's base (wrapped index) zeroed
            lc = csum - base[seg_rep]  # left counts at split index i = pos+1
            rc = np.repeat(node_counts, sizes, 0)[:, None, :] - lc
            nl = lc.sum(-1)  # (na, nf) left weight (per feature: weighted rows differ)
            nr = w_tot[seg_rep, None] - nl
            pos1 = np.arange(na) - starts[seg_rep] + 1  # split index within segment
            valid = (pos1 >= ml) & (pos1 <= np.repeat(sizes, sizes) - ml)
            xs = x[order, cols]  # (na, nf) presorted feature values
            xnext = np.empty_like(xs)
            xnext[:-1] = xs[1:]
            xnext[-1] = np.inf  # last row is never a valid split anyway
            ok = valid[:, None] & (xs != xnext) & (nl > 0) & (nr > 0)
            # Total node weight is constant across a segment's positions, so
            # minimizing weighted Gini (nl*gl + nr*gr)/W is maximizing
            # h = sum(lc^2)/nl + sum(rc^2)/nr.
            with np.errstate(divide="ignore", invalid="ignore"):
                h = (lc * lc).sum(-1) / nl + (rc * rc).sum(-1) / nr
            h[~ok] = -np.inf
            if sub_features:  # random forest: per-node feature subsets
                allow = np.zeros((k, nf), dtype=bool)
                for j in range(k):
                    allow[j, rng.choice(nf, size=self.max_features, replace=False)] = True
                h[~allow[seg_rep]] = -np.inf

            # -- best split per segment -------------------------------------
            hrow = h.max(1)
            frow = h.argmax(1)
            seg_max = np.maximum.reduceat(hrow, starts)
            hit = np.where(hrow == seg_max[seg_rep], np.arange(na), na)
            br = np.minimum(np.minimum.reduceat(hit, starts), na - 1)  # first best row
            parent_h = (node_counts * node_counts).sum(1) / w_tot
            do_split = np.isfinite(seg_max) & (seg_max > parent_h + 1e-12 * w_tot)
            if not do_split.any():
                break
            f_k = frow[br]
            thr = 0.5 * (xs[br, f_k] + xs[np.minimum(br + 1, na - 1), f_k])
            hi = xs[np.minimum(br + 1, na - 1), f_k]
            thr = np.where(thr < hi, thr, xs[br, f_k])  # fp midpoint collapse
            lcounts = lc[br, f_k]  # (k, c)
            rcounts = node_counts - lcounts
            nl_k = pos1[br]
            nr_k = sizes - nl_k

            # -- wire child nodes (python bookkeeping on bulk scalars) -------
            llab = lcounts.argmax(1).tolist()
            rlab = rcounts.argmax(1).tolist()
            f_l = f_k.tolist()
            thr_l = thr.tolist()
            split_l = do_split.tolist()
            new_nodes: list[_Node] = []
            new_flat_idx: list[int] = []
            for j, nd in enumerate(nodes):
                if not split_l[j]:
                    continue
                nd.feature, nd.threshold = int(f_l[j]), float(thr_l[j])
                left, right = _Node(), _Node()
                left.counts, left.label = lcounts[j], llab[j]
                right.counts, right.label = rcounts[j], rlab[j]
                nd.left, nd.right = left, right
                new_nodes.extend((left, right))
                # mirror into the flat arrays: leaves now, patched if split later
                fi = flat_idx[j]
                li = len(f_feature)
                f_feature[fi] = nd.feature
                f_thr[fi] = nd.threshold
                f_left[fi] = li
                f_right[fi] = li + 1
                f_feature.extend((-1, -1))
                f_thr.extend((0.0, 0.0))
                f_left.extend((-1, -1))
                f_right.extend((-1, -1))
                f_label.extend((left.label, right.label))
                f_counts.extend((left.counts, right.counts))
                new_flat_idx.extend((li, li + 1))

            # -- stable partition of every feature's order for the next level
            is_left = np.zeros(n, dtype=bool)
            ids0 = order[:, 0]
            split_rep = do_split[seg_rep]
            is_left[ids0] = (x[ids0, f_k[seg_rep]] <= thr[seg_rep]) & split_rep
            order = order[split_rep]
            seg_next = seg_rep[split_rep]
            for f in range(nf):
                cid = order[:, f]
                key = 2 * seg_next + (~is_left[cid])
                order[:, f] = cid[np.argsort(key, kind="stable")]
            nodes = new_nodes
            flat_idx = new_flat_idx
            sizes = np.stack([nl_k[do_split], nr_k[do_split]], 1).ravel()
            node_counts = np.stack([lcounts[do_split], rcounts[do_split]], 1).reshape(-1, c)
            depth += 1
        return finish()

    # -- inference --------------------------------------------------------
    def _ensure_flat(self) -> FlatTree:
        if self.flat_ is None:
            self.flat_ = FlatTree.from_node(self.root_, self.n_classes_)
        return self.flat_

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Vectorized batch predict via the compiled :class:`FlatTree`."""
        x = np.asarray(x, dtype=np.float64)
        return self._ensure_flat().predict(x)

    def predict_nested(self, x: np.ndarray) -> np.ndarray:
        """Reference per-row nested walk (equivalence oracle for the flat path)."""
        x = np.asarray(x, dtype=np.float64)
        out = np.empty(len(x), dtype=int)
        for i, row in enumerate(x):
            node = self.root_
            while node.left is not None:
                node = node.left if row[node.feature] <= node.threshold else node.right
            out[i] = node.label
        return out

    def predict_counts(self, x: np.ndarray) -> np.ndarray:
        """Per-sample class-count vectors at the reached leaf (for forests)."""
        x = np.asarray(x, dtype=np.float64)
        flat = self._ensure_flat()
        if flat.counts is not None:
            c = flat.predict_counts(x)
            if c.shape[1] == self.n_classes_:
                return c
            # forest bootstrap samples can miss the top classes: pad out
            out = np.zeros((len(x), self.n_classes_))
            out[:, : c.shape[1]] = c
            return out
        out = np.zeros((len(x), self.n_classes_))
        for i, row in enumerate(x):
            node = self.root_
            while node.left is not None:
                node = node.left if row[node.feature] <= node.threshold else node.right
            c = node.counts
            out[i, : len(c)] = c / max(c.sum(), 1e-12)
        return out

    # -- depth / size introspection (for codegen & tests) ------------------
    def depth(self) -> int:
        def d(n):
            return 0 if n is None or n.left is None else 1 + max(d(n.left), d(n.right))

        return d(self.root_)

    def n_leaves(self) -> int:
        def c(n):
            return 1 if n.left is None else c(n.left) + c(n.right)

        return c(self.root_)


# ---------------------------------------------------------------------------
# k nearest neighbours
# ---------------------------------------------------------------------------
class KNeighborsClassifier:
    def __init__(self, k: int = 3):
        self.k = k

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        self._mu, self._sd = _standardize_fit(x)
        self._x = (x - self._mu) / self._sd
        self._y = np.asarray(y, dtype=int)
        self.n_classes_ = int(self._y.max()) + 1 if self._y.size else 1
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = (np.asarray(x, dtype=np.float64) - self._mu) / self._sd
        d2 = ((x[:, None, :] - self._x[None, :, :]) ** 2).sum(-1)
        k = min(self.k, len(self._y))
        nn = np.argpartition(d2, k - 1, axis=1)[:, :k]
        out = np.empty(len(x), dtype=int)
        for i in range(len(x)):
            out[i] = np.bincount(self._y[nn[i]], minlength=self.n_classes_).argmax()
        return out


# ---------------------------------------------------------------------------
# SVMs (Pegasos SGD on hinge loss, one-vs-rest)
# ---------------------------------------------------------------------------
class LinearSVM:
    def __init__(self, lam: float = 1e-3, epochs: int = 60, seed: int = 0):
        self.lam, self.epochs, self.seed = lam, epochs, seed

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self._mu, self._sd = _standardize_fit(x)
        xs = (x - self._mu) / self._sd
        xs = np.hstack([xs, np.ones((len(xs), 1))])  # bias feature
        self.n_classes_ = int(y.max()) + 1
        n, d = xs.shape
        rng = np.random.default_rng(self.seed)
        self._w = np.zeros((self.n_classes_, d))
        for c in range(self.n_classes_):
            t = 0
            yc = np.where(y == c, 1.0, -1.0)
            w = np.zeros(d)
            for _ in range(self.epochs):
                for i in rng.permutation(n):
                    t += 1
                    eta = 1.0 / (self.lam * t)
                    margin = yc[i] * (w @ xs[i])
                    w *= 1 - eta * self.lam
                    if margin < 1:
                        w += eta * yc[i] * xs[i]
            self._w[c] = w
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xs = (np.asarray(x, dtype=np.float64) - self._mu) / self._sd
        xs = np.hstack([xs, np.ones((len(xs), 1))])
        return (xs @ self._w.T).argmax(1)


class RadialSVM:
    """Kernelized Pegasos (RBF) one-vs-rest SVM."""

    def __init__(self, lam: float = 1e-2, epochs: int = 40, gamma: float | None = None, seed: int = 0):
        self.lam, self.epochs, self.gamma, self.seed = lam, epochs, gamma, seed

    def _kernel(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return np.exp(-self._g * d2)

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self._mu, self._sd = _standardize_fit(x)
        self._x = (x - self._mu) / self._sd
        n = len(y)
        if self.gamma is None:
            d2 = ((self._x[:, None, :] - self._x[None, :, :]) ** 2).sum(-1)
            nz = d2[d2 > 0]
            self._g = 1.0 / max(np.median(nz), 1e-12) if nz.size else 1.0
        else:
            self._g = self.gamma
        gram = self._kernel(self._x, self._x)
        self.n_classes_ = int(y.max()) + 1
        self._alpha = np.zeros((self.n_classes_, n))
        rng = np.random.default_rng(self.seed)
        for c in range(self.n_classes_):
            yc = np.where(y == c, 1.0, -1.0)
            a = np.zeros(n)
            t = 0
            for _ in range(self.epochs):
                for i in rng.permutation(n):
                    t += 1
                    f = (a * yc) @ gram[:, i] / (self.lam * t)
                    if yc[i] * f < 1:
                        a[i] += 1
            self._alpha[c] = a * yc / (self.lam * max(t, 1))
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xs = (np.asarray(x, dtype=np.float64) - self._mu) / self._sd
        k = self._kernel(xs, self._x)
        return (k @ self._alpha.T).argmax(1)


# ---------------------------------------------------------------------------
# Random forest
# ---------------------------------------------------------------------------
class RandomForestClassifier:
    def __init__(self, n_trees: int = 30, max_depth: int | None = None, seed: int = 0):
        self.n_trees, self.max_depth, self.seed = n_trees, max_depth, seed
        self.trees_: list[DecisionTreeClassifier] = []

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        rng = np.random.default_rng(self.seed)
        n, nf = x.shape
        self.n_classes_ = int(y.max()) + 1
        self.trees_ = []
        mf = max(1, int(np.sqrt(nf)))
        for t in range(self.n_trees):
            idx = rng.integers(0, n, size=n)  # bootstrap
            tree = DecisionTreeClassifier(max_depth=self.max_depth, seed=self.seed + t)
            tree.max_features = mf
            tree.n_classes_ = self.n_classes_
            tree.fit(x[idx], y[idx])
            tree.n_classes_ = self.n_classes_
            self.trees_.append(tree)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        votes = np.zeros((len(x), self.n_classes_))
        for tree in self.trees_:
            pc = tree.predict_counts(x)
            votes[:, : pc.shape[1]] += pc
        return votes.argmax(1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
class MLPClassifier:
    def __init__(self, hidden: int = 32, epochs: int = 400, lr: float = 1e-2, seed: int = 0):
        self.hidden, self.epochs, self.lr, self.seed = hidden, epochs, lr, seed

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self._mu, self._sd = _standardize_fit(x)
        xs = (x - self._mu) / self._sd
        n, d = xs.shape
        c = int(y.max()) + 1
        self.n_classes_ = c
        rng = np.random.default_rng(self.seed)
        w1 = rng.normal(0, np.sqrt(2.0 / d), (d, self.hidden))
        b1 = np.zeros(self.hidden)
        w2 = rng.normal(0, np.sqrt(2.0 / self.hidden), (self.hidden, c))
        b2 = np.zeros(c)
        onehot = np.zeros((n, c))
        onehot[np.arange(n), y] = 1.0
        # Adam
        ms = [np.zeros_like(p) for p in (w1, b1, w2, b2)]
        vs = [np.zeros_like(p) for p in (w1, b1, w2, b2)]
        params = [w1, b1, w2, b2]
        for t in range(1, self.epochs + 1):
            h = np.maximum(xs @ params[0] + params[1], 0.0)
            logits = h @ params[2] + params[3]
            logits -= logits.max(1, keepdims=True)
            p = np.exp(logits)
            p /= p.sum(1, keepdims=True)
            g_logits = (p - onehot) / n
            gw2 = h.T @ g_logits
            gb2 = g_logits.sum(0)
            gh = g_logits @ params[2].T
            gh[h <= 0] = 0.0
            gw1 = xs.T @ gh
            gb1 = gh.sum(0)
            grads = [gw1, gb1, gw2, gb2]
            b1m, b2m = 0.9, 0.999
            for j, g in enumerate(grads):
                ms[j] = b1m * ms[j] + (1 - b1m) * g
                vs[j] = b2m * vs[j] + (1 - b2m) * g * g
                mh = ms[j] / (1 - b1m**t)
                vh = vs[j] / (1 - b2m**t)
                params[j] -= self.lr * mh / (np.sqrt(vh) + 1e-8)
        self._params = params
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xs = (np.asarray(x, dtype=np.float64) - self._mu) / self._sd
        w1, b1, w2, b2 = self._params
        h = np.maximum(xs @ w1 + b1, 0.0)
        return (h @ w2 + b2).argmax(1)


# ---------------------------------------------------------------------------
# registry (paper Tables 1-2 rows)
# ---------------------------------------------------------------------------
CLASSIFIERS: dict[str, callable] = {
    "DecisionTreeA": lambda seed=0: DecisionTreeClassifier(max_depth=None, min_samples_leaf=1, seed=seed),
    "DecisionTreeB": lambda seed=0: DecisionTreeClassifier(max_depth=6, min_samples_leaf=3, seed=seed),
    "DecisionTreeC": lambda seed=0: DecisionTreeClassifier(max_depth=3, min_samples_leaf=4, seed=seed),
    "1NearestNeighbor": lambda seed=0: KNeighborsClassifier(k=1),
    "3NearestNeighbor": lambda seed=0: KNeighborsClassifier(k=3),
    "7NearestNeighbor": lambda seed=0: KNeighborsClassifier(k=7),
    "LinearSVM": lambda seed=0: LinearSVM(seed=seed),
    "RadialSVM": lambda seed=0: RadialSVM(seed=seed),
    "RandomForest": lambda seed=0: RandomForestClassifier(n_trees=30, seed=seed),
    "MLP": lambda seed=0: MLPClassifier(seed=seed),
}


def make_classifier(name: str, seed: int = 0):
    """A fresh classifier by registry name, seeded for reproducible fits.

    ``seed`` reaches every stochastic classifier's RNG (tie-breaking,
    SGD shuffling, forest bagging); the k-NN family has no randomness and
    ignores it.  Threading the tune seed here is what makes
    ``tune_for_archs``/``tune_fleet`` bit-reproducible run-to-run.
    """
    try:
        return CLASSIFIERS[name](seed=seed)
    except KeyError:
        raise ValueError(f"unknown classifier {name!r}; expected one of {sorted(CLASSIFIERS)}") from None
