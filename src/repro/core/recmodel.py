"""Analytic performance models for the recurrence kernel families (WKV, SSM).

Third and fourth kernel families through the paper's pipeline: the RWKV6
chunked-WKV recurrence (``repro.kernels.wkv``) and the Mamba selective-SSM
scan (``repro.kernels.ssm``).  Same physics as ``core.perfmodel`` /
``core.attnmodel``: an overlapped compute/memory roofline over the exact
Pallas tile-streaming pattern, per-grid-step pipeline overhead, VMEM-overflow
configs fail, and a deterministic microarchitectural texture so the
long-tail-of-optima phenomenon (paper Fig. 2) exists for these families too.

Problem spaces mirror what the dispatch layer featurizes at trace time
(``repro.kernels.ops``):

  * WKV:  ``(s, hd)``  — sequence length x head dim; config ``WkvConfig(chunk)``.
    Total chunked-WKV FLOPs grow with the chunk size (the intra-chunk
    quadratic form is O(c^2 hd) per chunk) while the sequential-grid overhead
    shrinks as 1/c — the optimum genuinely depends on ``s``, which is exactly
    the structure a selection classifier can learn.
  * SSM:  ``(s, d)``   — sequence length x inner width; config
    ``SsmConfig(block_d, chunk)``.  The dt*A tile is ``(chunk, block_d*N)``
    f32 in VMEM (double-buffered): large blocks overflow VMEM and fail, small
    ``block_d`` under-fills the lanes.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.ssm import SsmConfig, ssm_config_space
from repro.kernels.wkv import WkvConfig, wkv_config_space

from .perfmodel import DEVICES, TPU_V5E, DeviceModel, _hash_unit

WkvProblem = tuple[int, int]  # (seq_len, head_dim)
SsmProblem = tuple[int, int]  # (seq_len, d_inner)

WKV_FEATURE_NAMES = ("log2_s", "log2_hd", "log2_s_over_hd")
SSM_FEATURE_NAMES = ("log2_s", "log2_d", "log2_s_over_d")

SSM_STATE_N = 16  # modeled state width (the shipped configs all use N=16)


def _device(device_name: str | None) -> DeviceModel:
    """The recurrence models cover every modeled TPU; unknown hosts (e.g.
    ``host_cpu``) fall back to the primary target — these families are tuned
    once per fleet, like attention."""
    return DEVICES.get(device_name or TPU_V5E.name, TPU_V5E)


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _log2_features(p: np.ndarray) -> np.ndarray:
    a, b = p.T
    return np.column_stack([np.log2(a), np.log2(b), np.log2(a / b)])


def wkv_problem_features(problems: list[WkvProblem]) -> np.ndarray:
    p = np.asarray(problems, dtype=np.float64).reshape(-1, 2)
    if p.size == 0:
        return np.zeros((0, len(WKV_FEATURE_NAMES)))
    return _log2_features(np.maximum(p, 1.0))


def ssm_problem_features(problems: list[SsmProblem]) -> np.ndarray:
    p = np.asarray(problems, dtype=np.float64).reshape(-1, 2)
    if p.size == 0:
        return np.zeros((0, len(SSM_FEATURE_NAMES)))
    return _log2_features(np.maximum(p, 1.0))


# ---------------------------------------------------------------------------
# WKV (RWKV6 chunked recurrence)
# ---------------------------------------------------------------------------
def predict_wkv_time(
    problem: WkvProblem, cfg: WkvConfig, device: DeviceModel = TPU_V5E, *,
    dtype_bytes: int = 4, texture: bool = True,
) -> float:
    """Predicted seconds for one (head, sequence) WKV pass; inf if invalid."""
    s, hd = problem
    c = min(cfg.chunk, _round_up(max(s, 1), 8))
    n_chunks = _ceil(max(s, 1), c)
    # r/k/v/w tiles double-buffered + (hd, hd) f32 state + f32 score scratch.
    vmem = 2 * 4 * c * hd * dtype_bytes + hd * hd * 4 + c * c * 4
    if vmem > device.vmem_bytes:
        return float("inf")
    # Per chunk: state in/out quadratic forms (2 x c*hd*hd MACs each) plus the
    # intra-chunk triangular score/output forms (2 x c*c*hd MACs).
    flops = n_chunks * (8.0 * c * hd * hd + 4.0 * c * c * hd)
    util = (min(c, device.mxu_dim) / device.mxu_dim) * (min(hd, device.mxu_dim) / device.mxu_dim)
    t_compute = flops / (device.peak_flops * util)
    # r/k/v/w streamed once; o written f32; the state never leaves VMEM.
    traffic = n_chunks * (4.0 * c * hd * dtype_bytes + c * hd * 4)
    t_mem = traffic / device.hbm_bw
    t = max(t_compute, t_mem) + n_chunks * device.grid_step_overhead + device.launch_overhead
    if not texture:  # smooth roofline: the model-side view (see perfmodel)
        return t
    return t / _texture(device, "wkv", (cfg.chunk,), problem)


def predict_wkv_gflops(
    problem: WkvProblem, cfg: WkvConfig, device: DeviceModel = TPU_V5E, **kw
) -> float:
    t = predict_wkv_time(problem, cfg, device, **kw)
    if not np.isfinite(t) or t <= 0:
        return 0.0
    s, hd = problem
    useful = 8.0 * s * hd * hd  # the recurrence's irreducible state math
    return useful / t / 1e9


def build_wkv_matrix(
    problems: list[WkvProblem], configs=None, device: DeviceModel | str | None = TPU_V5E,
    *, texture: bool = True,
) -> np.ndarray:
    if not isinstance(device, DeviceModel):
        device = _device(device)
    configs = list(configs if configs is not None else wkv_config_space())
    perf = np.zeros((len(problems), len(configs)))
    for i, p in enumerate(problems):
        for j, c in enumerate(configs):
            perf[i, j] = predict_wkv_gflops(p, c, device, texture=texture)
    return perf


def harvest_wkv_problems(arch_ids: list[str] | None = None) -> list[WkvProblem]:
    """WKV shapes the attention-free architectures actually launch."""
    from repro.configs import registry

    arch_ids = arch_ids or list(registry.ARCHS)
    out: set[WkvProblem] = set()
    for arch in arch_ids:
        cfg = registry.get(arch)
        if cfg.family != "ssm":  # RWKV-style time-mix archs only
            continue
        hd = cfg.head_dim
        for shape in registry.shapes_for(arch):
            sp = registry.SHAPES[shape]
            if sp.kind == "decode":
                out.add((1, hd))
            else:
                out.add((sp.seq_len, hd))
                out.add((min(2048, sp.seq_len), hd))  # chunked-prefill sub-blocks
    return sorted(out)


# ---------------------------------------------------------------------------
# selective-SSM scan (Mamba / Hymba recurrence)
# ---------------------------------------------------------------------------
def predict_ssm_time(
    problem: SsmProblem,
    cfg: SsmConfig,
    device: DeviceModel = TPU_V5E,
    *,
    n_state: int = SSM_STATE_N,
    texture: bool = True,
) -> float:
    """Predicted seconds for one batched-sequence SSM scan; inf if invalid."""
    s, d = problem
    bd = min(cfg.block_d, _round_up(max(d, 1), 8))
    c = min(cfg.chunk, _round_up(max(s, 1), 8))
    t_d, t_s = _ceil(max(d, 1), bd), _ceil(max(s, 1), c)
    steps = t_d * t_s
    # dt*A tile is the VMEM hog: (chunk, bd*N) f32, double-buffered, plus the
    # carried (bd, N) state and the dtx/y tiles.
    vmem = 2 * c * bd * n_state * 4 + bd * n_state * 4 + 3 * c * bd * 4
    if vmem > device.vmem_bytes:
        return float("inf")
    # exp + state update + output contraction ~ 6 ops per (t, channel, state).
    flops = 6.0 * steps * c * bd * n_state
    util = (min(bd, device.mxu_dim) / device.mxu_dim) * (0.5 + 0.5 * min(c, 64) / 64.0)
    t_compute = flops / (device.peak_flops * util)
    # dta dominates traffic (N x the activations); b/c re-streamed per d block.
    traffic = steps * (c * bd * (2.0 + n_state) * 4 + 2.0 * c * n_state * 4)
    t_mem = traffic / device.hbm_bw
    t = max(t_compute, t_mem) + steps * device.grid_step_overhead + device.launch_overhead
    if not texture:  # smooth roofline: the model-side view (see perfmodel)
        return t
    return t / _texture(device, "ssm", (cfg.block_d, cfg.chunk), problem)


def predict_ssm_gflops(
    problem: SsmProblem, cfg: SsmConfig, device: DeviceModel = TPU_V5E, **kw
) -> float:
    t = predict_ssm_time(problem, cfg, device, **kw)
    if not np.isfinite(t) or t <= 0:
        return 0.0
    s, d = problem
    useful = 6.0 * s * d * kw.get("n_state", SSM_STATE_N)
    return useful / t / 1e9


def build_ssm_matrix(
    problems: list[SsmProblem], configs=None, device: DeviceModel | str | None = TPU_V5E,
    *, texture: bool = True,
) -> np.ndarray:
    if not isinstance(device, DeviceModel):
        device = _device(device)
    configs = list(configs if configs is not None else ssm_config_space())
    perf = np.zeros((len(problems), len(configs)))
    for i, p in enumerate(problems):
        for j, c in enumerate(configs):
            perf[i, j] = predict_ssm_gflops(p, c, device, texture=texture)
    return perf


def harvest_ssm_problems(arch_ids: list[str] | None = None) -> list[SsmProblem]:
    """Selective-scan shapes the hybrid (Mamba-head) architectures launch.

    Decode is excluded: ``mamba_decode_step`` advances the state inline and
    never dispatches ``ops.ssm_scan``.
    """
    from repro.configs import registry

    arch_ids = arch_ids or list(registry.ARCHS)
    out: set[SsmProblem] = set()
    for arch in arch_ids:
        cfg = registry.get(arch)
        if cfg.family != "hybrid":
            continue
        d = cfg.d_model
        for shape in registry.shapes_for(arch):
            sp = registry.SHAPES[shape]
            if sp.kind == "decode":
                continue
            out.add((sp.seq_len, d))
            out.add((min(2048, sp.seq_len), d))
    return sorted(out)


def _texture(device: DeviceModel, op: str, cfg_key: tuple, problem: tuple) -> float:
    e_cfg = 1.0 - 0.10 * _hash_unit(device.name, f"{op}_cfg", cfg_key)
    bucket = tuple(int(np.log2(max(v, 1))) for v in problem)
    e_int = 1.0 + 0.07 * (2.0 * _hash_unit(device.name, f"{op}_int", cfg_key, bucket) - 1.0)
    return max(e_cfg * e_int, 1e-3)
