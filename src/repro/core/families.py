"""Kernel-family registry: every tunable op as a first-class pipeline citizen.

The paper's pitch is that clustering + classification makes kernel selection
work for *general-purpose* libraries — any routine, any input.  This module
is the piece that makes that true here: a :class:`KernelFamily` declares
everything the tune -> deploy -> dispatch -> retune pipeline needs to know
about one op, and every layer iterates the registry instead of special-casing
matmul/attention:

  * ``tuner.tune`` / ``tune_fleet``     loop ``families()`` to tune each op;
  * ``dispatch.Deployment``             stores per-family ``(configs, tree)``
                                        and answers ``select(family, problem)``;
  * ``kernels.ops``                     resolves the policy hook and memoizes
                                        by family-qualified shape key;
  * ``core.retune``                     buckets telemetry and drift per
                                        ``(device, family, shape)``;
  * ``core.codegen``                    emits launcher routing per family.

Adding a new op to the whole pipeline is one ``register_family`` call (see
DESIGN.md §9 for the recipe); ``wkv`` and ``ssm_scan`` are registered below
exactly that way.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Sequence

import numpy as np

from repro.kernels.attention import DEFAULT_ATTN_CONFIG, AttentionConfig, attention_config_space
from repro.kernels.matmul import DEFAULT_CONFIG, MatmulConfig, config_space
from repro.kernels.ssm import DEFAULT_SSM_CONFIG, SsmConfig, ssm_config_space
from repro.kernels.wkv import DEFAULT_WKV_CONFIG, WkvConfig, wkv_config_space


class FamilyTuning(NamedTuple):
    """One family's shipped artifact: deployed configs + runtime classifier."""

    configs: list
    tree: object | None


@dataclasses.dataclass(frozen=True)
class KernelFamily:
    """Everything the pipeline needs to know about one tunable op.

    ``perf_matrix(problems, configs, device_name)`` is the benchmark-data
    source (analytic model on TPU-less hosts, a measure hook on hardware);
    ``harvest(arch_ids)`` yields the problems the assigned architectures
    actually launch; ``features`` is the trace-time featurization shared by
    tuning and dispatch.  ``policy_attr`` names the ``KernelPolicy`` method
    (``select_matmul``, ``select_wkv``, ...) so the ops layer can resolve the
    hook generically; ``name`` doubles as the dispatch-op / telemetry key.
    """

    name: str
    config_cls: type
    config_space: Callable[[], Sequence]
    default_config: object
    feature_names: tuple[str, ...]
    features: Callable[[list[tuple]], np.ndarray]
    harvest: Callable[[list[str] | None], list[tuple]]
    perf_matrix: Callable[[list[tuple], Sequence, str | None], np.ndarray]
    policy_attr: str
    problem_arity: int
    reference: str  # where the numerically-identical fallback lives
    default_n_kernels: int = 4
    # True: the perf surface differs per device, so tune_fleet re-tunes this
    # family per device; False: one tuning is shared across the fleet.
    device_sensitive: bool = False
    # Decision-tree hyperparameters for this family's runtime classifier —
    # shared by tune_family and incremental_retune so a retuned artifact
    # refits with the same capacity the offline tuning shipped.
    tree_max_depth: int = 6
    tree_min_samples_leaf: int = 1
    # Model-side (measurement-free) perf predictor with the same signature as
    # ``perf_matrix``.  The staged pipeline (repro.core.pipeline) prunes the
    # config space and allocates its measurement budget from this table; a
    # family without one tunes full-harvest only.  For the analytic-model
    # families this is the untextured roofline (``texture=False``).
    model_matrix: Callable[[list[tuple], Sequence, str | None], np.ndarray] | None = None

    def make_tree(self, seed: int = 0):
        """A fresh (unfit) runtime classifier for this family."""
        from .classify import DecisionTreeClassifier

        return DecisionTreeClassifier(
            max_depth=self.tree_max_depth, min_samples_leaf=self.tree_min_samples_leaf,
            seed=seed,
        )


_REGISTRY: dict[str, KernelFamily] = {}


def register_family(family: KernelFamily) -> KernelFamily:
    """Add (or replace) one family; returns it for decorator-style use."""
    if not family.name or any(ch in family.name for ch in " ,/"):
        raise ValueError(f"bad family name {family.name!r}")
    _REGISTRY[family.name] = family
    return family


def unregister_family(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_family(name: str) -> KernelFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel family {name!r}; registered: {family_names()}") from None


def family_names() -> list[str]:
    """Registered family names, matmul first (it anchors the Deployment)."""
    return sorted(_REGISTRY, key=lambda n: (n != "matmul", n))


def families() -> list[KernelFamily]:
    return [_REGISTRY[n] for n in family_names()]


def is_registered(name: str) -> bool:
    return name in _REGISTRY


# ---------------------------------------------------------------------------
# built-in families
# ---------------------------------------------------------------------------
def _matmul_features(problems):
    from .dataset import problem_features

    return problem_features(problems)


def _matmul_harvest(arch_ids):
    from .dataset import harvest_problems

    return harvest_problems(arch_ids)


def _matmul_perf(problems, configs, device_name):
    from .perfmodel import DEVICES, build_perf_matrix

    if device_name not in DEVICES:
        raise ValueError(
            f"no analytic matmul perf model for device {device_name!r}; "
            f"use a measured dataset (repro.core.cpubench) instead"
        )
    return build_perf_matrix(problems, list(configs), DEVICES[device_name])


def _matmul_model(problems, configs, device_name):
    from .perfmodel import DEVICES, TPU_V5E, build_perf_matrix

    dev = DEVICES.get(device_name, TPU_V5E) if device_name else TPU_V5E
    return build_perf_matrix(problems, list(configs), dev, texture=False)


def _attn_features(problems):
    from .attnmodel import attn_problem_features

    return attn_problem_features(problems)


def _attn_harvest(arch_ids):
    from .attnmodel import harvest_attn_problems

    return harvest_attn_problems(arch_ids)


def _attn_perf(problems, configs, device_name):
    from .attnmodel import build_attn_matrix
    from .perfmodel import DEVICES, TPU_V5E

    return build_attn_matrix(problems, list(configs), DEVICES.get(device_name, TPU_V5E))


def _attn_model(problems, configs, device_name):
    from .attnmodel import build_attn_matrix
    from .perfmodel import DEVICES, TPU_V5E

    dev = DEVICES.get(device_name, TPU_V5E)
    return build_attn_matrix(problems, list(configs), dev, texture=False)


def _wkv_perf(problems, configs, device_name):
    from .recmodel import build_wkv_matrix

    return build_wkv_matrix(problems, list(configs), device_name)


def _wkv_model(problems, configs, device_name):
    from .recmodel import build_wkv_matrix

    return build_wkv_matrix(problems, list(configs), device_name, texture=False)


def _wkv_features(problems):
    from .recmodel import wkv_problem_features

    return wkv_problem_features(problems)


def _wkv_harvest(arch_ids):
    from .recmodel import harvest_wkv_problems

    return harvest_wkv_problems(arch_ids)


def _ssm_perf(problems, configs, device_name):
    from .recmodel import build_ssm_matrix

    return build_ssm_matrix(problems, list(configs), device_name)


def _ssm_model(problems, configs, device_name):
    from .recmodel import build_ssm_matrix

    return build_ssm_matrix(problems, list(configs), device_name, texture=False)


def _ssm_features(problems):
    from .recmodel import ssm_problem_features

    return ssm_problem_features(problems)


def _ssm_harvest(arch_ids):
    from .recmodel import harvest_ssm_problems

    return harvest_ssm_problems(arch_ids)


from .attnmodel import ATTN_FEATURE_NAMES  # noqa: E402
from .dataset import FEATURE_NAMES as MATMUL_FEATURE_NAMES  # noqa: E402
from .recmodel import SSM_FEATURE_NAMES, WKV_FEATURE_NAMES  # noqa: E402

MATMUL = register_family(
    KernelFamily(
        name="matmul",
        config_cls=MatmulConfig,
        config_space=config_space,
        default_config=DEFAULT_CONFIG,
        feature_names=tuple(MATMUL_FEATURE_NAMES),
        features=_matmul_features,
        harvest=_matmul_harvest,
        perf_matrix=_matmul_perf,
        policy_attr="select_matmul",
        problem_arity=4,
        reference="jnp.dot (XLA)",
        default_n_kernels=8,
        device_sensitive=True,
        model_matrix=_matmul_model,
    )
)

ATTENTION = register_family(
    KernelFamily(
        name="attention",
        config_cls=AttentionConfig,
        config_space=attention_config_space,
        default_config=DEFAULT_ATTN_CONFIG,
        feature_names=tuple(ATTN_FEATURE_NAMES),
        features=_attn_features,
        harvest=_attn_harvest,
        perf_matrix=_attn_perf,
        policy_attr="select_attention",
        problem_arity=3,
        reference="repro.kernels.ref.flash_attention_ref",
        default_n_kernels=4,
        model_matrix=_attn_model,
    )
)

WKV = register_family(
    KernelFamily(
        name="wkv",
        config_cls=WkvConfig,
        config_space=wkv_config_space,
        default_config=DEFAULT_WKV_CONFIG,
        feature_names=tuple(WKV_FEATURE_NAMES),
        features=_wkv_features,
        harvest=_wkv_harvest,
        perf_matrix=_wkv_perf,
        policy_attr="select_wkv",
        problem_arity=2,
        reference="repro.kernels.ref.wkv_ref",
        default_n_kernels=3,
        model_matrix=_wkv_model,
    )
)

SSM_SCAN = register_family(
    KernelFamily(
        name="ssm_scan",
        config_cls=SsmConfig,
        config_space=ssm_config_space,
        default_config=DEFAULT_SSM_CONFIG,
        feature_names=tuple(SSM_FEATURE_NAMES),
        features=_ssm_features,
        harvest=_ssm_harvest,
        perf_matrix=_ssm_perf,
        policy_attr="select_ssm",
        problem_arity=2,
        reference="repro.kernels.ref.ssm_scan_ref",
        default_n_kernels=4,
        model_matrix=_ssm_model,
    )
)


def build_family_dataset(
    family: str | KernelFamily,
    problems: list[tuple] | None = None,
    device_name: str = "tpu_v5e",
):
    """Benchmark table for any registered family as a ``TuningDataset``."""
    from .dataset import TuningDataset

    fam = family if isinstance(family, KernelFamily) else get_family(family)
    problems = problems if problems is not None else fam.harvest(None)
    configs = list(fam.config_space())
    perf = fam.perf_matrix(problems, configs, device_name)
    return TuningDataset(
        device=device_name, problems=list(problems), configs=configs, perf=perf,
        source="model", family=fam.name,
    )
