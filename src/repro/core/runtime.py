"""`KernelRuntime`: an explicit, multi-tenant runtime handle (DESIGN.md §10).

The paper's pipeline assumes one library instance per process, and until this
module the reproduction inherited that: a process-global registry in
``repro.kernels.ops`` mutated by ~20 module-level functions.  Production
serving needs *isolated, concurrently-active tunings* — A/B shadow policies,
per-tenant deployments, test isolation without ``clear_*`` teardown
choreography.  Following the model-driven-adaptive-libraries framing
(selection state as a first-class library object, not ambient process state),
everything that used to be global now lives on a :class:`KernelRuntime`:

  * the per-device policy registry + activation/epoch state (hot-swap unit);
  * per-thread shape-memoization caches and their counters;
  * the selection log (telemetry source of the continuous tuning loop);
  * the Pallas dispatch flags.

Scoping: ``with rt.activate(): ...`` makes ``rt`` the innermost active
runtime for the *current thread*; ``repro.kernels.ops`` dispatch
(``matmul`` / ``attention`` / ``wkv`` / ``ssm_scan`` and the
``select_*_config`` helpers) consults :func:`current_runtime`.  With nothing
activated, the process-wide :func:`default_runtime` serves — which is exactly
what the legacy module-level API in ``repro.kernels.ops`` now shims over, so
old code keeps producing byte-identical selections.

The whole lifecycle reads as four lines through the facade::

    bundle = repro.tune(["granite-8b"], devices=("tpu_v5e",))
    rt = bundle.runtime(device="tpu_v5e")
    engine = rt.serve(model, params)
    engine.run(requests)

Thread model: one runtime may serve many threads (its registry mutations are
lock+epoch protected and its dispatch caches are per-thread, exactly like the
old global state), and many runtimes may serve one process (each thread picks
its runtime via activation).  Two engines with different runtimes on
different threads share nothing: no policy, shape-cache, or selection-log
cross-talk.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import OrderedDict, deque

DEFAULT_LOG_CAP = 4096
DEFAULT_SHAPE_CACHE_CAP = 1024
DEFAULT_INCIDENT_CAP = 256
DEFAULT_SWAP_HISTORY = 4
# Circuit breaker re-probe cadence, counted in selections of the quarantined
# config: first re-probe after QUARANTINE_BACKOFF skipped selections, doubling
# per failed probe up to the cap.
QUARANTINE_BACKOFF = 4
QUARANTINE_MAX_BACKOFF = 256

_MISS = object()


@dataclasses.dataclass(frozen=True)
class Objective:
    """A serving objective consulted at kernel-selection time.

    ``latency_target_ms`` is a per-token SLO: while an objective with a
    target is installed (:meth:`KernelRuntime.set_objective`), selection
    routes through the policy's ``select_for_objective(family, problem,
    objective)`` — typically trading peak throughput for predicted latency
    (e.g. the analytic-model-fastest deployed config instead of the
    classifier's throughput pick, or pausing online exploration).  Policies
    without ``select_for_objective`` are unaffected.

    ``prefill_chunk_tokens`` is a work-granularity hint set alongside the
    latency target by SLO-mode serving engines: it caps how many prompt
    tokens one prefill chunk may cover, so deadline pressure shrinks the
    unit of prefill work interleaved between decode rounds (DESIGN.md §13).
    Kernel policies may consult it to prefer configs tuned at the chunk's
    GEMM shapes; the serving scheduler enforces it as the admission budget.
    """

    latency_target_ms: float | None = None
    prefill_chunk_tokens: int | None = None

    def __bool__(self) -> bool:
        return (self.latency_target_ms is not None
                or self.prefill_chunk_tokens is not None)


class _RuntimeLocal(threading.local):
    """One thread's dispatch fast path *within one runtime*.

    ``family_stats`` tracks hit/miss per kernel family — cache keys are
    family-qualified (``(op, *problem)``) so an ssm ``(s, d)`` problem can
    never alias a matmul ``(m, k)`` tuple.  ``hook_cache`` memoizes the
    resolved policy hook per family; it depends only on the live policy, so
    it lives and dies with the shape cache (epoch sync).
    """

    def __init__(self):
        self.epoch: int = -1  # never matches: first dispatch syncs
        self.policy = None
        self.shape_cache: OrderedDict[tuple, object] = OrderedDict()
        self.shape_cache_cap: int = DEFAULT_SHAPE_CACHE_CAP
        self.cache_hits: int = 0
        self.cache_misses: int = 0
        self.family_stats: dict[str, list] = {}  # op -> [hits, misses]
        self.hook_cache: dict[str, object] = {}


_runtime_ids = itertools.count(1)


class KernelRuntime:
    """Explicit owner of kernel-selection state (policies, caches, telemetry).

    Construct one per tenant / deployment / test; or use
    :func:`default_runtime` (what the legacy ``repro.kernels.ops`` module
    functions mutate).  All registry mutations are atomic under the runtime's
    lock with an epoch bump; dispatching threads re-sync lazily on their next
    selection, so a cached config from an old policy can never be served as
    if the new policy had chosen it (the DESIGN.md §8 hot-swap contract,
    unchanged — just per-runtime now).
    """

    def __init__(self, name: str | None = None):
        self.name = name or f"runtime-{next(_runtime_ids)}"
        self._lock = threading.RLock()
        self._epoch: int = 0
        self._policy = None
        self._device_policies: dict[str, object] = {}
        self._active_device: str | None = None
        self._requested_device: str | None = None
        self.use_pallas: bool = False  # CPU host default: XLA dot
        self.interpret: bool = False
        self._log_enabled: bool = False
        self._selection_log: deque[tuple] = deque(maxlen=DEFAULT_LOG_CAP)
        self._shape_cache_cap: int = DEFAULT_SHAPE_CACHE_CAP
        self._local = _RuntimeLocal()
        # -- failure containment (DESIGN.md §11) --
        self.fault_plan = None  # repro.core.faults.FaultPlan, or None
        self._validate_outputs: bool = False
        self._quarantine: dict[tuple[str, str], dict] = {}
        self._incidents: deque[dict] = deque(maxlen=DEFAULT_INCIDENT_CAP)
        self._incident_count: int = 0
        self._swap_history: deque[tuple[str, object, int]] = deque(
            maxlen=DEFAULT_SWAP_HISTORY
        )
        # -- SLO-aware selection (serving tier) --
        self._objective: Objective | None = None

    def __repr__(self) -> str:
        with self._lock:
            return (
                f"KernelRuntime({self.name!r}, active_device={self._active_device!r}, "
                f"devices={sorted(self._device_policies)}, epoch={self._epoch})"
            )

    # -- scoping --------------------------------------------------------------
    def activate(self) -> "_Activation":
        """Context manager making this the innermost active runtime.

        Per-thread and reentrant: ``with rt.activate():`` pushes ``rt`` onto
        the calling thread's activation stack, so ops-layer dispatch inside
        the block consults ``rt`` — other threads are unaffected.  (Not to be
        confused with :meth:`activate_device`, which picks which *registered
        per-device policy* is live inside this runtime.)
        """
        return _Activation(self)

    # -- policy installation ---------------------------------------------------
    def install(self, policy) -> None:
        """Install ``policy`` directly (manual single-device path).

        Clears the active-device marker: a manually installed policy is not
        tied to the registry, so later :meth:`install_for_device` calls won't
        silently replace it.
        """
        with self._lock:
            self._policy = policy
            self._active_device = None
            self._requested_device = None
            self._epoch += 1
        self.clear_shape_cache()

    def policy(self):
        """The live policy, syncing this thread's view of a hot swap."""
        return self._sync()

    def install_for_device(self, device: str, policy) -> None:
        """Register (or with ``None``, drop) the policy tuned for one device.

        Registration alone activates nothing; :meth:`activate_device` picks
        which registered policy serves.  If ``device`` is the currently
        active one, the live policy is refreshed in place — the zero-downtime
        hot-swap primitive the retune loop uses.
        """
        from .devices import canonical_device_name

        name = canonical_device_name(device)
        with self._lock:
            if policy is None:
                self._device_policies.pop(name, None)
                if name == self._active_device:
                    # Dropping the live policy deactivates it — a stale marker
                    # would report an active device while dispatch runs unpoliced.
                    self._policy = None
                    self._active_device = None
                    self._requested_device = None
                    self._epoch += 1
            else:
                prev = self._device_policies.get(name)
                if prev is not None and prev is not policy:
                    # Bounded swap history: rollback_device() restores the
                    # most recent predecessor after a regressing hot-swap.
                    self._swap_history.append((name, prev, self._epoch))
                self._device_policies[name] = policy
                if name == self._active_device:
                    self._policy = policy
                    self._epoch += 1
        # No explicit cache clear: the epoch bump (live-device cases only)
        # makes every dispatching thread drop its shape cache on its next
        # selection; registering an inactive device leaves warm caches alone.

    def device_policies(self) -> dict[str, object]:
        """Snapshot of the registered per-device policies (name -> policy)."""
        with self._lock:
            return dict(self._device_policies)

    # -- serving objective ----------------------------------------------------
    def set_objective(self, objective: Objective | None) -> None:
        """Install (or with ``None``/empty, clear) the serving objective.

        Epoch-bumped like a policy swap: every dispatching thread drops its
        shape and hook caches on its next selection, so objective-aware
        selections never serve from (or pollute) the unconstrained cache.
        The serving engine drives this from its SLO pressure loop; the
        objective applies runtime-wide — one engine per runtime (the router
        layout) keeps tenants isolated.
        """
        if objective is not None and not objective:
            objective = None
        with self._lock:
            if objective == self._objective:
                return
            self._objective = objective
            self._epoch += 1
        self.clear_shape_cache()

    def objective(self) -> Objective | None:
        """The live serving objective (``None`` when unconstrained)."""
        return self._objective

    def active_device(self) -> str | None:
        """Canonical name of the device whose registered policy is live."""
        return self._active_device

    def device_resolution(self) -> tuple[str | None, str | None]:
        """(requested, resolved) device names from the last activation."""
        with self._lock:
            return (self._requested_device, self._active_device)

    def activate_device(self, device: str | None = None, *, strict: bool = False) -> str:
        """Make the registered policy for ``device`` the live one.

        ``device=None`` detects the host (``REPRO_DEVICE`` override first).
        An unregistered device resolves to the nearest registered sibling via
        ``repro.core.devices.resolve_device``; ``strict=True`` raises instead
        of crossing platform families.  Returns the resolved canonical name.
        """
        from .devices import canonical_device_name, detect_device, resolve_device

        requested = canonical_device_name(device) if device is not None else detect_device()
        with self._lock:
            resolved = resolve_device(requested, list(self._device_policies), strict=strict)
            if resolved is None:
                raise KeyError(
                    f"no kernel policy registered for device {requested!r} "
                    f"(registered: {sorted(self._device_policies)})"
                )
            self._policy = self._device_policies[resolved]
            self._active_device = resolved
            self._requested_device = requested
            self._epoch += 1
        self.clear_shape_cache()
        return resolved

    def clear_device_policies(self) -> None:
        """Drop every registered per-device policy, deactivating the live one.

        A policy activated from the registry is uninstalled with it (the
        marker and the live policy must never disagree); a policy installed
        manually via :meth:`install` is not registry-owned and survives.
        """
        with self._lock:
            self._device_policies.clear()
            if self._active_device is not None:
                self._policy = None
            self._active_device = None
            self._requested_device = None
            self._epoch += 1
        self.clear_shape_cache()

    def install_bundle(self, bundle, device: str | None = None, *, strict: bool = False):
        """Install a :class:`~repro.core.bundle.DeploymentBundle` (or path).

        The bundle's policies become this runtime's registry (replacing any
        previous registrations — installing a bundle is authoritative) and
        the one resolved for ``device`` (default: detected host) activates.
        Returns the activated ``Deployment``.
        """
        from .bundle import DeploymentBundle
        from .devices import canonical_device_name, detect_device

        if not isinstance(bundle, DeploymentBundle):
            bundle = DeploymentBundle.load(bundle)
        requested = canonical_device_name(device) if device else detect_device()
        # Resolve (and raise under strict) before touching the live registry.
        bundle.deployment_for(requested, strict=strict)
        self.clear_device_policies()
        for name, dep in bundle.deployments.items():
            self.install_for_device(name, dep)
        resolved = self.activate_device(requested, strict=strict)
        return bundle.deployments[resolved]

    def apply_policy_update(self, deployment, device: str | None = None) -> str | None:
        """Adopt a control-plane-pushed deployment (subscription client path).

        The engine-less counterpart of ``ServingEngine.adopt_deployment``: a
        :class:`repro.control.PolicySubscriber` attached directly to a
        runtime (a trainer, a batch job — anything dispatching without a
        serving engine) lands pushed artifacts here.  ``device=None`` targets
        the currently active device; the update goes through
        :meth:`install_for_device`, so when the target is live this is the
        same lock+epoch hot-swap the retune loop uses (every dispatching
        thread drops its shape cache on its next selection).  With no target
        device at all the policy installs directly.  Returns the canonical
        device name the update landed on (``None`` for a direct install).
        """
        from .devices import canonical_device_name

        target = canonical_device_name(device) if device is not None else self.active_device()
        if target is None:
            self.install(deployment)
            return None
        self.install_for_device(target, deployment)
        if self.active_device() is None:
            self.activate_device(target)
        return target

    # -- pallas dispatch flags -------------------------------------------------
    def set_pallas_enabled(self, enabled: bool, *, interpret: bool = False) -> None:
        """Route ops through the Pallas kernels (interpret=True on CPU)."""
        self.use_pallas = enabled
        self.interpret = interpret

    # -- failure containment (DESIGN.md §11) -----------------------------------
    def set_fault_plan(self, plan) -> None:
        """Attach (or with ``None``, detach) a chaos-injection plan.

        An attached plan arms the ops-layer guard's injection sites *and* its
        non-finite output validation — injected NaN/Inf must be caught, and a
        chaos run should exercise the same validation a hardened production
        deployment would enable via :meth:`set_output_validation`.
        """
        self.fault_plan = plan

    def set_output_validation(self, enabled: bool) -> None:
        """Opt dispatch into checking kernel outputs for NaN/Inf.

        Only concrete (non-tracer) outputs are checked — inside a ``jit``
        trace there is nothing to inspect.  Always on while a fault plan is
        attached.
        """
        self._validate_outputs = bool(enabled)

    def output_validation_enabled(self) -> bool:
        return self._validate_outputs or self.fault_plan is not None

    def record_incident(self, rec: dict) -> dict:
        """Append one structured incident (see ``repro.core.faults.incident``).

        Stamps the monotonic incident sequence number; the bounded deque
        keeps the newest :data:`DEFAULT_INCIDENT_CAP` records while
        :meth:`incident_count` keeps counting — the engine's health watchdog
        compares counts, not buffer lengths.
        """
        with self._lock:
            self._incident_count += 1
            rec = dict(rec, seq=self._incident_count)
            self._incidents.append(rec)
        return rec

    def incidents(self) -> list[dict]:
        """Newest-last snapshot of recorded dispatch/serving incidents."""
        with self._lock:
            return list(self._incidents)

    def incident_count(self) -> int:
        """Monotonic count of incidents ever recorded on this runtime."""
        return self._incident_count

    def quarantine_config(self, family: str, config, error=None) -> dict:
        """Open (or re-open) the circuit breaker for ``(device, family, config)``.

        While open, selections that would serve ``config`` are redirected to
        the family default; every :data:`QUARANTINE_BACKOFF` (doubling per
        failed re-probe, capped at :data:`QUARANTINE_MAX_BACKOFF`) redirected
        selections the breaker goes half-open and serves the quarantined
        config once so the guard can re-probe it.  The epoch bump makes every
        dispatching thread drop its shape cache on its next selection — a
        cached entry from before the quarantine can never be served after it.
        """
        name = config.name() if hasattr(config, "name") and callable(config.name) else str(config)
        with self._lock:
            entry = self._quarantine.get((family, name))
            if entry is None:
                entry = {
                    "family": family,
                    "config": config,
                    "name": name,
                    "device": self._active_device,
                    "failures": 0,
                    "backoff": QUARANTINE_BACKOFF,
                    "countdown": QUARANTINE_BACKOFF,
                    "skipped": 0,
                    "probes": 0,
                    "state": "open",
                    "error": None,
                }
                self._quarantine[(family, name)] = entry
            else:
                entry["backoff"] = min(entry["backoff"] * 2, QUARANTINE_MAX_BACKOFF)
                entry["countdown"] = entry["backoff"]
                entry["state"] = "open"
            entry["failures"] += 1
            if error is not None:
                entry["error"] = f"{type(error).__name__}: {error}" if isinstance(
                    error, BaseException) else str(error)
            self._epoch += 1
        self.clear_shape_cache()
        return dict(entry)

    def absolve(self, family: str, config) -> bool:
        """Close the breaker after a successful re-probe (config healthy again)."""
        name = config.name() if hasattr(config, "name") and callable(config.name) else str(config)
        with self._lock:
            entry = self._quarantine.pop((family, name), None)
            if entry is not None:
                self._epoch += 1
        if entry is not None:
            self.clear_shape_cache()
        return entry is not None

    def quarantined(self) -> list[dict]:
        """Snapshot of open/half-open breaker entries (shallow copies)."""
        with self._lock:
            return [dict(e) for e in self._quarantine.values()]

    def _apply_quarantine(self, family: str, cfg):
        """Selection-time breaker: redirect a quarantined config, or probe it.

        Called only when the quarantine table is non-empty (the happy path
        pays one falsy-dict check).  Counting happens per *selection*, so a
        shape-cache hit still advances the re-probe countdown — the breaker
        sits after the cache, on the served value.
        """
        if cfg is None:
            return cfg
        name = cfg.name() if hasattr(cfg, "name") and callable(cfg.name) else str(cfg)
        with self._lock:
            entry = self._quarantine.get((family, name))
            if entry is None:
                return cfg
            if entry["device"] not in (None, self._active_device):
                return cfg
            entry["countdown"] -= 1
            if entry["countdown"] <= 0:
                # Half-open: serve the quarantined config once as a probe.
                # The countdown resets immediately so an unexecuted selection
                # (launcher-side select_* with no kernel run) cannot wedge
                # the breaker in half-open.
                entry["countdown"] = entry["backoff"]
                entry["probes"] += 1
                entry["state"] = "half_open"
                return cfg
            entry["skipped"] += 1
            entry["state"] = "open"
        from .families import get_family

        fallback = get_family(family).default_config
        return fallback if fallback is not None else cfg

    def probing(self, family: str, config) -> bool:
        """True when ``config`` is a half-open breaker's live probe."""
        name = config.name() if hasattr(config, "name") and callable(config.name) else str(config)
        with self._lock:
            entry = self._quarantine.get((family, name))
            return entry is not None and entry["state"] == "half_open"

    def swap_history(self) -> list[tuple[str, object, int]]:
        """Bounded (device, previous_policy, epoch) history of hot-swaps."""
        with self._lock:
            return list(self._swap_history)

    def rollback_device(self, device: str | None = None):
        """Reinstall the most recent pre-swap policy for ``device``.

        The auto-rollback path for an installed-but-regressing retune: pops
        the newest swap-history entry for the device (default: the active
        one) and restores it, with the usual epoch bump when the device is
        live.  Returns the restored policy, or ``None`` with no history.
        """
        from .devices import canonical_device_name

        name = canonical_device_name(device) if device else self._active_device
        if name is None:
            return None
        with self._lock:
            prev = None
            for i in range(len(self._swap_history) - 1, -1, -1):
                if self._swap_history[i][0] == name:
                    prev = self._swap_history[i][1]
                    del self._swap_history[i]
                    break
            if prev is None:
                return None
            self._device_policies[name] = prev
            if name == self._active_device:
                self._policy = prev
                self._epoch += 1
        return prev

    # -- selection log (telemetry) ---------------------------------------------
    def set_selection_logging(self, enabled: bool, *, cap: int | None = None) -> None:
        """Opt in/out of recording dispatch decisions; ``cap`` bounds the buffer."""
        with self._lock:
            self._log_enabled = enabled
            if cap is not None:
                self._selection_log = deque(self._selection_log, maxlen=max(int(cap), 1))

    def selection_logging_enabled(self) -> bool:
        return self._log_enabled

    def selection_log(self) -> list[tuple]:
        """Trace-time dispatch decisions (op, problem, chosen config).

        Empty unless :meth:`set_selection_logging` opted in; at most the
        newest ``cap`` entries are retained.  The log is runtime-global (not
        per-thread): the retune loop's telemetry reader may run on a
        different thread than the dispatches it observes.
        """
        return list(self._selection_log)

    def clear_selection_log(self) -> None:
        self._selection_log.clear()

    def telemetry(self, online=None):
        """Aggregate this runtime's selection log into a `TelemetrySnapshot`.

        Handle-side spelling of ``TelemetrySnapshot.from_runtime(rt)`` — what
        ``ServingEngine.maybe_retune`` reads each drift-check window.
        """
        from .retune import TelemetrySnapshot

        return TelemetrySnapshot.from_runtime(self, online=online)

    # -- dispatch shape cache --------------------------------------------------
    def policy_epoch(self) -> int:
        """Monotonic counter bumped by every policy mutation (swap observability)."""
        return self._epoch

    def clear_shape_cache(self) -> None:
        """Drop this thread's shape cache (other threads re-sync on epoch bump)."""
        loc = self._local
        loc.shape_cache.clear()
        loc.cache_hits = 0
        loc.cache_misses = 0
        loc.family_stats = {}
        loc.hook_cache = {}

    def set_shape_cache_cap(self, cap: int) -> None:
        """Bound the dispatch cache; oldest (LRU) shape keys are evicted.

        Runtime-level: the calling thread adopts the cap immediately, every
        other thread dispatching against this runtime adopts it at its next
        policy sync (a fresh thread's first selection, or the first selection
        after any epoch bump).
        """
        cap = max(int(cap), 1)
        self._shape_cache_cap = cap
        loc = self._local
        loc.shape_cache_cap = cap
        while len(loc.shape_cache) > cap:
            loc.shape_cache.popitem(last=False)

    def shape_cache_stats(self) -> dict:
        """Hit/miss counters for this thread's dispatch cache (reset on swap).

        ``per_family`` breaks the counters (and resident cache entries) down
        by kernel family — keys are the family-qualified ``op`` names of the
        selection log.
        """
        loc = self._local
        sizes: dict[str, int] = {}
        for key in loc.shape_cache:
            sizes[key[0]] = sizes.get(key[0], 0) + 1
        per_family = {
            op: {"hits": hm[0], "misses": hm[1], "size": sizes.get(op, 0)}
            for op, hm in sorted(loc.family_stats.items())
        }
        for op, size in sorted(sizes.items()):  # entries inherited before any stat
            per_family.setdefault(op, {"hits": 0, "misses": 0, "size": size})
        return {
            "hits": loc.cache_hits,
            "misses": loc.cache_misses,
            "size": len(loc.shape_cache),
            "cap": loc.shape_cache_cap,
            "per_family": per_family,
        }

    # -- selection -------------------------------------------------------------
    def _sync(self):
        """The live policy, after syncing this thread's view of a hot swap.

        The epoch check makes the swap atomic from the dispatcher's side: the
        policy reference and the shape-cache invalidation are taken together
        under the registry lock, so a selection either runs fully against the
        old policy (an in-flight request — fine) or fully against the new one.
        """
        loc = self._local
        if loc.epoch != self._epoch:
            with self._lock:
                loc.policy = self._policy
                loc.epoch = self._epoch
                loc.shape_cache_cap = self._shape_cache_cap
            loc.shape_cache.clear()
            loc.cache_hits = 0
            loc.cache_misses = 0
            loc.family_stats = {}
            loc.hook_cache = {}
        return loc.policy

    def _select(self, op: str, problem: tuple, policy, select_fn):
        """Policy consultation with LRU shape memoization.

        Repeated traces of the same problem shape (the serving engine's
        prefill/decode retraces) hit a dict lookup instead of
        featurize+predict.  Policies whose selections are not a pure function
        of the shape (e.g. the exploring ``OnlinePolicy``) opt out via
        ``cacheable = False``.  ``policy`` is the reference the caller already
        synced — passing it through keeps one selection pinned to one policy
        even if a hot swap lands mid-call.
        """
        loc = self._local
        cacheable = bool(getattr(policy, "cacheable", True))
        key = (op, *problem)
        if cacheable:
            cfg = loc.shape_cache.get(key, _MISS)
            if cfg is not _MISS:
                loc.cache_hits += 1
                loc.family_stats.setdefault(op, [0, 0])[0] += 1
                loc.shape_cache.move_to_end(key)
                if self._quarantine:
                    # Breaker sits after the cache (cache holds the policy's
                    # raw choice): counting per served selection keeps the
                    # re-probe countdown advancing on cache hits too.
                    cfg = self._apply_quarantine(op, cfg)
                if self._log_enabled:
                    self._selection_log.append((op, problem, cfg))
                return cfg
        cfg = select_fn()
        if cacheable:
            loc.cache_misses += 1
            loc.family_stats.setdefault(op, [0, 0])[1] += 1
            loc.shape_cache[key] = cfg
            if len(loc.shape_cache) > loc.shape_cache_cap:
                loc.shape_cache.popitem(last=False)
        if self._quarantine:
            cfg = self._apply_quarantine(op, cfg)
        if self._log_enabled:
            self._selection_log.append((op, problem, cfg))
        return cfg

    def _policy_hook(self, pol, family: str):
        """Resolve the policy's selection callable for ``family``.

        With a serving :class:`Objective` installed and a policy exposing
        ``select_for_objective``, the hook routes through it (SLO-aware
        selection); otherwise the method name comes from the family's
        registry-declared ``policy_attr``, and a policy may instead expose a
        generic ``select(family, problem)``.  Returns a ``hook(problem)``
        callable, or ``None`` when the policy covers none of these (the op
        runs its default config).  Resolution depends only on (policy,
        family, objective) — and an objective change bumps the epoch, which
        drops the per-thread hook cache — so :meth:`select_config` memoizes
        it per thread and the shape-cache fast path never pays registry
        lookup or ``getattr``.
        """
        from .families import get_family

        hook = self._objective_hook(pol, family)
        if hook is not None:
            return hook
        meth = getattr(pol, get_family(family).policy_attr, None)
        if meth is not None:
            return lambda problem: meth(*problem)
        generic = getattr(pol, "select", None)
        if generic is not None:
            return lambda problem: generic(family, problem)
        return None

    def _objective_hook(self, pol, family: str):
        """The SLO-aware selection callable, or None when unconstrained."""
        obj = self._objective
        if obj is None:
            return None
        slo = getattr(pol, "select_for_objective", None)
        if slo is None:
            return None
        return lambda problem: slo(family, problem, obj)

    def select_config(self, family: str, problem: tuple):
        """Generic launcher-side selection for any registered family.

        Shape-memoized under the family-qualified key, recorded in the
        selection log as ``(family, problem, config)``; ``None`` when no
        policy is installed or the policy does not cover this family.
        """
        pol = self._sync()  # drops stale hook/shape caches
        if pol is None:
            return None
        loc = self._local
        hook = loc.hook_cache.get(family, _MISS)
        if hook is _MISS:
            hook = self._policy_hook(pol, family)
            loc.hook_cache[family] = hook
        if hook is None:
            return None
        problem = tuple(problem)
        return self._select(family, problem, pol, lambda: hook(problem))

    def select_matmul_config(self, m: int, k: int, n: int, batch: int = 1):
        """The launcher-side matmul selection path on its own (what
        ``ops.matmul`` runs at trace time); ``None`` with no policy."""
        pol = self._sync()
        if pol is None:
            return None
        hook = self._objective_hook(pol, "matmul")
        if hook is not None:
            return self._select(
                "matmul", (m, k, n, batch), pol, lambda: hook((m, k, n, batch))
            )
        return self._select(
            "matmul", (m, k, n, batch), pol, lambda: pol.select_matmul(m, k, n, batch)
        )

    def select_attention_config(self, sq: int, skv: int, d: int):
        """Launcher-side flash-attention selection (what ``ops.attention`` runs)."""
        pol = self._sync()
        if pol is None:
            return None
        hook = self._objective_hook(pol, "attention")
        if hook is not None:
            return self._select(
                "attention", (sq, skv, d), pol, lambda: hook((sq, skv, d))
            )
        return self._select(
            "attention", (sq, skv, d), pol, lambda: pol.select_attention(sq, skv, d)
        )

    def select_wkv_config(self, s: int, hd: int):
        """Launcher-side WKV selection (what ``ops.wkv`` runs at trace time)."""
        return self.select_config("wkv", (s, hd))

    def select_ssm_config(self, s: int, d: int):
        """Launcher-side selective-scan selection (what ``ops.ssm_scan`` runs)."""
        return self.select_config("ssm_scan", (s, d))

    # -- serving ---------------------------------------------------------------
    def serve(self, model, params, **kwargs):
        """Build a :class:`~repro.serve.engine.ServingEngine` owned by this
        runtime (all its trace-time kernel selections dispatch here)."""
        from repro.serve.engine import ServingEngine

        return ServingEngine(model, params, runtime=self, **kwargs)


class _Activation:
    """``with rt.activate():`` — push/pop on the thread's activation stack."""

    __slots__ = ("runtime",)

    def __init__(self, runtime: KernelRuntime):
        self.runtime = runtime

    def __enter__(self) -> KernelRuntime:
        _active.stack.append(self.runtime)
        return self.runtime

    def __exit__(self, *exc) -> None:
        popped = _active.stack.pop()
        assert popped is self.runtime, "unbalanced KernelRuntime activation"


class _ActiveStack(threading.local):
    def __init__(self):
        self.stack: list[KernelRuntime] = []


_active = _ActiveStack()
_default_lock = threading.Lock()
_default_runtime: KernelRuntime | None = None


def default_runtime() -> KernelRuntime:
    """The process-wide runtime legacy ``repro.kernels.ops`` functions target.

    Created lazily on first use; survives for the process lifetime (or until
    :func:`reset_default_runtime`).
    """
    global _default_runtime
    rt = _default_runtime
    if rt is None:
        with _default_lock:
            rt = _default_runtime
            if rt is None:
                rt = _default_runtime = KernelRuntime(name="default")
    return rt


def reset_default_runtime() -> KernelRuntime:
    """Replace the default runtime with a fresh one (test isolation).

    Threads still dispatching against the old default keep their reference's
    state; new legacy-API calls see the fresh runtime.
    """
    global _default_runtime
    with _default_lock:
        _default_runtime = KernelRuntime(name="default")
        return _default_runtime


def current_runtime() -> KernelRuntime:
    """The innermost runtime activated on this thread, else the default."""
    stack = _active.stack
    return stack[-1] if stack else default_runtime()
