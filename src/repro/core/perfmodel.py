"""Analytic TPU performance model — the benchmark-data source on TPU-less hosts.

The paper's pipeline consumes a dense benchmark table: for each *problem*
(GEMM sizes) the measured gigaflops/s of each *kernel configuration*.  This
container has no TPU, so for the TPU device we derive that table from a
physically-grounded roofline model of the Pallas kernel in
``repro.kernels.matmul`` (the host-CPU dataset in ``benchmarks/`` is measured
for real, mirroring the paper's i7-6700K target).  The tuning pipeline is
agnostic to the data source.

Model, per (problem, config):
  * tile grid  T_m x T_n x T_k (+ batch), dims padded up to block multiples;
  * compute    padded_flops / (peak * mxu_util), where mxu_util penalises
               blocks that under-fill the 128x128 MXU (the analogue of the
               paper's register/occupancy effects);
  * HBM traffic from the exact Pallas tile-revisit rule (a block is re-fetched
    only when its index changes between consecutive grid steps) — this is
    what makes the grid *order* parameter matter, exactly like the paper's
    work-group shapes;
  * per-grid-step pipeline overhead + fixed launch overhead;
  * time = max(compute, memory) + overhead  (overlapped roofline);
  * VMEM-overflow configs are failures (0 gflops), like a kernel the driver
    refuses to launch;
  * deterministic "microarchitectural texture": measured kernels never track
    an analytic roofline exactly (compiler scheduling, bank conflicts,
    prefetch resonances).  We model this as a seeded, reproducible
    multiplicative efficiency per config (+/- ~8%) and per
    (problem-regime, config) interaction (+/- ~5%), plus optional measurement
    noise.  Without it the model is unrealistically smooth — one config
    dominates everywhere and the paper's long-tail-of-optima phenomenon
    (Fig. 2) cannot exist.  This is a documented simulation choice; the
    measured host-CPU dataset (benchmarks/fig6) carries no such term.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.kernels.matmul import VMEM_BYTES, MatmulConfig


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    peak_flops: float  # FLOP/s (bf16)
    hbm_bw: float  # bytes/s
    vmem_bytes: int
    grid_step_overhead: float  # s per grid step (pipeline bubble)
    launch_overhead: float  # s per kernel launch
    mxu_dim: int = 128


# TPU v5e (the production target of this repo).
TPU_V5E = DeviceModel(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    vmem_bytes=VMEM_BYTES,
    grid_step_overhead=150e-9,
    launch_overhead=2e-6,
)

# A TPU-v4-flavoured second device (larger, more bandwidth) so the benchmark
# suite mirrors the paper's two-device comparison (AMD GPU vs Intel CPU).
TPU_V4 = DeviceModel(
    name="tpu_v4",
    peak_flops=275e12,
    hbm_bw=1228e9,
    vmem_bytes=2 * VMEM_BYTES,
    grid_step_overhead=120e-9,
    launch_overhead=2e-6,
)

DEVICES = {d.name: d for d in (TPU_V5E, TPU_V4)}


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def predict_time(
    problem: tuple[int, int, int, int],
    cfg: MatmulConfig,
    device: DeviceModel = TPU_V5E,
    dtype_bytes: int = 2,
    *,
    texture: bool = True,
) -> float:
    """Predicted seconds for one batched GEMM; inf if the config is invalid.

    ``texture=False`` returns the smooth analytic roofline — what a *model*
    can know about a config before running it.  The textured default is the
    simulated *measurement* (roofline + microarchitectural quirks), so the
    gap between the two is exactly the information measuring buys.  The
    staged tuning pipeline prunes on the untextured prediction and spends
    its measurement budget only where that prediction is uncertain.
    """
    m, k, n, batch = problem
    if cfg.vmem_bytes(dtype_bytes) > device.vmem_bytes:
        return float("inf")
    bm = min(cfg.block_m, _round_up(m, 8))
    bn = min(cfg.block_n, _round_up(n, 128))
    bk = min(cfg.block_k, _round_up(k, 128))
    t_m, t_n, t_k = _ceil_div(m, bm), _ceil_div(n, bn), _ceil_div(k, bk)
    steps = t_m * t_n * t_k

    # --- compute term (padded dims; MXU under-fill penalty) ---------------
    pm, pn, pk = t_m * bm, t_n * bn, t_k * bk
    util = (min(bm, device.mxu_dim) / device.mxu_dim) * (min(bn, device.mxu_dim) / device.mxu_dim)
    t_compute = (2.0 * pm * pn * pk) / (device.peak_flops * util)

    # --- memory term (Pallas tile-revisit rule) ---------------------------
    # Grid order: ('mnk') outer->inner = m, n, k; ('nmk') = n, m, k.
    if cfg.order == "mnk":
        outer, inner = t_m, t_n
    else:
        outer, inner = t_n, t_m
    # LHS block index for 'mnk' is (m, k): constant across the inner n loop
    # only when t_k == 1 -> loaded t_m times; else every step.
    # (Symmetric for 'nmk' with RHS.)
    if cfg.order == "mnk":
        loads_lhs = t_m if t_k == 1 else steps
        loads_rhs = steps if (t_n > 1 or t_k > 1) else 1
        bytes_lhs = loads_lhs * bm * bk
        bytes_rhs = loads_rhs * bk * bn
    else:
        loads_rhs = t_n if t_k == 1 else steps
        loads_lhs = steps if (t_m > 1 or t_k > 1) else 1
        bytes_lhs = loads_lhs * bm * bk
        bytes_rhs = loads_rhs * bk * bn
    bytes_out = t_m * t_n * bm * bn
    traffic = (bytes_lhs + bytes_rhs + bytes_out) * dtype_bytes
    t_mem = traffic / device.hbm_bw

    per_call = max(t_compute, t_mem) + steps * device.grid_step_overhead
    t = batch * per_call + device.launch_overhead
    if not texture:
        return t
    return t / _texture(device, cfg, (m, k, n, batch))


def _hash_unit(*parts) -> float:
    """Deterministic uniform [0,1) from arbitrary parts (stable across runs)."""
    h = hashlib.sha256("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "little") / 2**64


def _texture(device: DeviceModel, cfg: MatmulConfig, problem: tuple[int, int, int, int]) -> float:
    """Reproducible per-config and per-(regime, config) efficiency in (0, 1]."""
    m, k, n, batch = problem
    cfg_key = (cfg.block_m, cfg.block_n, cfg.block_k, cfg.order)
    # Per-config compiler/scheduling efficiency: 0.90 .. 1.00.
    e_cfg = 1.0 - 0.10 * _hash_unit(device.name, "cfg", cfg_key)
    # Problem-regime interaction (resonances): bucket shapes by log2 so nearby
    # shapes share the quirk (a classifier can learn it): 0.93 .. 1.07.
    bucket = (int(np.log2(m)), int(np.log2(k)), int(np.log2(n)), int(np.log2(max(batch, 1))))
    e_int = 1.0 + 0.07 * (2.0 * _hash_unit(device.name, "int", cfg_key, bucket) - 1.0)
    return max(e_cfg * e_int, 1e-3)


def predict_gflops(
    problem: tuple[int, int, int, int],
    cfg: MatmulConfig,
    device: DeviceModel = TPU_V5E,
    dtype_bytes: int = 2,
    *,
    texture: bool = True,
) -> float:
    """Useful (unpadded) gigaflops/s; 0 for invalid configs."""
    t = predict_time(problem, cfg, device, dtype_bytes, texture=texture)
    if not np.isfinite(t) or t <= 0:
        return 0.0
    m, k, n, batch = problem
    return 2.0 * m * k * n * batch / t / 1e9


def build_perf_matrix(
    problems: list[tuple[int, int, int, int]],
    configs: list[MatmulConfig],
    device: DeviceModel = TPU_V5E,
    dtype_bytes: int = 2,
    *,
    texture: bool = True,
) -> np.ndarray:
    """(n_problems, n_configs) raw gflops/s table — the benchmark dataset.

    ``texture=False`` yields the pure-roofline *model* table (free to
    compute, never counted as a measurement by the staged pipeline).
    """
    out = np.zeros((len(problems), len(configs)))
    for i, p in enumerate(problems):
        for j, c in enumerate(configs):
            out[i, j] = predict_gflops(p, c, device, dtype_bytes, texture=texture)
    return out


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
