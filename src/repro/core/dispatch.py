"""Deployment artifact: selected kernels + trained runtime classifier (paper §5).

A :class:`Deployment` is what actually ships in the library: the list of
deployed kernel configs (the 'binary blobs') and a classifier mapping problem
features -> deployed-config index.  It implements the ``KernelPolicy``
protocol consumed by ``repro.kernels.ops``.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from repro.kernels.attention import DEFAULT_ATTN_CONFIG, AttentionConfig
from repro.kernels.matmul import MatmulConfig

from .classify import make_classifier
from .dataset import TuningDataset, problem_features

_EPS = 1e-12


def _validate_tree_labels(tree, n_configs: int, field: str) -> None:
    """Reject blobs whose leaves point past the deployed config list.

    A corrupt / truncated artifact used to be clamped silently at dispatch
    time; failing at ``load`` surfaces it where it can actually be fixed.
    """
    flat = tree._ensure_flat()
    hi = flat.max_leaf_label()
    lo = int(flat.label.min())
    if lo < 0 or hi >= n_configs:
        raise ValueError(
            f"deployment blob {field!r} selects config {hi if hi >= n_configs else lo} "
            f"but only {n_configs} configs are deployed"
        )


def build_labels(perf: np.ndarray, chosen: list[int]) -> np.ndarray:
    """Per-problem index (into ``chosen``) of the best deployed kernel."""
    perf = np.asarray(perf, dtype=np.float64)
    return perf[:, chosen].argmax(axis=1)


@dataclasses.dataclass
class Deployment:
    """The shippable tuning artifact (implements KernelPolicy)."""

    # Selections are a pure function of the problem shape, so the ops-layer
    # shape cache may memoize them (DESIGN.md §6).
    cacheable = True

    device: str
    configs: list[MatmulConfig]
    classifier: object  # fit classifier: features -> index into configs
    classifier_name: str = "DecisionTreeA"
    attention_configs: list[AttentionConfig] = dataclasses.field(
        default_factory=lambda: [DEFAULT_ATTN_CONFIG]
    )
    attention_tree: object | None = None  # features -> index into attention_configs
    meta: dict = dataclasses.field(default_factory=dict)

    # -- KernelPolicy -------------------------------------------------------
    def select_matmul(self, m: int, k: int, n: int, batch: int) -> MatmulConfig:
        feats = problem_features([(m, k, n, batch)])
        idx = int(self.classifier.predict(feats)[0])
        idx = min(max(idx, 0), len(self.configs) - 1)
        return self.configs[idx]

    def select_attention(self, sq: int, skv: int, d: int) -> AttentionConfig:
        if self.attention_tree is not None:
            from .attnmodel import attn_problem_features

            feats = attn_problem_features([(sq, skv, d)])
            idx = int(self.attention_tree.predict(feats)[0])
            idx = min(max(idx, 0), len(self.attention_configs) - 1)
            return self.attention_configs[idx]
        # Fallback: pick by KV-length bucket (untuned deployments).
        best = self.attention_configs[0]
        for cfg in self.attention_configs:
            if cfg.block_kv <= max(skv, 128) and cfg.block_q <= max(sq, 128):
                if cfg.block_kv * cfg.block_q > best.block_kv * best.block_q:
                    best = cfg
        return best

    # -- persistence ---------------------------------------------------------
    def to_blob(self, *, tree_format: str = "flat") -> dict:
        """JSON-ready blob (the per-device payload a bundle embeds verbatim).

        ``tree_format="flat"`` (default) emits v2 structure-of-arrays tree
        blobs; ``"nested"`` emits the v1 recursive-dict form for tooling that
        still expects it.  Both load identically.
        """
        from .codegen import tree_to_dict, tree_to_flat_dict

        if tree_format not in ("flat", "nested"):
            raise ValueError(f"unknown tree_format {tree_format!r}")
        to_blob = tree_to_flat_dict if tree_format == "flat" else tree_to_dict
        return {
            "version": 2 if tree_format == "flat" else 1,
            "device": self.device,
            "configs": [c.to_dict() for c in self.configs],
            "attention_configs": [c.to_dict() for c in self.attention_configs],
            "classifier_name": self.classifier_name,
            "tree": to_blob(self.classifier),
            "attention_tree": (
                to_blob(self.attention_tree) if self.attention_tree is not None else None
            ),
            "meta": self.meta,
        }

    def save(self, path: str | Path, *, tree_format: str = "flat") -> None:
        """Serialize (decision-tree classifiers only, like the paper ships)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_blob(tree_format=tree_format), indent=1))

    @staticmethod
    def from_blob(blob: dict) -> "Deployment":
        """Parse a v1/v2 single-device blob (label-validated on the way in)."""
        from .codegen import dict_to_tree

        atree = blob.get("attention_tree")
        dep = Deployment(
            device=blob["device"],
            configs=[MatmulConfig.from_dict(d) for d in blob["configs"]],
            classifier=dict_to_tree(blob["tree"]),
            classifier_name=blob["classifier_name"],
            attention_configs=[AttentionConfig.from_dict(d) for d in blob["attention_configs"]],
            attention_tree=dict_to_tree(atree) if atree else None,
            meta=blob.get("meta", {}),
        )
        _validate_tree_labels(dep.classifier, len(dep.configs), "tree")
        if dep.attention_tree is not None:
            _validate_tree_labels(
                dep.attention_tree, len(dep.attention_configs), "attention_tree"
            )
        return dep

    @staticmethod
    def load(path: str | Path) -> "Deployment":
        return Deployment.from_blob(json.loads(Path(path).read_text()))


def train_deployment(
    train: TuningDataset,
    chosen: list[int],
    classifier_name: str = "DecisionTreeA",
    *,
    meta: dict | None = None,
) -> Deployment:
    labels = build_labels(train.perf, chosen)
    clf = make_classifier(classifier_name)
    clf.fit(train.features, labels)
    return Deployment(
        device=train.device,
        configs=[train.configs[i] for i in chosen],
        classifier=clf,
        classifier_name=classifier_name,
        meta=meta or {},
    )


def classifier_fraction(test: TuningDataset, chosen: list[int], deployment: Deployment) -> float:
    """Geomean of (perf of classifier-picked kernel) / optimal (Tables 1-2)."""
    pred = deployment.classifier.predict(test.features)
    pred = np.clip(pred, 0, len(chosen) - 1)
    picked = test.perf[np.arange(len(test.problems)), [chosen[i] for i in pred]]
    best = test.perf.max(axis=1)
    ratio = np.where(best > 0, picked / np.maximum(best, _EPS), 1.0)
    return float(np.exp(np.mean(np.log(np.maximum(ratio, _EPS)))))
