"""Deployment artifact: selected kernels + trained runtime classifier (paper §5).

A :class:`Deployment` is what actually ships in the library: per kernel
*family* (``repro.core.families``), the list of deployed kernel configs (the
'binary blobs') and a classifier mapping problem features -> deployed-config
index.  It implements the ``KernelPolicy`` protocol consumed by
``repro.kernels.ops``: the generic :meth:`Deployment.select` answers any
registered family, with ``select_matmul`` / ``select_attention`` /
``select_wkv`` / ``select_ssm`` kept as thin shims.

Blob format (DESIGN.md §9): v5 adds a ``families`` section carrying every
family beyond the legacy matmul/attention fields; v1 (nested trees) and v2
(flat trees) single-device blobs load unchanged, and unknown family names in
a newer blob are ignored (forward compat).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from repro.kernels.attention import DEFAULT_ATTN_CONFIG, AttentionConfig
from repro.kernels.matmul import MatmulConfig

from .classify import make_classifier
from .dataset import TuningDataset, problem_features
from .families import FamilyTuning, get_family, is_registered

DEPLOYMENT_VERSION = 5


def _validate_tree_labels(tree, n_configs: int, field: str) -> None:
    """Reject blobs whose leaves point past the deployed config list.

    A corrupt / truncated artifact used to be clamped silently at dispatch
    time; failing at ``load`` surfaces it where it can actually be fixed.
    """
    flat = tree._ensure_flat()
    hi = flat.max_leaf_label()
    lo = int(flat.label.min())
    if lo < 0 or hi >= n_configs:
        raise ValueError(
            f"deployment blob {field!r} selects config {hi if hi >= n_configs else lo} "
            f"but only {n_configs} configs are deployed"
        )


def build_labels(perf: np.ndarray, chosen: list[int]) -> np.ndarray:
    """Per-problem index (into ``chosen``) of the best deployed kernel."""
    perf = np.asarray(perf, dtype=np.float64)
    return perf[:, chosen].argmax(axis=1)


@dataclasses.dataclass
class Deployment:
    """The shippable tuning artifact (implements KernelPolicy).

    The matmul family lives in the legacy ``configs``/``classifier`` fields
    and attention in ``attention_configs``/``attention_tree`` (wire + ctor
    compatibility); every other family lives in ``families``.  Use
    :meth:`family_tuning` / :meth:`set_family_tuning` for uniform access.
    """

    # Selections are a pure function of the problem shape, so the ops-layer
    # shape cache may memoize them (DESIGN.md §6).
    cacheable = True

    device: str
    configs: list[MatmulConfig]
    classifier: object  # fit classifier: features -> index into configs
    classifier_name: str = "DecisionTreeA"
    attention_configs: list[AttentionConfig] = dataclasses.field(
        default_factory=lambda: [DEFAULT_ATTN_CONFIG]
    )
    attention_tree: object | None = None  # features -> index into attention_configs
    meta: dict = dataclasses.field(default_factory=dict)
    families: dict[str, FamilyTuning] = dataclasses.field(default_factory=dict)

    # -- family access ------------------------------------------------------
    def family_tuning(self, family: str) -> FamilyTuning:
        """``(configs, tree)`` for any family (empty tuning when untuned)."""
        if family == "matmul":
            return FamilyTuning(self.configs, self.classifier)
        if family == "attention":
            return FamilyTuning(self.attention_configs, self.attention_tree)
        return self.families.get(family, FamilyTuning([], None))

    def set_family_tuning(self, family: str, configs: list, tree: object | None) -> None:
        if family == "matmul":
            self.configs = list(configs)
            self.classifier = tree
        elif family == "attention":
            self.attention_configs = list(configs)
            self.attention_tree = tree
        else:
            self.families[family] = FamilyTuning(list(configs), tree)

    def family_names(self) -> list[str]:
        """Families this artifact carries a non-empty tuning for."""
        out = []
        if self.configs:
            out.append("matmul")
        if self.attention_configs:
            out.append("attention")
        out.extend(sorted(self.families))
        return out

    def clone(self) -> "Deployment":
        """Shallow copy safe for per-family replacement (retune's swap unit)."""
        return Deployment(
            device=self.device,
            configs=list(self.configs),
            classifier=self.classifier,
            classifier_name=self.classifier_name,
            attention_configs=list(self.attention_configs),
            attention_tree=self.attention_tree,
            meta=dict(self.meta),
            families=dict(self.families),
        )

    # -- KernelPolicy -------------------------------------------------------
    def select(self, family: str, problem: tuple):
        """Generic launcher-side selection for any registered family."""
        configs, tree = self.family_tuning(family)
        if not configs:
            return get_family(family).default_config
        if tree is None:
            if family == "attention":
                return self._attention_bucket_fallback(*problem)
            return configs[0]
        feats = get_family(family).features([tuple(problem)])
        idx = int(tree.predict(feats)[0])
        idx = min(max(idx, 0), len(configs) - 1)
        return configs[idx]

    def select_matmul(self, m: int, k: int, n: int, batch: int) -> MatmulConfig:
        feats = problem_features([(m, k, n, batch)])
        idx = int(self.classifier.predict(feats)[0])
        idx = min(max(idx, 0), len(self.configs) - 1)
        return self.configs[idx]

    def select_attention(self, sq: int, skv: int, d: int) -> AttentionConfig:
        if self.attention_tree is not None:
            return self.select("attention", (sq, skv, d))
        return self._attention_bucket_fallback(sq, skv, d)

    def select_wkv(self, s: int, hd: int):
        return self.select("wkv", (s, hd))

    def select_ssm(self, s: int, d: int):
        return self.select("ssm_scan", (s, d))

    def select_for_objective(self, family: str, problem: tuple, objective):
        """SLO-aware selection: pick by predicted per-problem speed.

        The classifier is trained to maximise aggregate throughput over the
        train distribution; under a latency objective the serving tier wants
        the config the family's analytic model predicts *fastest for this
        problem* instead (max score == min predicted time at fixed work).
        Falls back to the plain classifier path when the objective carries no
        target, the family has nothing to choose between, or the family
        declares no model.
        """
        if getattr(objective, "latency_target_ms", None) is None:
            return self.select(family, tuple(problem))
        configs, _tree = self.family_tuning(family)
        if len(configs) <= 1:
            return self.select(family, tuple(problem))
        fam = get_family(family)
        model = fam.model_matrix or fam.perf_matrix
        if model is None:
            return self.select(family, tuple(problem))
        try:
            scores = np.asarray(model([tuple(problem)], list(configs), self.device))
        except Exception:
            return self.select(family, tuple(problem))
        return configs[int(np.argmax(scores[0]))]

    def _attention_bucket_fallback(self, sq: int, skv: int, d: int) -> AttentionConfig:
        # Pick by KV-length bucket (untuned deployments).
        best = self.attention_configs[0]
        for cfg in self.attention_configs:
            if cfg.block_kv <= max(skv, 128) and cfg.block_q <= max(sq, 128):
                if cfg.block_kv * cfg.block_q > best.block_kv * best.block_q:
                    best = cfg
        return best

    # -- persistence ---------------------------------------------------------
    def to_blob(self, *, tree_format: str = "flat") -> dict:
        """JSON-ready blob (the per-device payload a bundle embeds verbatim).

        ``tree_format="flat"`` (default) emits the v5 layout: v2
        structure-of-arrays tree blobs plus a ``families`` section for every
        family beyond matmul/attention.  ``"nested"`` emits the v1
        recursive-dict form for tooling that still expects it (legacy
        families only).  Both load identically for matmul/attention.
        """
        from .codegen import tree_to_dict, tree_to_flat_dict

        if tree_format not in ("flat", "nested"):
            raise ValueError(f"unknown tree_format {tree_format!r}")
        to_blob = tree_to_flat_dict if tree_format == "flat" else tree_to_dict
        blob = {
            "version": DEPLOYMENT_VERSION if tree_format == "flat" else 1,
            "device": self.device,
            "configs": [c.to_dict() for c in self.configs],
            "attention_configs": [c.to_dict() for c in self.attention_configs],
            "classifier_name": self.classifier_name,
            "tree": to_blob(self.classifier),
            "attention_tree": (
                to_blob(self.attention_tree) if self.attention_tree is not None else None
            ),
            "meta": self.meta,
        }
        if tree_format == "flat":
            blob["families"] = {
                name: {
                    "configs": [c.to_dict() for c in tuning.configs],
                    "tree": to_blob(tuning.tree) if tuning.tree is not None else None,
                }
                for name, tuning in sorted(self.families.items())
            }
        return blob

    def save(self, path: str | Path, *, tree_format: str = "flat") -> None:
        """Serialize (decision-tree classifiers only, like the paper ships)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_blob(tree_format=tree_format), indent=1))

    @staticmethod
    def from_blob(blob: dict) -> "Deployment":
        """Parse a v1/v2/v5 single-device blob (label-validated on the way in).

        Unknown family names inside a v5 ``families`` section are skipped —
        a newer artifact stays loadable, serving the families this build
        knows (the unknown op falls back to its reference implementation).
        """
        from .codegen import dict_to_tree

        atree = blob.get("attention_tree")
        extra: dict[str, FamilyTuning] = {}
        for name, sub in (blob.get("families") or {}).items():
            if name in ("matmul", "attention") or not is_registered(name):
                continue  # legacy fields win; unknown families are ignored
            fam = get_family(name)
            cfgs = [fam.config_cls.from_dict(d) for d in sub.get("configs", [])]
            tree = dict_to_tree(sub["tree"]) if sub.get("tree") else None
            extra[name] = FamilyTuning(cfgs, tree)
        dep = Deployment(
            device=blob["device"],
            configs=[MatmulConfig.from_dict(d) for d in blob["configs"]],
            classifier=dict_to_tree(blob["tree"]),
            classifier_name=blob["classifier_name"],
            attention_configs=[AttentionConfig.from_dict(d) for d in blob["attention_configs"]],
            attention_tree=dict_to_tree(atree) if atree else None,
            meta=blob.get("meta", {}),
            families=extra,
        )
        _validate_tree_labels(dep.classifier, len(dep.configs), "tree")
        if dep.attention_tree is not None:
            _validate_tree_labels(
                dep.attention_tree, len(dep.attention_configs), "attention_tree"
            )
        for name, tuning in dep.families.items():
            if tuning.tree is not None:
                _validate_tree_labels(tuning.tree, len(tuning.configs), f"families.{name}.tree")
        return dep

    @staticmethod
    def load(path: str | Path) -> "Deployment":
        return Deployment.from_blob(json.loads(Path(path).read_text()))


def train_deployment(
    train: TuningDataset,
    chosen: list[int],
    classifier_name: str = "DecisionTreeA",
    *,
    meta: dict | None = None,
    seed: int = 0,
) -> Deployment:
    labels = build_labels(train.perf, chosen)
    clf = make_classifier(classifier_name, seed=seed)
    clf.fit(train.features, labels)
    return Deployment(
        device=train.device,
        configs=[train.configs[i] for i in chosen],
        classifier=clf,
        classifier_name=classifier_name,
        meta=meta or {},
    )


def classifier_fraction(test: TuningDataset, chosen: list[int], deployment: Deployment) -> float:
    """Geomean of (perf of classifier-picked kernel) / optimal (Tables 1-2)."""
    from .selection import geomean_fraction

    pred = deployment.classifier.predict(test.features)
    pred = np.clip(pred, 0, len(chosen) - 1)
    picked = test.perf[np.arange(len(test.problems)), [chosen[i] for i in pred]]
    return geomean_fraction(picked, test.perf.max(axis=1))
