"""Measured host-CPU benchmark source (the paper's i7-6700K analogue).

The paper's second device is a CPU; ours is this container's host.  We time a
*cache-blocked* numpy GEMM parameterized by the exact same
``MatmulConfig(block_m, block_n, block_k, order)`` space as the Pallas kernel
(blocks play the role of L1/L2 tiles instead of VMEM tiles), giving a REAL
measured dataset with genuinely different optima per shape — no analytic
model involved.  The tuning pipeline consumes it unchanged.
"""
from __future__ import annotations

import time

import numpy as np

from repro.kernels.matmul import MatmulConfig, config_space

from .dataset import Problem, TuningDataset


def blocked_gemm(a: np.ndarray, b: np.ndarray, cfg: MatmulConfig) -> np.ndarray:
    """Cache-blocked matmul with the config's tiling + loop order."""
    m, k = a.shape
    _, n = b.shape
    out = np.zeros((m, n), dtype=np.float32)
    bm, bn, bk = min(cfg.block_m, m), min(cfg.block_n, n), min(cfg.block_k, k)
    m_blocks = range(0, m, bm)
    n_blocks = range(0, n, bn)
    if cfg.order == "mnk":
        for i in m_blocks:
            for j in n_blocks:
                acc = out[i : i + bm, j : j + bn]
                for s in range(0, k, bk):
                    acc += a[i : i + bm, s : s + bk] @ b[s : s + bk, j : j + bn]
    else:
        for j in n_blocks:
            for i in m_blocks:
                acc = out[i : i + bm, j : j + bn]
                for s in range(0, k, bk):
                    acc += a[i : i + bm, s : s + bk] @ b[s : s + bk, j : j + bn]
    return out


def _time_config(a, b, cfg, *, min_time: float = 0.02, max_reps: int = 5) -> float:
    """Median wall-time of blocked_gemm; adaptively repeats short runs."""
    times = []
    t_total = 0.0
    for _ in range(max_reps):
        t0 = time.perf_counter()
        blocked_gemm(a, b, cfg)
        dt = time.perf_counter() - t0
        times.append(dt)
        t_total += dt
        if t_total > min_time and len(times) >= 2:
            break
    return float(np.median(times))


def cpu_problems(n: int = 24, seed: int = 0) -> list[Problem]:
    """Paper-flavoured shapes scaled to CPU-friendly sizes (batch folded in)."""
    rng = np.random.default_rng(seed)
    out = set()
    pows = [64, 128, 192, 256, 384, 512]
    while len(out) < n:
        kind = rng.random()
        if kind < 0.45:  # squarish
            m, k_, n_ = rng.choice(pows, 3)
        elif kind < 0.75:  # deep-k rectangular
            m, n_ = rng.choice(pows[:4], 2)
            k_ = int(rng.choice([512, 768, 1024]))
        else:  # tall-skinny
            m = int(rng.choice([1, 4, 8, 16]))
            k_ = int(rng.choice([256, 512, 1024]))
            n_ = int(rng.choice(pows[2:]))
        out.add((int(m), int(k_), int(n_), 1))
    return sorted(out)


def build_cpu_dataset(
    problems: list[Problem] | None = None,
    configs: list[MatmulConfig] | None = None,
    *,
    verbose: bool = False,
) -> TuningDataset:
    """Measure the full (problems x configs) wall-clock table on this host."""
    problems = problems if problems is not None else cpu_problems()
    configs = list(configs if configs is not None else config_space())
    perf = np.zeros((len(problems), len(configs)))
    rng = np.random.default_rng(0)
    for i, (m, k, n, batch) in enumerate(problems):
        a = rng.standard_normal((m, k)).astype(np.float32)
        b = rng.standard_normal((k, n)).astype(np.float32)
        flops = 2.0 * m * k * n * batch
        for j, cfg in enumerate(configs):
            t = _time_config(a, b, cfg)
            perf[i, j] = flops / t / 1e9  # measured gflops/s
        if verbose:
            print(f"  measured problem {i + 1}/{len(problems)}: {problems[i]}", flush=True)
    return TuningDataset(
        device="host_cpu", problems=problems, configs=configs, perf=perf, source="measured"
    )
