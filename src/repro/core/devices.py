"""Device registry + detection: the "which tuned artifact runs here?" layer.

The paper's pitch is performance *portability*: tune once per device from
benchmark data, then at runtime the library picks the right deployed kernel
set for whatever hardware it landed on.  That requires three small pieces,
all host-side and dependency-free:

  1. **Canonical device names.**  ``jax.devices()[0]`` reports hardware as a
     free-form ``device_kind`` string ("TPU v5 lite", "TPU v4", "cpu", ...).
     :func:`canonical_device_name` normalizes those to the canonical slugs
     the tuning pipeline uses (``tpu_v5e``, ``tpu_v4``, ``host_cpu``, ...),
     so a :class:`~repro.core.bundle.DeploymentBundle` keyed by tuning-time
     names matches serve-time hardware.
  2. **Explicit override.**  The ``REPRO_DEVICE`` environment variable wins
     over detection (operators pinning a known-good artifact, CI hosts with
     no accelerator pretending to be one).
  3. **Nearest-device fallback.**  An untuned host should degrade to the
     closest tuned *sibling* — a v5p serving host picks the v4 artifact, not
     the single-kernel ``FixedPolicy`` baseline.  :data:`FALLBACKS` encodes
     the preference chain per device; :func:`resolve_device` walks it against
     the devices a bundle actually contains, then falls back to any device of
     the same platform family, then (non-strict) to anything tuned at all.

See DESIGN.md §7 for the resolution order contract.
"""
from __future__ import annotations

import os
import re

DEVICE_ENV_VAR = "REPRO_DEVICE"

# Preference chain per canonical device: first tuned entry wins.  Chains are
# walked in order and only ever consulted when the device itself is untuned.
FALLBACKS: dict[str, tuple[str, ...]] = {
    "tpu_v6e": ("tpu_v5e", "tpu_v5p", "tpu_v4"),
    "tpu_v5p": ("tpu_v4", "tpu_v5e"),
    "tpu_v5e": ("tpu_v4", "tpu_v6e"),
    "tpu_v4": ("tpu_v5e", "tpu_v5p"),
    "tpu_v3": ("tpu_v4", "tpu_v5e"),
    "tpu_v2": ("tpu_v3", "tpu_v4", "tpu_v5e"),
    "host_cpu": (),
}

_TPU_KIND = re.compile(r"tpu[\s_-]*v(\d+)[\s_-]*(lite|e|p|i)?", re.IGNORECASE)


def _slug(s: str) -> str:
    return re.sub(r"[^a-z0-9]+", "_", s.strip().lower()).strip("_") or "unknown"


def canonical_device_name(kind: str, platform: str | None = None) -> str:
    """Normalize a ``device_kind`` / platform pair to a canonical slug.

    ``"TPU v5 lite"`` -> ``tpu_v5e``; ``"TPU v4"`` / ``"TPU v4i"`` ->
    ``tpu_v4``; ``"cpu"`` -> ``host_cpu``; GPUs become ``gpu_<kind>``;
    already-canonical slugs pass through unchanged.
    """
    raw = (kind or platform or "").strip()
    low = raw.lower()
    if low in ("cpu", "host_cpu") or platform == "cpu":
        return "host_cpu"
    m = _TPU_KIND.search(low)
    if m:
        version, variant = m.group(1), (m.group(2) or "").lower()
        if variant == "lite":
            variant = "e"
        elif variant == "i":  # inference variants tune like the base part
            variant = ""
        return f"tpu_v{version}{variant}"
    if platform == "gpu" or low.startswith("gpu"):
        return "gpu_" + _slug(re.sub(r"^gpu[\s_-]*", "", low) or "unknown")
    return _slug(raw)


def detect_device(env: dict | None = None) -> str:
    """Canonical name of the host accelerator (env override > jax probe).

    ``REPRO_DEVICE`` wins when set (itself canonicalized, so both
    ``REPRO_DEVICE=tpu_v4`` and ``REPRO_DEVICE="TPU v4"`` work).  Otherwise
    the first jax device's kind/platform is normalized; a host where jax is
    unavailable reports ``host_cpu``.
    """
    e = env if env is not None else os.environ
    override = e.get(DEVICE_ENV_VAR)
    if override:
        return canonical_device_name(override)
    try:
        import jax

        dev = jax.devices()[0]
        return canonical_device_name(getattr(dev, "device_kind", ""), dev.platform)
    except Exception:  # pragma: no cover - jax-less host
        return "host_cpu"


def _family(name: str) -> str:
    return name.split("_", 1)[0]


def fallback_order(device: str) -> list[str]:
    """Every sibling reachable from ``device`` through :data:`FALLBACKS`,
    nearest first (breadth-first over the preference graph).

    The direct chain comes first in its declared order, then each entry's own
    chain, and so on transitively — so a v2 host with only a v5p artifact
    still finds it (v2 -> v3 -> v4 -> v5p) instead of dropping straight to
    the same-platform-family lottery.  Cycle-safe: the graph is deliberately
    cyclic (v5e <-> v4) and every device is visited at most once; ``device``
    itself never appears in its own order.
    """
    device = canonical_device_name(device)
    seen = {device}
    order: list[str] = []
    frontier = [device]
    while frontier:
        nxt: list[str] = []
        for d in frontier:
            for cand in FALLBACKS.get(d, ()):
                if cand in seen:
                    continue
                seen.add(cand)
                order.append(cand)
                nxt.append(cand)
        frontier = nxt
    return order


def transfer_donor(device: str, tuned: "list[str] | set[str]") -> str | None:
    """The nearest already-tuned sibling a new device can warm-start from.

    Walks :func:`fallback_order` (so multi-hop siblings count), then any
    tuned device of the same platform family.  Never crosses platform
    families — a ``host_cpu`` tuning says nothing about a TPU's perf surface,
    so unlike :func:`resolve_device` there is no serve-anything last resort.
    """
    device = canonical_device_name(device)
    tuned_set = {canonical_device_name(t) for t in tuned} - {device}
    for cand in fallback_order(device):
        if cand in tuned_set:
            return cand
    fam = _family(device)
    for cand in sorted(tuned_set):
        if _family(cand) == fam:
            return cand
    return None


def transfer_order(device_names: "list[str] | tuple[str, ...]") -> list[str]:
    """Order a fleet so donors tune before the siblings that warm-start off
    them (deterministic for a given input order).

    Greedy: at each step prefer a device whose :func:`transfer_donor` is
    already placed; when none qualifies (the bootstrap full-tune roots),
    place the device that donates to the most still-pending peers, earliest
    in the input on ties.  Duplicates (post-canonicalization) collapse to
    their first occurrence.
    """
    pending = list(dict.fromkeys(canonical_device_name(n) for n in device_names))
    placed: list[str] = []
    while pending:
        pick = next((d for d in pending if transfer_donor(d, placed)), None)
        if pick is None:
            def donates(d: str) -> int:
                return sum(1 for o in pending if o != d and d in fallback_order(o))

            pick = max(pending, key=lambda d: (donates(d), -pending.index(d)))
        placed.append(pick)
        pending.remove(pick)
    return placed


def resolve_device(
    requested: str, available: list[str], *, strict: bool = False
) -> str | None:
    """Pick the tuned device that should serve ``requested``.

    Resolution order (DESIGN.md §7):
      1. exact match;
      2. the :data:`FALLBACKS` graph for ``requested`` — the direct chain in
         order, then transitive siblings breadth-first (:func:`fallback_order`);
      3. any available device of the same platform family (``tpu_*`` for a
         TPU, ...), lexicographically smallest for determinism;
      4. non-strict only: any available device at all (a tuned artifact still
         beats the untuned ``FixedPolicy`` baseline).

    Returns ``None`` (or raises ``KeyError`` when ``strict``) if nothing is
    available.
    """
    requested = canonical_device_name(requested)
    avail = sorted(dict.fromkeys(available))
    if requested in avail:
        return requested
    for cand in fallback_order(requested):
        if cand in avail:
            return cand
    fam = _family(requested)
    for cand in avail:
        if _family(cand) == fam:
            return cand
    if not strict and avail:
        return avail[0]
    if strict:
        raise KeyError(
            f"no deployment resolves device {requested!r} (available: {avail})"
        )
    return None
