"""Unsupervised kernel-subset selection (paper §4).

Each problem instance (a set of matrix sizes) is a point in R^{n_configs}
whose coordinates are its normalized per-config performance.  Clustering
groups problems with similar performance characteristics; one kernel config
is then extracted per cluster (paper §4.2):

  * methods with centroids (k-means family) pick the argmax config of the
    centroid;
  * methods yielding only labels (spectral, density, tree leaves) pick the
    argmax config of the *geometric mean* of the cluster members.

Implemented selectors (paper §4.1):
  ``topn``          — Top-N by best-count baseline.
  ``kmeans``        — k-means++ / Lloyd.
  ``pca_kmeans``    — PCA dimensionality reduction, then k-means.
  ``spectral``      — RBF similarity graph, normalized Laplacian eigenmaps,
                      then k-means (classic spectral clustering).
  ``density``       — HDBSCAN-style density clustering: mutual-reachability
                      MST, cut hierarchically; hyperparameters swept until the
                      requested number of clusters is produced (paper §4.1.4).
  ``tree``          — multi-output regression tree (sizes -> perf vector) with
                      the leaf count capped at n_kernels; each leaf's mean
                      perf vector is a cluster representative (paper §4.1.5).

Everything is numpy-only (no sklearn available in this environment).
"""
from __future__ import annotations

import numpy as np

from .pca import PCA

CLUSTER_METHODS = ("topn", "kmeans", "pca_kmeans", "spectral", "density", "tree")

_EPS = 1e-12


# ---------------------------------------------------------------------------
# k-means
# ---------------------------------------------------------------------------
def _kmeans_pp_init(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    n = x.shape[0]
    centers = np.empty((k, x.shape[1]), dtype=x.dtype)
    centers[0] = x[rng.integers(n)]
    d2 = np.sum((x - centers[0]) ** 2, axis=1)
    for i in range(1, k):
        probs = d2 / max(d2.sum(), _EPS)
        centers[i] = x[rng.choice(n, p=probs)]
        d2 = np.minimum(d2, np.sum((x - centers[i]) ** 2, axis=1))
    return centers


def kmeans(
    x: np.ndarray,
    k: int,
    *,
    n_init: int = 8,
    max_iter: int = 200,
    seed: int = 0,
    init_centers: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Lloyd's algorithm with k-means++ init. Returns (labels, centers).

    ``init_centers`` warm-starts Lloyd from caller-supplied centroids (the
    incremental-retune path seeds with the deployed clustering so refinement
    converges in a handful of iterations instead of ``n_init`` cold restarts).
    Fewer than ``k`` rows are topped up by k-means++; extra rows are ignored.
    """
    x = np.asarray(x, dtype=np.float64)
    k = min(k, x.shape[0])
    rng = np.random.default_rng(seed)
    warm = None
    if init_centers is not None:
        warm = np.asarray(init_centers, dtype=np.float64)[:k]
        if warm.shape[0] < k:
            # top up missing centroids with k-means++ picks over the data
            extra = _kmeans_pp_init(x, k - warm.shape[0], rng)
            warm = np.vstack([warm, extra])
        n_init = 1
    best = (None, None, np.inf)
    for _ in range(n_init):
        centers = warm if warm is not None else _kmeans_pp_init(x, k, rng)
        for _ in range(max_iter):
            d2 = ((x[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
            labels = d2.argmin(1)
            new = np.stack(
                [x[labels == j].mean(0) if np.any(labels == j) else centers[j] for j in range(k)]
            )
            if np.allclose(new, centers):
                centers = new
                break
            centers = new
        inertia = ((x - centers[labels]) ** 2).sum()
        if inertia < best[2]:
            best = (labels, centers, inertia)
    return best[0], best[1]


# ---------------------------------------------------------------------------
# spectral clustering
# ---------------------------------------------------------------------------
def spectral_labels(x: np.ndarray, k: int, *, seed: int = 0) -> np.ndarray:
    """RBF-affinity normalized-Laplacian spectral clustering."""
    x = np.asarray(x, dtype=np.float64)
    n = x.shape[0]
    k = min(k, n)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    # Median-heuristic bandwidth over nonzero distances.
    nz = d2[d2 > 0]
    gamma = 1.0 / max(np.median(nz), _EPS) if nz.size else 1.0
    a = np.exp(-gamma * d2)
    np.fill_diagonal(a, 0.0)
    deg = a.sum(1)
    dinv = 1.0 / np.sqrt(np.maximum(deg, _EPS))
    lap = np.eye(n) - dinv[:, None] * a * dinv[None, :]
    # k smallest eigenvectors of the symmetric normalized Laplacian.
    vals, vecs = np.linalg.eigh(lap)
    emb = vecs[:, :k]
    # Row-normalize (Ng-Jordan-Weiss).
    norms = np.linalg.norm(emb, axis=1, keepdims=True)
    emb = emb / np.maximum(norms, _EPS)
    labels, _ = kmeans(emb, k, seed=seed)
    return labels


# ---------------------------------------------------------------------------
# density clustering (HDBSCAN-style)
# ---------------------------------------------------------------------------
def _mst_edges(dist: np.ndarray) -> list[tuple[float, int, int]]:
    """Prim's MST over a dense distance matrix -> sorted edge list."""
    n = dist.shape[0]
    in_tree = np.zeros(n, dtype=bool)
    in_tree[0] = True
    best = dist[0].copy()
    parent = np.zeros(n, dtype=int)
    edges: list[tuple[float, int, int]] = []
    for _ in range(n - 1):
        cand = np.where(in_tree, np.inf, best)
        j = int(cand.argmin())
        edges.append((float(best[j]), int(parent[j]), j))
        in_tree[j] = True
        upd = dist[j] < best
        best = np.where(upd, dist[j], best)
        parent = np.where(upd, j, parent)
    edges.sort()
    return edges


def density_labels(
    x: np.ndarray,
    k: int,
    *,
    min_cluster_size_range: tuple[int, ...] = (2, 3, 4, 5, 8),
    min_samples_range: tuple[int, ...] = (1, 2, 3, 5),
) -> np.ndarray:
    """HDBSCAN-flavoured density clustering with a hyperparameter sweep.

    Builds the mutual-reachability MST, then removes the largest edges one at
    a time; components smaller than ``min_cluster_size`` count as noise.  As
    HDBSCAN cannot be told how many clusters to produce, we sweep its
    hyperparameters and keep whichever yields exactly ``k`` clusters (paper
    §4.1.4); nearest match wins otherwise.  Noise points are assigned to the
    nearest cluster so every problem gets a label.
    """
    x = np.asarray(x, dtype=np.float64)
    n = x.shape[0]
    d = np.sqrt(np.maximum(((x[:, None, :] - x[None, :, :]) ** 2).sum(-1), 0.0))

    best_labels, best_err = None, np.inf
    for ms in min_samples_range:
        core = np.sort(d, axis=1)[:, min(ms, n - 1)]  # distance to ms-th neighbour
        mreach = np.maximum(np.maximum(core[:, None], core[None, :]), d)
        edges = _mst_edges(mreach)
        for mcs in min_cluster_size_range:
            labels = _cut_mst(edges, n, k, mcs)
            ncl = labels.max() + 1
            err = abs(ncl - k)
            if err < best_err:
                best_labels, best_err = labels, err
            if best_err == 0:
                break
        if best_err == 0:
            break

    labels = best_labels
    # Assign noise (-1) to nearest labelled point.
    noise = np.where(labels < 0)[0]
    ok = np.where(labels >= 0)[0]
    if ok.size == 0:
        return np.zeros(n, dtype=int)
    for i in noise:
        labels[i] = labels[ok[d[i, ok].argmin()]]
    # Compact label ids.
    _, labels = np.unique(labels, return_inverse=True)
    return labels


def _cut_mst(edges: list[tuple[float, int, int]], n: int, k: int, min_cluster_size: int) -> np.ndarray:
    """Remove heaviest MST edges until ~k components of size>=min_cluster_size."""
    # Union-find over edges sorted ascending, stopping before the heaviest
    # (k-1) merges would have happened — equivalently, build with all but the
    # largest edges removed, trying successively smaller cut thresholds.
    for n_cut in range(k - 1, n):
        keep = edges[: max(len(edges) - n_cut, 0)]
        parent = list(range(n))

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for _, u, v in keep:
            ru, rv = find(u), find(v)
            if ru != rv:
                parent[ru] = rv
        roots = np.array([find(i) for i in range(n)])
        uniq, counts = np.unique(roots, return_counts=True)
        big = uniq[counts >= min_cluster_size]
        if len(big) >= k or n_cut == n - 1:
            labels = np.full(n, -1, dtype=int)
            for ci, r in enumerate(big):
                labels[roots == r] = ci
            return labels
    return np.zeros(n, dtype=int)


# ---------------------------------------------------------------------------
# regression-tree "clustering" (paper §4.1.5)
# ---------------------------------------------------------------------------
class _TreeNode:
    __slots__ = ("feature", "threshold", "left", "right", "value", "indices")

    def __init__(self):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.value = None
        self.indices = None


def _best_split(x: np.ndarray, y: np.ndarray, min_leaf: int) -> tuple[int, float, float] | None:
    """Best (feature, threshold) minimizing summed variance of y halves.

    One vectorized sum-of-squared-error sweep per feature: prefix sums of y
    and y^2 give both halves' SSE at every candidate split position at once.
    """
    n, nf = x.shape
    best = None
    base = ((y - y.mean(0)) ** 2).sum()
    pos = np.arange(min_leaf, n - min_leaf + 1)
    pos = pos[(pos >= 1) & (pos <= n - 1)]
    if pos.size == 0:
        return None
    for f in range(nf):
        order = np.argsort(x[:, f], kind="stable")
        xs, ys = x[order, f], y[order]
        csum = np.cumsum(ys, axis=0)
        csum2 = np.cumsum(ys**2, axis=0)
        tot, tot2 = csum[-1], csum2[-1]
        valid = xs[pos - 1] != xs[pos]
        if not valid.any():
            continue
        nl = pos.astype(np.float64)
        nr = (n - pos).astype(np.float64)
        sl, sl2 = csum[pos - 1], csum2[pos - 1]
        sr, sr2 = tot[None, :] - sl, tot2[None, :] - sl2
        sse = (sl2 - sl**2 / nl[:, None]).sum(1) + (sr2 - sr**2 / nr[:, None]).sum(1)
        gain = np.where(valid, base - sse, -np.inf)
        j = int(gain.argmax())
        if best is None or gain[j] > best[2]:
            i = int(pos[j])
            thr = 0.5 * (xs[i - 1] + xs[i])
            best = (f, float(thr), float(gain[j]))
    if best is None or best[2] <= 1e-12:
        return None
    return best


def regression_tree_leaves(
    features: np.ndarray, perf: np.ndarray, max_leaves: int, *, min_leaf: int = 1
) -> np.ndarray:
    """Grow a multi-output regression tree best-first until ``max_leaves``.

    Returns integer leaf labels per problem — the tree-based "clustering" of
    paper §4.1.5 (splits on *matrix sizes*, values are performance vectors).
    """
    features = np.asarray(features, dtype=np.float64)
    perf = np.asarray(perf, dtype=np.float64)
    n = features.shape[0]
    root_idx = np.arange(n)
    # Best-first growth: priority queue on variance-reduction gain.
    leaves: list[np.ndarray] = [root_idx]
    splits: list[tuple[float, int, int, float, np.ndarray, np.ndarray]] = []

    def try_split(leaf_id: int) -> None:
        idx = leaves[leaf_id]
        if len(idx) < 2 * min_leaf:
            return
        got = _best_split(features[idx], perf[idx], min_leaf)
        if got is None:
            return
        f, thr, gain = got
        mask = features[idx, f] <= thr
        splits.append((gain, leaf_id, f, thr, idx[mask], idx[~mask]))

    try_split(0)
    while len(leaves) < max_leaves and splits:
        splits.sort(key=lambda s: -s[0])
        gain, leaf_id, f, thr, li, ri = splits.pop(0)
        if leaves[leaf_id] is None or len(leaves[leaf_id]) != len(li) + len(ri):
            continue  # stale entry
        leaves[leaf_id] = li
        leaves.append(ri)
        # Invalidate stale queued splits of this leaf.
        splits[:] = [s for s in splits if s[1] != leaf_id]
        try_split(leaf_id)
        try_split(len(leaves) - 1)

    labels = np.zeros(n, dtype=int)
    for ci, idx in enumerate(leaves):
        labels[idx] = ci
    return labels


# ---------------------------------------------------------------------------
# selection front-end (paper §4.2)
# ---------------------------------------------------------------------------
def _geomean(y: np.ndarray, axis: int = 0) -> np.ndarray:
    return np.exp(np.mean(np.log(np.maximum(y, _EPS)), axis=axis))


def _configs_from_labels(perf: np.ndarray, labels: np.ndarray, k: int) -> list[int]:
    chosen: list[int] = []
    for c in range(labels.max() + 1):
        members = perf[labels == c]
        if members.size == 0:
            continue
        gm = _geomean(members, axis=0)
        order = np.argsort(-gm)
        for cfg in order:
            if int(cfg) not in chosen:
                chosen.append(int(cfg))
                break
    return chosen[:k]


def _configs_from_centers(perf: np.ndarray, labels: np.ndarray, centers: np.ndarray, k: int) -> list[int]:
    chosen: list[int] = []
    for c in range(centers.shape[0]):
        order = np.argsort(-centers[c])
        for cfg in order:
            if int(cfg) not in chosen:
                chosen.append(int(cfg))
                break
    return chosen[:k]


def _pad_selection(chosen: list[int], perf: np.ndarray, k: int) -> list[int]:
    """If dedup left fewer than k configs, pad with global best-by-count."""
    if len(chosen) >= k:
        return chosen[:k]
    counts = np.bincount(perf.argmax(1), minlength=perf.shape[1])
    for cfg in np.argsort(-counts):
        if int(cfg) not in chosen:
            chosen.append(int(cfg))
        if len(chosen) == k:
            break
    return chosen


def select_configs(
    perf: np.ndarray,
    k: int,
    method: str = "pca_kmeans",
    *,
    features: np.ndarray | None = None,
    seed: int = 0,
    pca_components: int = 8,
    init_centers: np.ndarray | None = None,
) -> list[int]:
    """Select ``k`` kernel-config indices to deploy, from normalized perf data.

    ``perf`` is (n_problems, n_configs) *normalized* performance; ``features``
    (problem sizes) is required only by the ``tree`` method.  ``init_centers``
    (perf-space centroids) warm-starts the ``kmeans`` and ``pca_kmeans``
    methods — the incremental-retune and transfer-tuning paths; for
    ``pca_kmeans`` the centroids are projected through the fitted PCA so the
    warm start happens in the same reduced space the clustering runs in.
    Other methods ignore it.
    """
    perf = np.asarray(perf, dtype=np.float64)
    if method == "topn":
        counts = np.bincount(perf.argmax(1), minlength=perf.shape[1])
        return [int(i) for i in np.argsort(-counts)[:k]]
    if method == "kmeans":
        labels, centers = kmeans(perf, k, seed=seed, init_centers=init_centers)
        chosen = _configs_from_centers(perf, labels, centers, k)
    elif method == "pca_kmeans":
        pca = PCA(n_components=min(pca_components, perf.shape[1], perf.shape[0])).fit(perf)
        z = pca.transform(perf)
        warm = pca.transform(init_centers) if init_centers is not None else None
        labels, _ = kmeans(z, k, seed=seed, init_centers=warm)
        chosen = _configs_from_labels(perf, labels, k)
    elif method == "spectral":
        labels = spectral_labels(perf, k, seed=seed)
        chosen = _configs_from_labels(perf, labels, k)
    elif method == "density":
        labels = density_labels(perf, k)
        chosen = _configs_from_labels(perf, labels, k)
    elif method == "tree":
        if features is None:
            raise ValueError("tree selection requires problem-size features")
        labels = regression_tree_leaves(features, perf, k)
        chosen = _configs_from_labels(perf, labels, k)
    else:
        raise ValueError(f"unknown selection method {method!r}; expected one of {CLUSTER_METHODS}")
    return _pad_selection(chosen, perf, k)
