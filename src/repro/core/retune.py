"""Continuous tuning loop: telemetry -> drift detection -> incremental retune.

The paper's pipeline is "fully automated, relying only on benchmark data" —
but that benchmark data is frozen at tune time, while the serving engine sees
the live problem distribution.  This module closes the loop (the adaptive-
libraries direction of Cianfriglia et al., and the online-autotuning
comparison of the paper's §2.2, combined): the offline classifier is a
*prior* that runtime evidence continuously corrects.

    selection log + OnlinePolicy measurements
        -> TelemetrySnapshot            (per-(family, shape-bucket) histograms)
        -> detect_drift                 (per family, vs the Deployment's
                                         training distribution, carried as
                                         provenance metadata in the artifact)
        -> incremental_retune           (re-harvest only drifted buckets,
                                         warm-start clustering from the
                                         deployed centroids, refit the
                                         classifier traffic-weighted)
        -> new Deployment               (hot-swapped into the serving engine's
                                         KernelRuntime with zero dropped
                                         requests)

Everything buckets per ``(device, family, shape)``: the matmul histogram
lives in ``meta["train_distribution"]`` (wire compat with v4 artifacts) and
every other family's in ``meta["family_distributions"][family]``, so an
ssm-only traffic shift retunes the ssm family without touching the matmul
artifact.  Everything is host-side numpy; the only measurement source needed
is the same benchmark-data supplier the offline pipeline used (each family's
analytic perf model for TPU targets, a measure hook for real hardware).  See
DESIGN.md §8-§9 for the telemetry schema, the drift metric, and the hot-swap
atomicity contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .classify import fit_weighted, make_classifier
from .cluster import select_configs
from .dataset import TuningDataset, build_model_dataset
from .dispatch import Deployment, build_labels
from .families import get_family
from .normalize import normalize
from .online import shape_bucket

Bucket = tuple[int, ...]

DEFAULT_DRIFT_THRESHOLD = 0.15
DEFAULT_MIN_EVENTS = 32


# ---------------------------------------------------------------------------
# training-distribution provenance (bundle v4+/Deployment.meta)
# ---------------------------------------------------------------------------
def bucket_key(bucket: Bucket) -> str:
    """JSON-safe bucket key: ``(9, 10, 9, 1)`` -> ``"9,10,9,1"``."""
    return ",".join(str(int(v)) for v in bucket)


def parse_bucket_key(key: str) -> Bucket:
    return tuple(int(v) for v in key.split(","))


def train_distribution(
    problems: list[tuple], weights: np.ndarray | None = None
) -> dict:
    """Provenance blob describing a tuning dataset's shape distribution.

    JSON-ready (it rides inside ``Deployment.meta`` and the v4+ bundle blob):

        {"buckets": {"9,10,9,1": {"w": 0.25, "problem": [512, 784, 512, 16]},
                     ...},
         "n_problems": 60}

    ``w`` is the bucket's share of (optionally weighted) problems; ``problem``
    is one representative shape per bucket, kept so an incremental retune can
    rebuild benchmark rows for undrifted buckets without the full dataset.
    """
    w = np.ones(len(problems)) if weights is None else np.asarray(weights, float)
    buckets: dict[str, dict] = {}
    total = float(w.sum()) or 1.0
    for p, wi in zip(problems, w):
        key = bucket_key(shape_bucket(p))
        ent = buckets.setdefault(key, {"w": 0.0, "problem": [int(v) for v in p]})
        ent["w"] += float(wi) / total
    return {"buckets": buckets, "n_problems": len(problems)}


def _dist_buckets(dist: dict | None) -> dict[Bucket, tuple[float, tuple]]:
    """Parse a provenance blob into ``{bucket: (weight, problem)}``."""
    if not dist or not dist.get("buckets"):
        return {}
    out = {}
    for key, ent in dist["buckets"].items():
        out[parse_bucket_key(key)] = (float(ent["w"]), tuple(int(v) for v in ent["problem"]))
    return out


def _deployment_distribution(deployment, family: str) -> dict | None:
    """The training-distribution provenance blob for one family."""
    if not isinstance(deployment, Deployment):
        return deployment  # caller passed the provenance dict itself
    if family == "matmul":
        return deployment.meta.get("train_distribution")
    return (deployment.meta.get("family_distributions") or {}).get(family)


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TelemetrySnapshot:
    """Aggregated runtime evidence for one serving window.

    ``counts`` holds one live shape-bucket histogram per kernel family
    (every trace-time selection, cache hits included, so frequencies reflect
    real traffic); ``family_problems`` keeps the most recent concrete shape
    per ``(family, bucket)`` (the re-harvest candidates); ``observed``
    carries any measured config timings an
    :class:`~repro.core.online.OnlinePolicy` gathered (bucket ->
    ``[(config, mean_s, trials)]``) — recorded for operators and for a
    future measured-retune path; :func:`detect_drift` and
    :func:`incremental_retune` key off the histograms alone today.

    ``matmul_counts`` / ``attention_counts`` / ``problems`` remain as live
    views into the per-family dicts (wire + test compat).
    """

    counts: dict[str, dict[Bucket, int]] = dataclasses.field(default_factory=dict)
    family_problems: dict[str, dict[Bucket, tuple]] = dataclasses.field(default_factory=dict)
    observed: dict[Bucket, list] = dataclasses.field(default_factory=dict)
    n_events: int = 0
    # Dispatch/serving incidents carried from the runtime (DESIGN.md §11):
    # structured records from the fault guard, newest last.  Purely
    # observational today — drift detection keys off the histograms — but the
    # canary and the engine's health watchdog read them alongside the counts.
    incidents: list[dict] = dataclasses.field(default_factory=list)

    # -- legacy views --------------------------------------------------------
    @property
    def matmul_counts(self) -> dict[Bucket, int]:
        return self.counts.setdefault("matmul", {})

    @property
    def attention_counts(self) -> dict[Bucket, int]:
        return self.counts.setdefault("attention", {})

    @property
    def problems(self) -> dict[Bucket, tuple]:
        return self.family_problems.setdefault("matmul", {})

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_selection_log(log: list[tuple], online=None) -> "TelemetrySnapshot":
        """Aggregate ``ops.selection_log()`` entries (op, problem, config).

        Every logged family is bucketed separately under its op name;
        ``online`` optionally supplies an ``OnlinePolicy`` whose
        ``measurements()`` are folded in as observed config timings.
        """
        snap = TelemetrySnapshot()
        for op, problem, _cfg in log:
            b = shape_bucket(problem)
            fam = snap.counts.setdefault(op, {})
            fam[b] = fam.get(b, 0) + 1
            snap.family_problems.setdefault(op, {})[b] = tuple(int(v) for v in problem)
            snap.n_events += 1
        if online is not None and hasattr(online, "measurements"):
            for b, rows in online.measurements().items():
                snap.observed.setdefault(b, []).extend(rows)
        return snap

    @staticmethod
    def from_runtime(runtime, online=None) -> "TelemetrySnapshot":
        """Aggregate one :class:`~repro.core.runtime.KernelRuntime`'s log.

        The runtime handle owns the telemetry window (per-tenant, isolated
        from every other runtime in the process); this is
        :meth:`from_selection_log` fed from ``runtime.selection_log()``,
        plus the runtime's recorded dispatch incidents.
        """
        snap = TelemetrySnapshot.from_selection_log(runtime.selection_log(), online=online)
        snap.incidents = runtime.incidents()
        return snap

    def families(self) -> list[str]:
        """Families with at least one recorded event, matmul first."""
        return sorted(
            (f for f, c in self.counts.items() if c), key=lambda f: (f != "matmul", f)
        )

    def family_events(self, family: str) -> int:
        return int(sum(self.counts.get(family, {}).values()))

    def histogram(self, family: str = "matmul") -> dict[Bucket, float]:
        """Normalized live traffic histogram for one family (sums to 1)."""
        fam = self.counts.get(family, {})
        total = float(sum(fam.values()))
        if total <= 0:
            return {}
        return {b: c / total for b, c in fam.items()}

    def merge(self, other: "TelemetrySnapshot") -> "TelemetrySnapshot":
        """Fold ``other`` into this snapshot (windowed or multi-host collection).

        **Commutative**: folding host A's snapshot into host B's produces the
        same aggregate as folding B into A — a federation service merging
        per-(device, family) telemetry from many serving hosts must not let
        arrival order change the drift verdict.  Histogram counts and
        ``n_events`` add; the representative problem per bucket is the
        largest shape tuple seen for it (deterministic, and within a bucket
        any member is an equally valid re-harvest candidate); ``observed``
        rows and ``incidents`` are kept in a canonical sort (per-host
        ``seq`` order is preserved inside the incident sort key).
        """
        for fname, fam in other.counts.items():
            mine = self.counts.setdefault(fname, {})
            for b, c in fam.items():
                mine[b] = mine.get(b, 0) + c
        for fname, probs in other.family_problems.items():
            mine_p = self.family_problems.setdefault(fname, {})
            for b, p in probs.items():
                prev = mine_p.get(b)
                mine_p[b] = p if prev is None else max(prev, tuple(p))
        for b, rows in other.observed.items():
            merged = self.observed.setdefault(b, [])
            merged.extend(rows)
            merged.sort(key=repr)
        if other.incidents:
            self.incidents = sorted(
                self.incidents + list(other.incidents),
                key=lambda r: (r.get("seq", 0), repr(sorted(r.items(), key=str))),
            )
        self.n_events += other.n_events
        return self

    # -- wire form (control-plane telemetry federation) ----------------------
    def to_json(self) -> dict:
        """JSON-ready wire form for federation (``POST /telemetry``).

        Bucket tuples become the ``bucket_key`` strings of the provenance
        blobs; observed config objects are flattened to their ``name()``
        string (the observed table is operator-facing evidence — the drift
        detector and the incremental retune key off the histograms and
        representative problems, which round-trip exactly).
        """
        def cfg_name(c):
            if c is None:
                return None
            return c.name() if hasattr(c, "name") and callable(c.name) else str(c)

        return {
            "version": 1,
            "counts": {
                fam: {bucket_key(b): int(c) for b, c in sorted(buckets.items())}
                for fam, buckets in sorted(self.counts.items())
            },
            "problems": {
                fam: {bucket_key(b): [int(v) for v in p] for b, p in sorted(probs.items())}
                for fam, probs in sorted(self.family_problems.items())
            },
            "observed": {
                bucket_key(b): [
                    [cfg_name(cfg), float(mean), int(trials)]
                    for cfg, mean, trials in rows
                ]
                for b, rows in sorted(self.observed.items())
            },
            "incidents": [dict(r) for r in self.incidents],
            "n_events": int(self.n_events),
        }

    @staticmethod
    def from_json(blob: dict) -> "TelemetrySnapshot":
        """Parse the :meth:`to_json` wire form back into a snapshot.

        Counts, representative problems, incidents, and ``n_events``
        round-trip exactly; observed configs come back as their name strings.
        """
        snap = TelemetrySnapshot()
        for fam, buckets in (blob.get("counts") or {}).items():
            snap.counts[fam] = {
                parse_bucket_key(k): int(c) for k, c in buckets.items()
            }
        for fam, probs in (blob.get("problems") or {}).items():
            snap.family_problems[fam] = {
                parse_bucket_key(k): tuple(int(v) for v in p) for k, p in probs.items()
            }
        for k, rows in (blob.get("observed") or {}).items():
            snap.observed[parse_bucket_key(k)] = [
                (cfg, float(mean), int(trials)) for cfg, mean, trials in rows
            ]
        snap.incidents = [dict(r) for r in blob.get("incidents") or []]
        snap.n_events = int(blob.get("n_events", 0))
        return snap


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Outcome of comparing live traffic against the training distribution.

    ``score`` is the Jensen-Shannon divergence (base 2, so 0 = identical,
    1 = disjoint) between the two bucket histograms; ``unseen_fraction`` is
    the live mass on buckets the tuning dataset never contained (the part no
    classifier accuracy can fix); ``drifted_buckets`` are the re-harvest
    targets, heaviest excess live mass first.  ``family`` names the kernel
    family the report covers (drift is detected per (device, family, shape)).
    """

    score: float
    unseen_fraction: float
    drifted_buckets: tuple[Bucket, ...]
    threshold: float
    n_events: int
    triggered: bool
    family: str = "matmul"


def js_divergence(p: dict[Bucket, float], q: dict[Bucket, float]) -> float:
    """Jensen-Shannon divergence between two bucket histograms, in [0, 1]."""
    keys = sorted(set(p) | set(q))
    if not keys:
        return 0.0
    pv = np.array([p.get(k, 0.0) for k in keys])
    qv = np.array([q.get(k, 0.0) for k in keys])
    pv = pv / max(pv.sum(), 1e-12)
    qv = qv / max(qv.sum(), 1e-12)
    m = 0.5 * (pv + qv)

    def kl(a, b):
        mask = a > 0
        return float(np.sum(a[mask] * np.log2(a[mask] / np.maximum(b[mask], 1e-300))))

    return 0.5 * kl(pv, m) + 0.5 * kl(qv, m)


def detect_drift(
    snapshot: TelemetrySnapshot,
    deployment: Deployment | dict | None,
    *,
    family: str = "matmul",
    threshold: float = DEFAULT_DRIFT_THRESHOLD,
    min_events: int = DEFAULT_MIN_EVENTS,
) -> DriftReport:
    """Compare one family's live traffic against its training distribution.

    ``deployment`` may be a :class:`Deployment` (provenance read from
    ``meta["train_distribution"]`` for matmul, ``meta["family_distributions"]``
    otherwise) or the provenance dict itself.  An artifact predating
    provenance (v1-v3, or a family tuned before per-family provenance)
    scores 1.0 — everything live is unseen as far as the frozen tuning data
    can prove, so past the event floor it always triggers a retune to the
    observed distribution.
    """
    dist = _deployment_distribution(deployment, family)
    live = snapshot.histogram(family)
    n_events = snapshot.family_events(family)
    train = {b: w for b, (w, _p) in _dist_buckets(dist).items()}
    if not live:
        return DriftReport(0.0, 0.0, (), threshold, n_events, False, family)
    if not train:
        drifted = tuple(sorted(live, key=lambda b: -live[b]))
        trig = n_events >= min_events
        return DriftReport(1.0, 1.0, drifted, threshold, n_events, trig, family)
    score = js_divergence(live, train)
    unseen = sum(w for b, w in live.items() if b not in train)
    # Re-harvest targets: buckets with materially more live than train mass.
    excess = {b: live[b] - train.get(b, 0.0) for b in live}
    margin = 0.5 / max(len(live), 1)
    drifted = tuple(
        sorted((b for b, e in excess.items() if e > margin or b not in train),
               key=lambda b: -excess[b])
    )
    triggered = n_events >= min_events and score >= threshold
    return DriftReport(score, unseen, drifted, threshold, n_events, triggered, family)


def detect_drift_all(
    snapshot: TelemetrySnapshot,
    deployment: Deployment | None,
    *,
    threshold: float = DEFAULT_DRIFT_THRESHOLD,
    min_events: int = DEFAULT_MIN_EVENTS,
) -> dict[str, DriftReport]:
    """One :func:`detect_drift` report per family with live traffic."""
    return {
        fam: detect_drift(
            snapshot, deployment, family=fam, threshold=threshold, min_events=min_events
        )
        for fam in snapshot.families()
    }


# ---------------------------------------------------------------------------
# incremental retune
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RetuneResult:
    deployment: Deployment
    report: DriftReport
    n_harvested: int  # buckets whose benchmark rows were newly measured
    n_problems: int  # total problems in the blended retune dataset
    warm_started: bool
    family: str = "matmul"


def _warm_start_centers(
    norm_perf: np.ndarray, all_configs: list, perf: np.ndarray, deployed_configs: list
) -> np.ndarray | None:
    """Perf-space centroids implied by the deployed kernel subset.

    Shared with the staged pipeline's transfer warm-start — a retune is a
    transfer from the deployment's own past (see ``pipeline.warm_start_centers``
    for the grouping semantics).
    """
    from .pipeline import warm_start_centers

    return warm_start_centers(norm_perf, all_configs, perf, deployed_configs)


def _blend_problems(
    train: dict[Bucket, tuple[float, tuple]],
    live: dict[Bucket, float],
    live_problems: dict[Bucket, tuple],
    drifted: set,
    blend: float,
) -> tuple[list[tuple], list[float], int]:
    """Blend train + live distributions into one weighted problem list.

    Drifted buckets take their *live* representative problem (the fresh
    harvest); undrifted training buckets keep their provenance representative.
    """
    problems: list[tuple] = []
    weights: list[float] = []
    harvested = 0
    for b in sorted(set(train) | set(live)):
        t_w = train.get(b, (0.0, None))[0]
        l_w = live.get(b, 0.0)
        w = (1.0 - blend) * t_w + blend * l_w
        if w <= 0:
            continue
        if b in drifted and b in live_problems:
            problems.append(live_problems[b])
            harvested += 1
        elif b in train:
            problems.append(train[b][1])
        elif b in live_problems:
            problems.append(live_problems[b])
            harvested += 1
        else:
            continue
        weights.append(w)
    return problems, weights, harvested


def incremental_retune(
    deployment: Deployment,
    snapshot: TelemetrySnapshot,
    *,
    family: str = "matmul",
    report: DriftReport | None = None,
    threshold: float = DEFAULT_DRIFT_THRESHOLD,
    min_events: int = DEFAULT_MIN_EVENTS,
    n_kernels: int | None = None,
    blend: float = 0.5,
    normalization: str = "standard",
    seed: int = 0,
    dataset_builder=None,
) -> RetuneResult:
    """Refresh one family of a deployment against observed traffic, cheaply.

    Incremental in three ways (vs a full ``tuner.tune`` run):

      * the benchmark set is *buckets*, not the original problem list — one
        representative problem per training bucket (from provenance) plus the
        live problems of **drifted buckets only** (fresh harvest);
      * clustering warm-starts from the deployed centroids
        (:func:`_warm_start_centers` + ``cluster.kmeans(init_centers=...)``)
        instead of ``n_init`` cold k-means++ restarts;
      * the classifier refit is traffic-weighted
        (:func:`repro.core.classify.fit_weighted` on the blended histogram),
        so accuracy concentrates where the live workload actually is.

    ``family`` picks which kernel family to retune — only that family's
    ``(configs, tree)`` and provenance change; every other family is carried
    over untouched (its telemetry carries no evidence about this one).
    ``blend`` sets the live-vs-train mix of the target distribution (0.5 =
    equal weight: the retuned artifact still serves yesterday's traffic).
    ``dataset_builder(problems, device)`` overrides the benchmark-data source
    for the matmul family (defaults to the analytic perf model; required for
    devices the model does not cover, e.g. measured ``host_cpu``); other
    families use their registry-declared perf model.
    """
    if report is None:
        report = detect_drift(
            snapshot, deployment, family=family, threshold=threshold, min_events=min_events
        )
    train = _dist_buckets(_deployment_distribution(deployment, family))
    live = snapshot.histogram(family)
    live_problems = snapshot.family_problems.get(family, {})
    problems, weights, harvested = _blend_problems(
        train, live, live_problems, set(report.drifted_buckets), blend
    )
    if not problems:
        raise ValueError("incremental_retune needs telemetry or provenance problems")
    w = np.asarray(weights, dtype=np.float64)

    if family == "matmul":
        build = dataset_builder or _model_dataset_builder
        ds = build(problems, deployment.device)
        all_configs, perf, feats = ds.configs, ds.perf, ds.features
        dist_problems = ds.problems
    else:
        fam = get_family(family)
        all_configs = list(fam.config_space())
        # Same perf surface the offline tuning used: device-insensitive
        # families keep their single model target, so a zero-drift retune
        # cannot churn kernels just by switching models.
        model_device = deployment.device if fam.device_sensitive else None
        perf = fam.perf_matrix(problems, all_configs, model_device)
        feats = fam.features(problems)
        dist_problems = problems

    norm = normalize(perf, normalization)
    deployed, _tree = deployment.family_tuning(family)
    k = n_kernels or len(deployed) or get_family(family).default_n_kernels
    k = min(k, len(all_configs))
    centers = _warm_start_centers(norm, all_configs, perf, deployed)
    chosen = select_configs(norm, k, "kmeans", seed=seed, init_centers=centers)

    labels = build_labels(perf, chosen)
    if family == "matmul":
        clf = make_classifier(deployment.classifier_name, seed=seed)
    else:
        clf = get_family(family).make_tree(seed)
    fit_weighted(clf, feats, labels, w)

    new_dep = deployment.clone()
    new_dep.set_family_tuning(family, [all_configs[i] for i in chosen], clf)
    new_dist = train_distribution(dist_problems, w)
    if family == "matmul":
        new_dep.meta["train_distribution"] = new_dist
    else:
        dists = dict(new_dep.meta.get("family_distributions") or {})
        dists[family] = new_dist
        new_dep.meta["family_distributions"] = dists
    new_dep.meta["retune_count"] = int(new_dep.meta.get("retune_count", 0)) + 1
    record = {
        "family": family,
        "drift_score": round(report.score, 6),
        "unseen_fraction": round(report.unseen_fraction, 6),
        "n_harvested_buckets": harvested,
        "n_problems": len(problems),
        "warm_started": centers is not None,
    }
    new_dep.meta["retune"] = record  # the latest retune (wire compat)
    # Bounded audit trail: one retune cycle may refresh several families
    # (engine.maybe_retune chains calls), and each record must survive —
    # otherwise retune_count and the recorded events could not be reconciled.
    new_dep.meta["retune_log"] = (list(new_dep.meta.get("retune_log") or []) + [record])[-16:]
    return RetuneResult(
        deployment=new_dep,
        report=report,
        n_harvested=harvested,
        n_problems=len(problems),
        warm_started=centers is not None,
        family=family,
    )


def _model_dataset_builder(problems: list[tuple], device: str) -> TuningDataset:
    from .perfmodel import DEVICES

    if device not in DEVICES:
        raise ValueError(
            f"no analytic perf model for device {device!r}; pass dataset_builder= "
            f"(e.g. a cpubench-backed measurer) to incremental_retune"
        )
    return build_model_dataset(problems, device_name=device)


# ---------------------------------------------------------------------------
# canary: validate a retune candidate before it is installed (DESIGN.md §11)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CanaryReport:
    """Verdict on one retune candidate for one family.

    ``selection_score_*`` are traffic-weighted achieved-fraction scores on
    the holdout (1.0 = every holdout problem gets its best deployable
    config); ``None`` when no perf model covers the device, in which case
    the selection check abstains (passes).  ``numeric_ok`` is the
    ref-agreement probe.  ``ok`` is the installable verdict.
    """

    family: str
    ok: bool
    selection_ok: bool
    numeric_ok: bool
    selection_score_new: float | None = None
    selection_score_old: float | None = None
    reason: str = ""


def _holdout_problems(
    snapshot: TelemetrySnapshot, family: str, holdout: int
) -> tuple[list[tuple], list[float]]:
    """The ``holdout`` heaviest-traffic buckets' representative problems."""
    live = snapshot.histogram(family)
    probs = snapshot.family_problems.get(family, {})
    buckets = sorted(live, key=lambda b: -live[b])[: max(int(holdout), 1)]
    pairs = [(probs[b], live[b]) for b in buckets if b in probs]
    return [p for p, _ in pairs], [w for _, w in pairs]


def _selection_score(
    deployment: Deployment, family: str, problems: list[tuple], weights: list[float]
) -> float | None:
    """Traffic-weighted achieved fraction of best deployable perf; None = no model."""
    fam = get_family(family)
    configs = list(fam.config_space())
    model_device = deployment.device if fam.device_sensitive else None
    try:
        perf = np.asarray(fam.perf_matrix(problems, configs, model_device))
    except Exception:
        return None  # no analytic model for this device: the check abstains
    best = perf.max(axis=1)
    total = sum(weights) or 1.0
    score = 0.0
    for i, p in enumerate(problems):
        cfg = deployment.select(family, p)
        try:
            j = configs.index(cfg)
        except ValueError:
            j = None
        achieved = float(perf[i, j]) if j is not None else 0.0
        score += weights[i] * (achieved / best[i] if best[i] > 0 else 0.0)
    return score / total


def _numeric_agreement(family: str, config, runtime) -> tuple[bool, str]:
    """Tiny probe through the family kernel with ``config`` vs the reference.

    Runs the candidate's selected config against the ``kernels.ref`` oracle
    on seeded inputs.  The probe honors the runtime's ``canary.<family>``
    fault-injection site (an injected failure rejects the candidate — the
    dispatch guard is deliberately *not* in the loop here, so containment
    cannot mask a canary failure) but detaches the plan around the kernel
    call itself: dispatch-site faults belong to serving, not to the canary.
    """
    import jax.numpy as jnp

    from repro.kernels import ref

    if config is None:
        return True, ""
    plan = getattr(runtime, "fault_plan", None) if runtime is not None else None
    key = config.name() if hasattr(config, "name") and callable(config.name) else str(config)
    spec = None
    if plan is not None:
        from .faults import FaultError

        try:
            spec = plan.raise_if(f"canary.{family}", key)
        except FaultError as e:
            return False, f"canary probe failed: {e}"
    rng = np.random.default_rng(0)
    f32 = lambda *shape: jnp.asarray(rng.normal(size=shape), jnp.float32)
    use_pallas = bool(getattr(runtime, "use_pallas", False))
    interpret = bool(getattr(runtime, "interpret", False))
    saved = getattr(runtime, "fault_plan", None) if runtime is not None else None
    if runtime is not None:
        runtime.fault_plan = None
    try:
        if family == "matmul":
            a, b = f32(8, 16), f32(16, 8)
            expect = ref.matmul_ref(a, b)
            if use_pallas:
                from repro.kernels.matmul import matmul_pallas

                got = matmul_pallas(a, b, config, interpret=interpret)
            else:
                got = jnp.dot(a, b, preferred_element_type=jnp.float32)
        elif family == "attention":
            q, k, v = f32(8, 16), f32(8, 16), f32(8, 16)
            expect = ref.flash_attention_ref(q, k, v)
            if use_pallas:
                from repro.kernels.attention import flash_attention_pallas

                got = flash_attention_pallas(q, k, v, config, interpret=interpret)
            else:
                got = expect
        elif family == "wkv":
            r, k, v, logw = f32(1, 8, 1, 4), f32(1, 8, 1, 4), f32(1, 8, 1, 4), f32(1, 8, 1, 4)
            u = f32(1, 4)
            expect = ref.wkv_ref(r, k, v, -jnp.abs(logw), u)[0]
            got = expect  # Pallas wkv probe rides the vmapped ops path only
        elif family == "ssm_scan":
            dtx, dta = f32(1, 8, 4), f32(1, 8, 4, 2)
            b_in, c_in = f32(1, 8, 2), f32(1, 8, 2)
            expect = ref.ssm_scan_ref(dtx, -jnp.abs(dta), b_in, c_in)[0]
            got = expect
        else:
            return True, ""
    except Exception as e:  # a real compile/lowering failure on this config
        return False, f"canary probe raised: {type(e).__name__}: {e}"
    finally:
        if runtime is not None:
            runtime.fault_plan = saved
    if spec is not None and spec.kind in ("nan", "inf"):
        from .faults import FaultPlan

        got = FaultPlan.corrupt_array(spec, got)
    if not bool(jnp.isfinite(got).all()):
        return False, "canary probe produced non-finite output"
    if not bool(jnp.allclose(got, expect, rtol=1e-3, atol=1e-3)):
        return False, "canary probe disagrees with reference"
    return True, ""


def canary_deployment(
    old: Deployment,
    new: Deployment,
    snapshot: TelemetrySnapshot,
    *,
    family: str = "matmul",
    holdout: int = 8,
    tolerance: float = 0.05,
    runtime=None,
) -> CanaryReport:
    """Gate a retune candidate on a holdout of recent telemetry.

    Two checks, both of which must pass before ``install_for_device``:

      * **selection quality** — on the ``holdout`` heaviest live buckets,
        the candidate's traffic-weighted achieved fraction (per the family's
        perf model) must not regress more than ``tolerance`` below the
        incumbent's.  Abstains (passes) when no perf model covers the
        device — a measured-path retune validates numerically only.
      * **numeric agreement** — the config the candidate selects for the
        heaviest bucket must reproduce the ``kernels.ref`` oracle on a
        seeded probe; honors the ``canary.<family>`` injection site.
    """
    problems, weights = _holdout_problems(snapshot, family, holdout)
    if not problems:
        return CanaryReport(family, True, True, True, reason="no holdout traffic")
    s_new = _selection_score(new, family, problems, weights)
    s_old = _selection_score(old, family, problems, weights)
    selection_ok = True
    reason = ""
    if s_new is not None and s_old is not None and s_new < s_old - tolerance:
        selection_ok = False
        reason = (
            f"selection quality regressed: {s_new:.4f} < {s_old:.4f} - {tolerance}"
        )
    probe_cfg = new.select(family, problems[0])
    numeric_ok, num_reason = _numeric_agreement(family, probe_cfg, runtime)
    ok = selection_ok and numeric_ok
    return CanaryReport(
        family, ok, selection_ok, numeric_ok, s_new, s_old, reason or num_reason
    )
