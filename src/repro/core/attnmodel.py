"""Analytic performance model for the Pallas flash-attention family.

Extends the paper's pipeline to a second, more complicated kernel family
(its stated future-work direction): the attention problem space is
``(sq, skv, d)`` and the config space is ``AttentionConfig(block_q,
block_kv)``.  Same physics as core.perfmodel: overlapped compute/memory
roofline over the exact Pallas tile-streaming pattern + deterministic
microarchitectural texture, VMEM-overflow configs fail.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.attention import AttentionConfig, attention_config_space

from .perfmodel import DeviceModel, TPU_V5E, _hash_unit

AttnProblem = tuple[int, int, int]  # (sq, skv, head_dim)

ATTN_FEATURE_NAMES = ("log2_sq", "log2_skv", "log2_d", "log2_sq_over_skv")


def attn_problem_features(problems: list[AttnProblem]) -> np.ndarray:
    p = np.asarray(problems, dtype=np.float64).reshape(-1, 3)
    if p.size == 0:
        return np.zeros((0, len(ATTN_FEATURE_NAMES)))
    sq, skv, d = p.T
    return np.column_stack([np.log2(sq), np.log2(skv), np.log2(d), np.log2(sq / skv)])


def _vmem_bytes(cfg: AttentionConfig, d: int, dtype_bytes: int = 2) -> int:
    # q tile + k tile + v tile (double-buffered) + f32 scratch (m, l, acc).
    tiles = cfg.block_q * d + 2 * cfg.block_kv * d
    scratch = cfg.block_q * (128 + 128 + d) * 4
    return 2 * tiles * dtype_bytes + scratch


def predict_attn_time(
    problem: AttnProblem,
    cfg: AttentionConfig,
    device: DeviceModel = TPU_V5E,
    *,
    causal: bool = True,
    dtype_bytes: int = 2,
    texture: bool = True,
) -> float:
    sq, skv, d = problem
    if _vmem_bytes(cfg, d, dtype_bytes) > device.vmem_bytes:
        return float("inf")
    bq = min(cfg.block_q, _round_up(sq, 8))
    bkv = min(cfg.block_kv, _round_up(skv, 128))
    n_q = _ceil(sq, bq)
    n_kv = _ceil(skv, bkv)
    # Causal masking skips fully-masked kv blocks: ~half the tiles when
    # sq == skv, none skipped for decode (sq=1 attends everything).
    if causal and sq == skv:
        live_tiles = n_q * (n_kv + 1) / 2.0
    else:
        live_tiles = float(n_q * n_kv)
    flops = 4.0 * live_tiles * bq * bkv * d  # qk^T + pv
    # Softmax/VPU work scales with logits tiles — penalize tiny bkv (lane
    # under-fill) and tiny bq (sublane under-fill on the MXU).
    util = (min(bq, device.mxu_dim) / device.mxu_dim) * (min(bkv, device.mxu_dim) / device.mxu_dim)
    t_compute = flops / (device.peak_flops * util)
    # Memory: q/out loaded+stored once per q row; k/v streamed once per q block.
    traffic = (2.0 * sq * d + 2.0 * n_q * skv * d) * dtype_bytes
    t_mem = traffic / device.hbm_bw
    t = max(t_compute, t_mem) + live_tiles * device.grid_step_overhead + device.launch_overhead
    if not texture:  # smooth roofline: the model-side view (see perfmodel)
        return t
    return t / _texture(device, cfg, problem)


def _texture(device: DeviceModel, cfg: AttentionConfig, problem: AttnProblem) -> float:
    key = (cfg.block_q, cfg.block_kv)
    e_cfg = 1.0 - 0.10 * _hash_unit(device.name, "attn_cfg", key)
    bucket = tuple(int(np.log2(max(v, 1))) for v in problem)
    e_int = 1.0 + 0.07 * (2.0 * _hash_unit(device.name, "attn_int", key, bucket) - 1.0)
    return max(e_cfg * e_int, 1e-3)


def predict_attn_gflops(problem: AttnProblem, cfg: AttentionConfig, device=TPU_V5E, **kw) -> float:
    t = predict_attn_time(problem, cfg, device, **kw)
    if not np.isfinite(t) or t <= 0:
        return 0.0
    sq, skv, d = problem
    useful = 4.0 * sq * skv * d * (0.5 if kw.get("causal", True) and sq == skv else 1.0)
    return useful / t / 1e9


def harvest_attn_problems(arch_ids: list[str] | None = None) -> list[AttnProblem]:
    """Attention shapes the assigned architectures actually launch."""
    from repro.configs import registry

    arch_ids = arch_ids or list(registry.ARCHS)
    out: set[AttnProblem] = set()
    for arch in arch_ids:
        cfg = registry.get(arch)
        if cfg.family == "ssm":
            continue  # attention-free (DESIGN.md §4)
        hd = cfg.head_dim
        for shape in registry.shapes_for(arch):
            sp = registry.SHAPES[shape]
            if sp.kind == "decode":
                out.add((1, sp.seq_len, hd))
            else:
                out.add((sp.seq_len, sp.seq_len, hd))
                # chunked-prefill style sub-blocks
                out.add((min(2048, sp.seq_len), sp.seq_len, hd))
    return sorted(out)


def build_attn_matrix(
    problems: list[AttnProblem], configs=None, device: DeviceModel = TPU_V5E,
    *, texture: bool = True,
) -> np.ndarray:
    configs = list(configs or attention_config_space())
    perf = np.zeros((len(problems), len(configs)))
    for i, p in enumerate(problems):
        for j, c in enumerate(configs):
            perf[i, j] = predict_attn_gflops(p, c, device, texture=texture)
    return perf


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
