"""Kernel-subset selection + evaluation (paper §4.2-4.3, Figs. 5-6)."""
from __future__ import annotations

import numpy as np

from .cluster import CLUSTER_METHODS, select_configs
from .dataset import TuningDataset
from .normalize import NORMALIZATIONS, normalize

_EPS = 1e-12


def geomean_fraction(picked: np.ndarray, best: np.ndarray) -> float:
    """Geomean over problems of picked-perf / best-perf (the paper's headline
    fraction-of-optimal metric).

    The one shared implementation: oracle fractions (:func:`achievable_fraction`),
    shipped-classifier fractions (``dispatch.classifier_fraction``,
    ``tuner.tune_family``), and the gated family benchmarks all call this, so
    the epsilon/clipping policy cannot drift between them.  Problems where no
    config achieved positive perf count as 1.0 (nothing was achievable).
    """
    picked = np.asarray(picked, dtype=np.float64)
    best = np.asarray(best, dtype=np.float64)
    ratio = np.where(best > 0, picked / np.maximum(best, _EPS), 1.0)
    return float(np.exp(np.mean(np.log(np.maximum(ratio, _EPS)))))


def select_from_dataset(
    ds: TuningDataset,
    n_kernels: int,
    method: str = "pca_kmeans",
    normalization: str = "standard",
    *,
    seed: int = 0,
) -> list[int]:
    """Pick the config indices to deploy, from a *training* dataset."""
    norm = normalize(ds.perf, normalization)
    return select_configs(norm, n_kernels, method, features=ds.features, seed=seed)


def achievable_fraction(perf_test: np.ndarray, chosen: list[int]) -> float:
    """Geomean over problems of best-deployed / best-overall (paper §4.3).

    This is the *oracle* fraction: assumes the launcher always picks the best
    of the deployed kernels (classifier quality is measured separately).
    """
    perf_test = np.asarray(perf_test, dtype=np.float64)
    return geomean_fraction(perf_test[:, chosen].max(axis=1), perf_test.max(axis=1))


def evaluate_methods(
    train: TuningDataset,
    test: TuningDataset,
    n_kernels_range: list[int],
    methods: list[str] | None = None,
    normalizations: list[str] | None = None,
    *,
    seed: int = 0,
) -> dict[tuple[str, str, int], float]:
    """The full Fig. 5/6 sweep: (method, normalization, n) -> oracle fraction."""
    methods = methods or list(CLUSTER_METHODS)
    normalizations = normalizations or list(NORMALIZATIONS)
    out: dict[tuple[str, str, int], float] = {}
    for norm in normalizations:
        for method in methods:
            for n in n_kernels_range:
                chosen = select_from_dataset(train, n, method, norm, seed=seed)
                out[(method, norm, n)] = achievable_fraction(test.perf, chosen)
    return out
