"""Pallas TPU kernel for the Mamba selective-SSM scan (Hymba's recurrence).

Fourth tunable kernel family.  The jnp reference (models/mamba.py) runs an
``associative_scan`` that materializes the (B, S, d, N) state history in HBM
— N=16× the activation traffic.  This kernel fuses the recurrence: the
running (d_block, N) state lives in VMEM scratch, the sequence streams
through in chunks, and only y (S, d) ever leaves the core.

Grid: (d_blocks, n_chunks) — d parallel, chunks sequential ('arbitrary');
the state scratch carries across the chunk dimension.  Config knobs:
``block_d`` (VMEM/occupancy) × ``chunk`` (stream granularity).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True, order=True)
class SsmConfig:
    block_d: int = 128
    chunk: int = 32

    def name(self) -> str:
        return f"ssm_bd{self.block_d}_c{self.chunk}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "SsmConfig":
        return SsmConfig(**d)


@functools.cache
def ssm_config_space() -> tuple[SsmConfig, ...]:
    out = []
    for bd in (64, 128, 256):
        for c in (16, 32, 64):
            out.append(SsmConfig(bd, c))
    return tuple(out)


DEFAULT_SSM_CONFIG = SsmConfig(128, 32)


def _ssm_kernel(dtx_ref, dta_ref, b_ref, c_ref, s0_ref, y_ref, sout_ref, h_ref, *, n_chunks: int, chunk: int):
    """One grid step = (d_block, chunk).

    dtx: (L, bd)   dt * x  (input term, f32)
    dta: (L, bd*N) dt * a  (log decay per channel/state, f32, flattened N-major)
    b/c: (L, N)    input/output mixing vectors
    s0:  (bd, N)   initial state for this d block
    """
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = s0_ref[...].astype(jnp.float32)

    dtx = dtx_ref[...].astype(jnp.float32)
    bvec = b_ref[...].astype(jnp.float32)
    cvec = c_ref[...].astype(jnp.float32)
    bd = dtx.shape[1]
    n = bvec.shape[1]
    dta = dta_ref[...].astype(jnp.float32).reshape(chunk, bd, n)

    def step(t, carry):
        h = carry
        abar = jnp.exp(dta[t])  # (bd, N)
        bx = dtx[t][:, None] * bvec[t][None, :]  # (bd, N)
        h = abar * h + bx
        y_t = jnp.sum(h * cvec[t][None, :], axis=1)  # (bd,)
        pl.store(y_ref, (pl.dslice(t, 1), slice(None)), y_t[None, :].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == n_chunks - 1)
    def _store():
        sout_ref[...] = h.astype(sout_ref.dtype)


def ssm_scan_pallas(
    dtx: jax.Array,
    dta: jax.Array,
    b: jax.Array,
    c: jax.Array,
    state: jax.Array | None = None,
    config: SsmConfig = DEFAULT_SSM_CONFIG,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Selective-SSM scan for one batch element.

    dtx (S, d) = dt*x;  dta (S, d, N) = dt[..,None]*a;  b/c (S, N);
    state (d, N) or None.  Returns (y (S, d) f32, final_state (d, N) f32)
    where h_t = exp(dta_t) * h_{t-1} + dtx_t * b_t  and  y_t = <h_t, c_t>_N.
    """
    s_len, d = dtx.shape
    n = b.shape[1]
    bd = min(config.block_d, d)
    chunk = min(config.chunk, max(s_len, 8))
    pad_s = (-s_len) % chunk
    pad_d = (-d) % bd
    if pad_s or pad_d:
        dtx = jnp.pad(dtx, ((0, pad_s), (0, pad_d)))
        dta = jnp.pad(dta, ((0, pad_s), (0, pad_d), (0, 0)))
        b = jnp.pad(b, ((0, pad_s), (0, 0)))
        c = jnp.pad(c, ((0, pad_s), (0, 0)))
    sp, dp = s_len + pad_s, d + pad_d
    if state is None:
        state = jnp.zeros((dp, n), jnp.float32)
    elif pad_d:
        state = jnp.pad(state, ((0, pad_d), (0, 0)))
    n_chunks = sp // chunk
    n_d = dp // bd
    dta2 = dta.reshape(sp, dp * n)  # flatten (d, N) N-major for 2-D blocking

    kernel = functools.partial(_ssm_kernel, n_chunks=n_chunks, chunk=chunk)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(n_d, n_chunks),
        in_specs=[
            pl.BlockSpec((chunk, bd), lambda di, ci: (ci, di)),
            pl.BlockSpec((chunk, bd * n), lambda di, ci: (ci, di)),
            pl.BlockSpec((chunk, n), lambda di, ci: (ci, 0)),
            pl.BlockSpec((chunk, n), lambda di, ci: (ci, 0)),
            pl.BlockSpec((bd, n), lambda di, ci: (di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((chunk, bd), lambda di, ci: (ci, di)),
            pl.BlockSpec((bd, n), lambda di, ci: (di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sp, dp), jnp.float32),
            jax.ShapeDtypeStruct((dp, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(dimension_semantics=("parallel", "arbitrary")),
    )(dtx, dta2, b, c, state)
    return y[:s_len, :d], s_out[:d]
