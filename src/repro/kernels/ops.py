"""Jit'd kernel entry points + the runtime kernel-selection hook (paper §5).

This is the "kernel launcher" of the paper: every matmul in the framework
routes through :func:`matmul`, which consults the installed
:class:`KernelPolicy` to pick one of the *deployed* kernel configurations for
the problem size at trace time (JAX shapes are static, so trace time is the
TPU-native "runtime" — see DESIGN.md §2).

A policy is produced by ``repro.core.tuner`` from benchmark data.  With no
policy installed (or on hosts without a TPU), the op falls back to XLA's
``jnp.dot`` — numerically identical to the Pallas path (same f32
accumulation), which the kernel tests assert.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict, deque
from typing import Protocol

import jax
import jax.numpy as jnp

from .attention import DEFAULT_ATTN_CONFIG, AttentionConfig, flash_attention_pallas
from .matmul import DEFAULT_CONFIG, MatmulConfig, matmul_pallas
from .ref import flash_attention_ref
from .ssm import DEFAULT_SSM_CONFIG, SsmConfig, ssm_scan_pallas
from .wkv import DEFAULT_WKV_CONFIG, WkvConfig, wkv_pallas


class KernelPolicy(Protocol):
    """Maps a kernel-family problem to the deployed config that should run it.

    One ``select_<family>`` hook per registered family
    (``repro.core.families``); the ops layer resolves the hook generically
    via the registry's ``policy_attr``, so a policy implementing only a
    subset keeps working — unimplemented families fall back to their default
    config (unless the policy exposes a generic ``select(family, problem)``).
    """

    def select_matmul(self, m: int, k: int, n: int, batch: int) -> MatmulConfig: ...

    def select_attention(self, sq: int, skv: int, d: int) -> AttentionConfig: ...

    def select_wkv(self, s: int, hd: int) -> WkvConfig: ...

    def select_ssm(self, s: int, d: int) -> SsmConfig: ...


@dataclasses.dataclass
class FixedPolicy:
    """Single-kernel-per-family baseline (what an untuned library ships)."""

    matmul_config: MatmulConfig = DEFAULT_CONFIG
    attention_config: AttentionConfig = DEFAULT_ATTN_CONFIG
    wkv_config: WkvConfig = DEFAULT_WKV_CONFIG
    ssm_config: SsmConfig = DEFAULT_SSM_CONFIG

    def select_matmul(self, m, k, n, batch):
        return self.matmul_config

    def select_attention(self, sq, skv, d):
        return self.attention_config

    def select_wkv(self, s, hd):
        return self.wkv_config

    def select_ssm(self, s, d):
        return self.ssm_config


DEFAULT_LOG_CAP = 4096
DEFAULT_SHAPE_CACHE_CAP = 1024


class _Shared:
    """Process-global policy registry (DESIGN.md §8 hot-swap contract).

    Everything a policy swap must change together — the live policy, the
    per-device registry, the active/requested markers, and the selection log
    — lives here, mutated only under ``lock`` with an ``epoch`` bump.
    Dispatching threads keep their own shape caches (:class:`_Local`) and
    re-sync them lazily: on the first selection after a swap, a thread sees
    the stale epoch, drops its cache, and adopts the new policy atomically,
    so a cached config from the old policy can never be served as if the new
    policy had chosen it.
    """

    def __init__(self):
        self.lock = threading.RLock()
        self.epoch: int = 0
        self.policy: KernelPolicy | None = None
        self.device_policies: dict[str, KernelPolicy] = {}
        self.active_device: str | None = None
        self.requested_device: str | None = None
        self.use_pallas: bool = False  # CPU host default: XLA dot
        self.interpret: bool = False
        self.log_enabled: bool = False
        self.selection_log: deque[tuple] = deque(maxlen=DEFAULT_LOG_CAP)


class _Local(threading.local):
    """Per-thread dispatch fast path: the LRU shape cache and its counters.

    ``family_stats`` tracks hit/miss per kernel family — cache keys are
    family-qualified (``(op, *problem)``) so an ssm ``(s, d)`` problem can
    never alias a matmul ``(m, k)`` tuple, and the counters let operators see
    which family's traffic the memo is actually absorbing.
    """

    def __init__(self):
        self.epoch: int = -1  # never matches: first dispatch syncs
        self.policy: KernelPolicy | None = None
        self.shape_cache: OrderedDict[tuple, object] = OrderedDict()
        self.shape_cache_cap: int = DEFAULT_SHAPE_CACHE_CAP
        self.cache_hits: int = 0
        self.cache_misses: int = 0
        self.family_stats: dict[str, list] = {}  # op -> [hits, misses]
        # family -> resolved policy hook (or None): depends only on the live
        # policy, so it lives and dies with the shape cache (epoch sync).
        self.hook_cache: dict[str, object] = {}


_shared = _Shared()
_local = _Local()
_MISS = object()


def _policy() -> KernelPolicy | None:
    """The live policy, syncing this thread's view of a hot swap.

    The epoch check makes the swap atomic from the dispatcher's side: the
    policy reference and the shape-cache invalidation are taken together
    under the registry lock, so a selection either runs fully against the
    old policy (an in-flight request — fine) or fully against the new one.
    """
    if _local.epoch != _shared.epoch:
        with _shared.lock:
            _local.policy = _shared.policy
            _local.epoch = _shared.epoch
        _local.shape_cache.clear()
        _local.cache_hits = 0
        _local.cache_misses = 0
        _local.family_stats = {}
        _local.hook_cache = {}
    return _local.policy


def policy_epoch() -> int:
    """Monotonic counter bumped by every policy mutation (swap observability)."""
    return _shared.epoch


def set_kernel_policy(policy: KernelPolicy | None) -> None:
    """Install ``policy`` directly (manual single-device path).

    Clears the active-device marker: a manually installed policy is not tied
    to the registry, so later ``set_kernel_policy_for_device`` calls won't
    silently replace it.
    """
    with _shared.lock:
        _shared.policy = policy
        _shared.active_device = None
        _shared.requested_device = None
        _shared.epoch += 1
    clear_shape_cache()


def get_kernel_policy() -> KernelPolicy | None:
    return _policy()


# ---------------------------------------------------------------------------
# per-device policy registry (the multi-device DeploymentBundle path)
# ---------------------------------------------------------------------------
def set_kernel_policy_for_device(device: str, policy: KernelPolicy | None) -> None:
    """Register (or with ``None``, drop) the policy tuned for one device.

    Registration alone activates nothing; ``activate_device`` picks which
    registered policy serves this host.  If ``device`` is the currently
    active one, the live policy is refreshed in place — this is the
    zero-downtime hot-swap primitive the retune loop uses: the registry,
    the live policy, and the epoch bump happen atomically under the lock,
    and every dispatching thread invalidates its shape cache on its next
    selection (in-flight selections complete against the old policy).
    """
    from repro.core.devices import canonical_device_name

    name = canonical_device_name(device)
    with _shared.lock:
        if policy is None:
            _shared.device_policies.pop(name, None)
            if name == _shared.active_device:
                # Dropping the live policy deactivates it — a stale marker
                # would report an active device while dispatch runs unpoliced.
                _shared.policy = None
                _shared.active_device = None
                _shared.requested_device = None
                _shared.epoch += 1
        else:
            _shared.device_policies[name] = policy
            if name == _shared.active_device:
                _shared.policy = policy
                _shared.epoch += 1
    # No explicit cache clear: the epoch bump (live-device cases only) makes
    # every thread — this one included — drop its shape cache on the next
    # selection; registering an inactive device leaves warm caches alone.


def device_policies() -> dict[str, KernelPolicy]:
    """Snapshot of the registered per-device policies (name -> policy)."""
    with _shared.lock:
        return dict(_shared.device_policies)


def active_device() -> str | None:
    """Canonical name of the device whose registered policy is live."""
    return _shared.active_device


def device_resolution() -> tuple[str | None, str | None]:
    """(requested, resolved) device names from the last ``activate_device``.

    Differing entries mean this host is untuned and serving a nearest-sibling
    fallback artifact; ``(None, None)`` means no registry activation is live.
    """
    with _shared.lock:
        return (_shared.requested_device, _shared.active_device)


def activate_device(device: str | None = None, *, strict: bool = False) -> str:
    """Make the registered policy for ``device`` the live ``KernelPolicy``.

    ``device=None`` detects the host (``REPRO_DEVICE`` override first).  An
    unregistered device resolves to the nearest registered sibling via
    ``repro.core.devices.resolve_device``; ``strict=True`` raises instead of
    crossing platform families.  Returns the resolved canonical name.
    """
    from repro.core.devices import canonical_device_name, detect_device, resolve_device

    requested = canonical_device_name(device) if device is not None else detect_device()
    with _shared.lock:
        resolved = resolve_device(requested, list(_shared.device_policies), strict=strict)
        if resolved is None:
            raise KeyError(
                f"no kernel policy registered for device {requested!r} "
                f"(registered: {sorted(_shared.device_policies)})"
            )
        _shared.policy = _shared.device_policies[resolved]
        _shared.active_device = resolved
        _shared.requested_device = requested
        _shared.epoch += 1
    clear_shape_cache()
    return resolved


def set_pallas_enabled(enabled: bool, *, interpret: bool = False) -> None:
    """Route matmuls through the Pallas kernels (interpret=True on CPU)."""
    _shared.use_pallas = enabled
    _shared.interpret = interpret


# ---------------------------------------------------------------------------
# selection log (opt-in, ring buffer — long serving runs must not leak host
# memory recording every trace-time decision).  The log is process-global:
# the retune loop's telemetry reader may run on a different thread than the
# dispatches it observes (deque append/iterate are GIL-atomic).
# ---------------------------------------------------------------------------
def set_selection_logging(enabled: bool, *, cap: int | None = None) -> None:
    """Opt in/out of recording dispatch decisions; ``cap`` bounds the buffer."""
    with _shared.lock:
        _shared.log_enabled = enabled
        if cap is not None:
            _shared.selection_log = deque(_shared.selection_log, maxlen=max(int(cap), 1))


def selection_logging_enabled() -> bool:
    return _shared.log_enabled


def selection_log() -> list[tuple]:
    """Trace-time dispatch decisions (op, problem, chosen config).

    Empty unless ``set_selection_logging(True)`` was called; at most the
    newest ``cap`` entries are retained.
    """
    return list(_shared.selection_log)


def clear_selection_log() -> None:
    _shared.selection_log.clear()


# ---------------------------------------------------------------------------
# shape-memoized dispatch (the serving fast path)
# ---------------------------------------------------------------------------
def clear_device_policies() -> None:
    """Drop every registered per-device policy, deactivating the live one.

    A policy that was activated from the registry is uninstalled with it
    (the marker and the live policy must never disagree); a policy installed
    manually via ``set_kernel_policy`` is not registry-owned and survives.
    """
    with _shared.lock:
        _shared.device_policies.clear()
        if _shared.active_device is not None:
            _shared.policy = None
        _shared.active_device = None
        _shared.requested_device = None
        _shared.epoch += 1
    clear_shape_cache()


def clear_shape_cache() -> None:
    """Drop this thread's shape cache (other threads re-sync on epoch bump)."""
    _local.shape_cache.clear()
    _local.cache_hits = 0
    _local.cache_misses = 0
    _local.family_stats = {}
    _local.hook_cache = {}


def set_shape_cache_cap(cap: int) -> None:
    """Bound the dispatch cache; oldest (LRU) shape keys are evicted."""
    _local.shape_cache_cap = max(int(cap), 1)
    while len(_local.shape_cache) > _local.shape_cache_cap:
        _local.shape_cache.popitem(last=False)


def shape_cache_stats() -> dict:
    """Hit/miss counters for the dispatch shape cache (reset on policy swap).

    ``per_family`` breaks the counters (and resident cache entries) down by
    kernel family — the keys are the family-qualified ``op`` names of the
    selection log.
    """
    sizes: dict[str, int] = {}
    for key in _local.shape_cache:
        sizes[key[0]] = sizes.get(key[0], 0) + 1
    per_family = {
        op: {"hits": hm[0], "misses": hm[1], "size": sizes.get(op, 0)}
        for op, hm in sorted(_local.family_stats.items())
    }
    for op, size in sorted(sizes.items()):  # entries inherited before any stat
        per_family.setdefault(op, {"hits": 0, "misses": 0, "size": size})
    return {
        "hits": _local.cache_hits,
        "misses": _local.cache_misses,
        "size": len(_local.shape_cache),
        "cap": _local.shape_cache_cap,
        "per_family": per_family,
    }


def _select(op: str, problem: tuple, policy: KernelPolicy, select_fn):
    """Policy consultation with LRU shape memoization.

    Repeated traces of the same problem shape (the serving engine's
    prefill/decode retraces) hit a dict lookup instead of featurize+predict.
    Policies whose selections are not a pure function of the shape (e.g. the
    exploring ``OnlinePolicy``) opt out via ``cacheable = False``.

    ``policy`` is the reference the caller already synced via :func:`_policy`
    — passing it through keeps one selection pinned to one policy even if a
    hot swap lands mid-call.
    """
    cacheable = bool(getattr(policy, "cacheable", True))
    key = (op, *problem)
    if cacheable:
        cfg = _local.shape_cache.get(key, _MISS)
        if cfg is not _MISS:
            _local.cache_hits += 1
            _local.family_stats.setdefault(op, [0, 0])[0] += 1
            _local.shape_cache.move_to_end(key)
            if _shared.log_enabled:
                _shared.selection_log.append((op, problem, cfg))
            return cfg
    cfg = select_fn()
    if cacheable:
        _local.cache_misses += 1
        _local.family_stats.setdefault(op, [0, 0])[1] += 1
        _local.shape_cache[key] = cfg
        if len(_local.shape_cache) > _local.shape_cache_cap:
            _local.shape_cache.popitem(last=False)
    if _shared.log_enabled:
        _shared.selection_log.append((op, problem, cfg))
    return cfg


def _policy_hook(pol: KernelPolicy, family: str):
    """Resolve the policy's selection callable for ``family`` via the registry.

    Replaces the old duck-typed ``hasattr(pol, "select_wkv")`` hooks: the
    method name comes from the family's declared ``policy_attr``, and a
    policy may instead expose a generic ``select(family, problem)``.  Returns
    a ``hook(problem)`` callable, or ``None`` when the policy covers neither
    (the op runs its default config).  Resolution depends only on (policy,
    family), so :func:`select_kernel_config` memoizes it per thread — the
    shape-cache fast path never pays registry lookup or ``getattr``.
    """
    from repro.core.families import get_family

    meth = getattr(pol, get_family(family).policy_attr, None)
    if meth is not None:
        return lambda problem: meth(*problem)
    generic = getattr(pol, "select", None)
    if generic is not None:
        return lambda problem: generic(family, problem)
    return None


def select_kernel_config(family: str, problem: tuple):
    """Generic launcher-side selection for any registered family.

    Shape-memoized under the family-qualified key, logged to the selection
    log as ``(family, problem, config)``; ``None`` when no policy is
    installed or the policy does not cover this family.
    """
    pol = _policy()  # syncs _local (and drops stale hook/shape caches)
    if pol is None:
        return None
    hook = _local.hook_cache.get(family, _MISS)
    if hook is _MISS:
        hook = _policy_hook(pol, family)
        _local.hook_cache[family] = hook
    if hook is None:
        return None
    problem = tuple(problem)
    return _select(family, problem, pol, lambda: hook(problem))


def select_matmul_config(m: int, k: int, n: int, batch: int = 1) -> MatmulConfig | None:
    """The launcher-side selection path on its own (what ``matmul`` runs at
    trace time); ``None`` when no policy is installed."""
    pol = _policy()
    if pol is None:
        return None
    return _select("matmul", (m, k, n, batch), pol, lambda: pol.select_matmul(m, k, n, batch))


def select_wkv_config(s: int, hd: int) -> WkvConfig | None:
    """Launcher-side WKV selection (what ``wkv`` runs at trace time)."""
    return select_kernel_config("wkv", (s, hd))


def select_ssm_config(s: int, d: int) -> SsmConfig | None:
    """Launcher-side selective-scan selection (what ``ssm_scan`` runs)."""
    return select_kernel_config("ssm_scan", (s, d))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------
def matmul(lhs: jax.Array, rhs: jax.Array, *, out_dtype=None, config: MatmulConfig | None = None) -> jax.Array:
    """``lhs @ rhs`` with ML-guided kernel selection.

    ``lhs``: (..., k) — leading dims are flattened into the GEMM M dimension.
    ``rhs``: (k, n).
    """
    if rhs.ndim != 2:
        raise ValueError(f"rhs must be 2-D, got {rhs.shape}")
    *lead, k = lhs.shape
    n = rhs.shape[1]
    # Featurize with the tuning dataset's (m, k, n, batch) convention: the
    # trailing lead dim is the GEMM M, everything before it is the repeated
    # batch — a (B, S, D) activation is B GEMMs of (S, D), not one (B*S, D).
    m = lead[-1] if lead else 1
    batch = 1
    for d in lead[:-1]:
        batch *= d
    if config is None:
        config = select_matmul_config(m, k, n, batch)
    if not _shared.use_pallas:
        out = jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
        return out.astype(out_dtype or lhs.dtype)
    lhs2 = lhs.reshape(m * batch, k)
    out = matmul_pallas(lhs2, rhs, config or DEFAULT_CONFIG, out_dtype=out_dtype, interpret=_shared.interpret)
    return out.reshape(*lead, n)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    config: AttentionConfig | None = None,
) -> jax.Array:
    """Multi-head attention: q (..., sq, d), k/v (..., skv, d).

    Leading dims (batch, heads) are vmapped over the single-head kernel.
    """
    sq, d = q.shape[-2:]
    skv = k.shape[-2]
    pol = _policy()
    if config is None and pol is not None:
        config = _select("attention", (sq, skv, d), pol, lambda: pol.select_attention(sq, skv, d))
    if not _shared.use_pallas:
        fn = lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=causal, scale=scale)
    else:
        cfg = config or DEFAULT_ATTN_CONFIG
        fn = lambda q_, k_, v_: flash_attention_pallas(
            q_, k_, v_, cfg, causal=causal, scale=scale, interpret=_shared.interpret
        )
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)


# ---------------------------------------------------------------------------
# wkv (RWKV6 recurrence)
# ---------------------------------------------------------------------------
def wkv(r, k, v, logw, u, state=None, *, config: WkvConfig | None = None):
    """Chunked WKV: r/k/v/logw (B, S, H, hd); u (H, hd); state (B, H, hd, hd).

    Returns (o (B, S, H, hd) f32, final_state).  Dispatches to the Pallas
    kernel when enabled; otherwise the jnp reference (identical math).
    """
    b, s, h, hd = r.shape
    if config is None:
        config = select_wkv_config(s, hd)
    if not _shared.use_pallas:
        from .ref import wkv_ref

        return wkv_ref(r, k, v, logw, u, state)
    if state is None:
        import jax.numpy as _jnp

        state = _jnp.zeros((b, h, hd, hd), _jnp.float32)
    cfg = config or DEFAULT_WKV_CONFIG
    one = lambda rr, kk, vv, ww, uu, ss: wkv_pallas(
        rr, kk, vv, ww, uu, ss, cfg, interpret=_shared.interpret
    )
    fn = jax.vmap(jax.vmap(one, in_axes=(1, 1, 1, 1, 0, 0)), in_axes=(0, 0, 0, 0, None, 0))
    o, s_out = fn(r, k, v, logw, u, state)
    return o.transpose(0, 2, 1, 3), s_out  # (B,H,S,hd) -> (B,S,H,hd)


# ---------------------------------------------------------------------------
# selective-SSM scan (Mamba / Hymba recurrence)
# ---------------------------------------------------------------------------
def ssm_scan(dtx, dta, b, v_c, state=None, *, config: SsmConfig | None = None):
    """Fused selective-SSM scan: dtx (B,S,d); dta (B,S,d,N); b/v_c (B,S,N).

    Returns (y (B,S,d) f32, final_state (B,d,N) f32).  Pallas path keeps the
    (d, N) state in VMEM (no (B,S,d,N) HBM materialization); jnp path is the
    associative-scan oracle.
    """
    if config is None:
        config = select_ssm_config(dtx.shape[1], dtx.shape[2])
    if not _shared.use_pallas:
        from .ref import ssm_scan_ref

        return ssm_scan_ref(dtx, dta, b, v_c, state)
    cfg = config or DEFAULT_SSM_CONFIG
    one = lambda x_, a_, b_, c_, s_: ssm_scan_pallas(
        x_, a_, b_, c_, s_, cfg, interpret=_shared.interpret
    )
    if state is None:
        import jax.numpy as _jnp

        bsz, _, d = dtx.shape
        state = _jnp.zeros((bsz, d, b.shape[-1]), _jnp.float32)
    return jax.vmap(one)(dtx, dta, b, v_c, state)
