"""Jit'd kernel entry points + the runtime kernel-selection hook (paper §5).

This is the "kernel launcher" of the paper: every matmul in the framework
routes through :func:`matmul`, which consults the installed
:class:`KernelPolicy` to pick one of the *deployed* kernel configurations for
the problem size at trace time (JAX shapes are static, so trace time is the
TPU-native "runtime" — see DESIGN.md §2).

Selection state lives on an explicit :class:`~repro.core.runtime.KernelRuntime`
(DESIGN.md §10): dispatch consults the innermost runtime activated on the
calling thread (``with rt.activate(): ...``), falling back to the process-wide
default runtime.  The module-level mutators below
(``set_kernel_policy`` & co.) are **deprecated** thin shims over that default
runtime — byte-identical selections, kept for migration; see README's
old→new map.  New code should hold a ``KernelRuntime`` and call its methods.

A policy is produced by ``repro.core.tuner`` from benchmark data.  With no
policy installed (or on hosts without a TPU), the op falls back to XLA's
``jnp.dot`` — numerically identical to the Pallas path (same f32
accumulation), which the kernel tests assert.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Protocol

import jax
import jax.numpy as jnp

from repro.core.faults import (
    GUARDED_EXCEPTIONS,
    FaultPlan,
    NonFiniteOutputError,
    incident,
)
from repro.core.runtime import (
    DEFAULT_LOG_CAP,
    DEFAULT_SHAPE_CACHE_CAP,
    current_runtime,
)

from .attention import DEFAULT_ATTN_CONFIG, AttentionConfig, flash_attention_pallas
from .matmul import DEFAULT_CONFIG, MatmulConfig, matmul_pallas
from .ref import flash_attention_ref
from .ssm import DEFAULT_SSM_CONFIG, SsmConfig, ssm_scan_pallas
from .wkv import DEFAULT_WKV_CONFIG, WkvConfig, wkv_pallas

__all__ = [
    # dispatch entry points (the real ops API)
    "KernelPolicy",
    "FixedPolicy",
    "attention",
    "matmul",
    "ssm_scan",
    "wkv",
    # launcher-side selection helpers (route through the current runtime)
    "select_kernel_config",
    "select_matmul_config",
    "select_ssm_config",
    "select_wkv_config",
    # runtime-state readers (current-runtime passthroughs)
    "active_device",
    "device_policies",
    "device_resolution",
    "get_kernel_policy",
    "policy_epoch",
    "selection_log",
    "selection_logging_enabled",
    "shape_cache_stats",
    # deprecated global mutators (shims over the default runtime)
    "activate_device",
    "clear_device_policies",
    "clear_selection_log",
    "clear_shape_cache",
    "set_kernel_policy",
    "set_kernel_policy_for_device",
    "set_pallas_enabled",
    "set_selection_logging",
    "set_shape_cache_cap",
]


class KernelPolicy(Protocol):
    """Maps a kernel-family problem to the deployed config that should run it.

    One ``select_<family>`` hook per registered family
    (``repro.core.families``); the runtime resolves the hook generically via
    the registry's ``policy_attr``, so a policy implementing only a subset
    keeps working — unimplemented families fall back to their default config
    (unless the policy exposes a generic ``select(family, problem)``).

    A policy may additionally expose ``select_for_objective(family, problem,
    objective)``; when the runtime carries an active
    :class:`~repro.core.runtime.Objective` (SLO mode — a latency target
    and/or a ``prefill_chunk_tokens`` work-granularity hint from the serving
    tier's chunked prefill), that hook is consulted first so latency-biased
    configs can override the throughput-tuned default for the same shape.
    """

    def select_matmul(self, m: int, k: int, n: int, batch: int) -> MatmulConfig: ...

    def select_attention(self, sq: int, skv: int, d: int) -> AttentionConfig: ...

    def select_wkv(self, s: int, hd: int) -> WkvConfig: ...

    def select_ssm(self, s: int, d: int) -> SsmConfig: ...


@dataclasses.dataclass
class FixedPolicy:
    """Single-kernel-per-family baseline (what an untuned library ships)."""

    matmul_config: MatmulConfig = DEFAULT_CONFIG
    attention_config: AttentionConfig = DEFAULT_ATTN_CONFIG
    wkv_config: WkvConfig = DEFAULT_WKV_CONFIG
    ssm_config: SsmConfig = DEFAULT_SSM_CONFIG

    def select_matmul(self, m, k, n, batch):
        return self.matmul_config

    def select_attention(self, sq, skv, d):
        return self.attention_config

    def select_wkv(self, s, hd):
        return self.wkv_config

    def select_ssm(self, s, d):
        return self.ssm_config


# ---------------------------------------------------------------------------
# deprecated module-level API: thin shims over the current (default) runtime
# ---------------------------------------------------------------------------
def _warn_global(old: str, new: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{old}() mutates shared global runtime state and is "
        f"deprecated; hold a repro.KernelRuntime and call {new} instead "
        f"(see the migration map in README.md)",
        DeprecationWarning,
        stacklevel=3,
    )


def set_kernel_policy(policy: KernelPolicy | None) -> None:
    """Deprecated shim: ``KernelRuntime.install(policy)`` on the current runtime."""
    _warn_global("set_kernel_policy", "KernelRuntime.install(policy)")
    current_runtime().install(policy)


def get_kernel_policy() -> KernelPolicy | None:
    return current_runtime().policy()


def set_kernel_policy_for_device(device: str, policy: KernelPolicy | None) -> None:
    """Deprecated shim: ``KernelRuntime.install_for_device(device, policy)``."""
    _warn_global(
        "set_kernel_policy_for_device", "KernelRuntime.install_for_device(device, policy)"
    )
    current_runtime().install_for_device(device, policy)


def device_policies() -> dict[str, KernelPolicy]:
    """Registered per-device policies of the current runtime (name -> policy)."""
    return current_runtime().device_policies()


def active_device() -> str | None:
    """Canonical name of the current runtime's live registered device."""
    return current_runtime().active_device()


def device_resolution() -> tuple[str | None, str | None]:
    """(requested, resolved) device names from the last device activation."""
    return current_runtime().device_resolution()


def activate_device(device: str | None = None, *, strict: bool = False) -> str:
    """Deprecated shim: ``KernelRuntime.activate_device(device)``."""
    _warn_global("activate_device", "KernelRuntime.activate_device(device)")
    return current_runtime().activate_device(device, strict=strict)


def clear_device_policies() -> None:
    """Deprecated shim: ``KernelRuntime.clear_device_policies()``."""
    _warn_global("clear_device_policies", "KernelRuntime.clear_device_policies()")
    current_runtime().clear_device_policies()


def set_pallas_enabled(enabled: bool, *, interpret: bool = False) -> None:
    """Deprecated shim: ``KernelRuntime.set_pallas_enabled(enabled)``."""
    _warn_global("set_pallas_enabled", "KernelRuntime.set_pallas_enabled(enabled)")
    current_runtime().set_pallas_enabled(enabled, interpret=interpret)


def set_selection_logging(enabled: bool, *, cap: int | None = None) -> None:
    """Deprecated shim: ``KernelRuntime.set_selection_logging(enabled)``."""
    _warn_global("set_selection_logging", "KernelRuntime.set_selection_logging(enabled)")
    current_runtime().set_selection_logging(enabled, cap=cap)


def selection_logging_enabled() -> bool:
    return current_runtime().selection_logging_enabled()


def selection_log() -> list[tuple]:
    """Trace-time dispatch decisions of the current runtime (op, problem, config)."""
    return current_runtime().selection_log()


def clear_selection_log() -> None:
    """Deprecated shim: ``KernelRuntime.clear_selection_log()``."""
    _warn_global("clear_selection_log", "KernelRuntime.clear_selection_log()")
    current_runtime().clear_selection_log()


def clear_shape_cache() -> None:
    """Deprecated shim: ``KernelRuntime.clear_shape_cache()``."""
    _warn_global("clear_shape_cache", "KernelRuntime.clear_shape_cache()")
    current_runtime().clear_shape_cache()


def set_shape_cache_cap(cap: int) -> None:
    """Deprecated shim: ``KernelRuntime.set_shape_cache_cap(cap)``."""
    _warn_global("set_shape_cache_cap", "KernelRuntime.set_shape_cache_cap(cap)")
    current_runtime().set_shape_cache_cap(cap)


def shape_cache_stats() -> dict:
    """Dispatch shape-cache counters of the current runtime (this thread)."""
    return current_runtime().shape_cache_stats()


def policy_epoch() -> int:
    """Policy epoch of the current runtime (swap observability)."""
    return current_runtime().policy_epoch()


# ---------------------------------------------------------------------------
# launcher-side selection (used by the ops below; also callable directly)
# ---------------------------------------------------------------------------
def select_kernel_config(family: str, problem: tuple):
    """Generic launcher-side selection against the current runtime.

    Shape-memoized under the family-qualified key, logged to the runtime's
    selection log as ``(family, problem, config)``; ``None`` when no policy
    is installed or the policy does not cover this family.
    """
    return current_runtime().select_config(family, problem)


def select_matmul_config(m: int, k: int, n: int, batch: int = 1) -> MatmulConfig | None:
    """The launcher-side selection path on its own (what ``matmul`` runs at
    trace time); ``None`` when no policy is installed."""
    return current_runtime().select_matmul_config(m, k, n, batch)


def select_wkv_config(s: int, hd: int) -> WkvConfig | None:
    """Launcher-side WKV selection (what ``wkv`` runs at trace time)."""
    return current_runtime().select_wkv_config(s, hd)


def select_ssm_config(s: int, d: int) -> SsmConfig | None:
    """Launcher-side selective-scan selection (what ``ssm_scan`` runs)."""
    return current_runtime().select_ssm_config(s, d)


# ---------------------------------------------------------------------------
# guarded execution (DESIGN.md §11: fault containment at the dispatch site)
# ---------------------------------------------------------------------------
_TRACER = getattr(jax.core, "Tracer", None)


def _raise_non_finite(family: str, out) -> None:
    """Raise :class:`NonFiniteOutputError` if a concrete output leaf has NaN/Inf.

    Tracer leaves are skipped — inside a ``jit`` trace there is no value to
    inspect (validation then happens on the eager/chaos path, which is where
    fault plans run).
    """
    leaves = out if isinstance(out, tuple) else (out,)
    for leaf in leaves:
        if _TRACER is not None and isinstance(leaf, _TRACER):
            return
        if not hasattr(leaf, "dtype") or not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        if not bool(jnp.isfinite(leaf).all()):
            raise NonFiniteOutputError(f"{family} kernel call produced non-finite output")


def _guarded_call(rt, family: str, config, run_tuned, run_ref):
    """Execute one kernel call under the fault guard.

    Happy-path cost is one try frame plus two attribute checks (the perf gate
    bounds it at <5%, ``guarded_dispatch_overhead`` in bench_selection).  On
    an injected or real compile/lowering/runtime failure — or a non-finite
    concrete output while validation is armed — the guard records the
    incident, quarantines ``(device, family, config)`` behind the runtime's
    circuit breaker, and re-runs the reference path (which a second failure
    would escape from: a broken oracle is a caller-visible bug, not a
    containment case).  A successful run of a half-open breaker's probe
    config closes the breaker (absolve).
    """
    plan = rt.fault_plan
    try:
        spec = None
        if plan is not None:
            key = config.name() if config is not None and hasattr(config, "name") else ""
            spec = plan.raise_if(f"dispatch.{family}", key)
        out = run_tuned()
        if spec is not None and spec.kind in ("nan", "inf"):
            out = FaultPlan.corrupt_array(spec, out)
        if plan is not None or rt._validate_outputs:
            _raise_non_finite(family, out)
        if spec is not None and spec.kind == "latency":
            rt.record_incident(incident(
                f"dispatch.{family}", family, config, "injected latency spike",
                "latency_spike", device=rt.active_device()))
        if config is not None and rt._quarantine and rt.probing(family, config):
            rt.absolve(family, config)
            rt.record_incident(incident(
                f"dispatch.{family}", family, config, "re-probe succeeded",
                "absolved", device=rt.active_device()))
        return out
    except GUARDED_EXCEPTIONS as e:
        if config is not None:
            rt.quarantine_config(family, config, e)
        rt.record_incident(incident(
            f"dispatch.{family}", family, config, e,
            "quarantined" if config is not None else "fallback_ref",
            device=rt.active_device()))
        return run_ref()


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------
def matmul(lhs: jax.Array, rhs: jax.Array, *, out_dtype=None, config: MatmulConfig | None = None) -> jax.Array:
    """``lhs @ rhs`` with ML-guided kernel selection.

    ``lhs``: (..., k) — leading dims are flattened into the GEMM M dimension.
    ``rhs``: (k, n).
    """
    if rhs.ndim != 2:
        raise ValueError(f"rhs must be 2-D, got {rhs.shape}")
    rt = current_runtime()
    *lead, k = lhs.shape
    n = rhs.shape[1]
    # Featurize with the tuning dataset's (m, k, n, batch) convention: the
    # trailing lead dim is the GEMM M, everything before it is the repeated
    # batch — a (B, S, D) activation is B GEMMs of (S, D), not one (B*S, D).
    m = lead[-1] if lead else 1
    batch = 1
    for d in lead[:-1]:
        batch *= d
    if config is None:
        config = rt.select_matmul_config(m, k, n, batch)
    run_ref = lambda: jnp.dot(lhs, rhs, preferred_element_type=jnp.float32).astype(
        out_dtype or lhs.dtype
    )
    if not rt.use_pallas:
        return _guarded_call(rt, "matmul", config, run_ref, run_ref)
    lhs2 = lhs.reshape(m * batch, k)
    run_tuned = lambda: matmul_pallas(
        lhs2, rhs, config or DEFAULT_CONFIG, out_dtype=out_dtype, interpret=rt.interpret
    ).reshape(*lead, n)
    return _guarded_call(rt, "matmul", config, run_tuned, run_ref)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    config: AttentionConfig | None = None,
) -> jax.Array:
    """Multi-head attention: q (..., sq, d), k/v (..., skv, d).

    Leading dims (batch, heads) are vmapped over the single-head kernel.
    """
    sq, d = q.shape[-2:]
    skv = k.shape[-2]
    rt = current_runtime()
    if config is None:
        config = rt.select_attention_config(sq, skv, d)

    def _apply(fn):
        for _ in range(q.ndim - 2):
            fn = jax.vmap(fn)
        return fn(q, k, v)

    ref_fn = lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=causal, scale=scale)
    if not rt.use_pallas:
        run_ref = lambda: _apply(ref_fn)
        return _guarded_call(rt, "attention", config, run_ref, run_ref)
    cfg = config or DEFAULT_ATTN_CONFIG
    tuned_fn = lambda q_, k_, v_: flash_attention_pallas(
        q_, k_, v_, cfg, causal=causal, scale=scale, interpret=rt.interpret
    )
    return _guarded_call(
        rt, "attention", config, lambda: _apply(tuned_fn), lambda: _apply(ref_fn)
    )


# ---------------------------------------------------------------------------
# wkv (RWKV6 recurrence)
# ---------------------------------------------------------------------------
def wkv(r, k, v, logw, u, state=None, *, config: WkvConfig | None = None):
    """Chunked WKV: r/k/v/logw (B, S, H, hd); u (H, hd); state (B, H, hd, hd).

    Returns (o (B, S, H, hd) f32, final_state).  Dispatches to the Pallas
    kernel when enabled; otherwise the jnp reference (identical math).
    """
    b, s, h, hd = r.shape
    rt = current_runtime()
    if config is None:
        config = rt.select_wkv_config(s, hd)
    from .ref import wkv_ref

    run_ref = lambda: wkv_ref(r, k, v, logw, u, state)
    if not rt.use_pallas:
        return _guarded_call(rt, "wkv", config, run_ref, run_ref)
    if state is None:
        import jax.numpy as _jnp

        state = _jnp.zeros((b, h, hd, hd), _jnp.float32)
    cfg = config or DEFAULT_WKV_CONFIG

    def run_tuned():
        one = lambda rr, kk, vv, ww, uu, ss: wkv_pallas(
            rr, kk, vv, ww, uu, ss, cfg, interpret=rt.interpret
        )
        fn = jax.vmap(jax.vmap(one, in_axes=(1, 1, 1, 1, 0, 0)), in_axes=(0, 0, 0, 0, None, 0))
        o, s_out = fn(r, k, v, logw, u, state)
        return o.transpose(0, 2, 1, 3), s_out  # (B,H,S,hd) -> (B,S,H,hd)

    return _guarded_call(rt, "wkv", config, run_tuned, run_ref)


# ---------------------------------------------------------------------------
# selective-SSM scan (Mamba / Hymba recurrence)
# ---------------------------------------------------------------------------
def ssm_scan(dtx, dta, b, v_c, state=None, *, config: SsmConfig | None = None):
    """Fused selective-SSM scan: dtx (B,S,d); dta (B,S,d,N); b/v_c (B,S,N).

    Returns (y (B,S,d) f32, final_state (B,d,N) f32).  Pallas path keeps the
    (d, N) state in VMEM (no (B,S,d,N) HBM materialization); jnp path is the
    associative-scan oracle.
    """
    rt = current_runtime()
    if config is None:
        config = rt.select_ssm_config(dtx.shape[1], dtx.shape[2])
    from .ref import ssm_scan_ref

    run_ref = lambda: ssm_scan_ref(dtx, dta, b, v_c, state)
    if not rt.use_pallas:
        return _guarded_call(rt, "ssm_scan", config, run_ref, run_ref)
    cfg = config or DEFAULT_SSM_CONFIG
    if state is None:
        import jax.numpy as _jnp

        bsz, _, d = dtx.shape
        state = _jnp.zeros((bsz, d, b.shape[-1]), _jnp.float32)

    def run_tuned():
        one = lambda x_, a_, b_, c_, s_: ssm_scan_pallas(
            x_, a_, b_, c_, s_, cfg, interpret=rt.interpret
        )
        return jax.vmap(one)(dtx, dta, b, v_c, state)

    return _guarded_call(rt, "ssm_scan", config, run_tuned, run_ref)
