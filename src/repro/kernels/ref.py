"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(lhs: jax.Array, rhs: jax.Array, *, out_dtype=None) -> jax.Array:
    """Oracle for kernels/matmul.py: f32-accumulated 2-D matmul."""
    out_dtype = out_dtype or lhs.dtype
    return jnp.dot(lhs, rhs, preferred_element_type=jnp.float32).astype(out_dtype)


def wkv_ref(r, k, v, logw, u, state=None):
    """Oracle for kernels/wkv.py: the chunked-WKV jnp reference.

    r/k/v/logw: (B, S, H, hd); u: (H, hd); state: (B, H, hd, hd) or None.
    """
    from repro.models.rwkv import wkv_chunked

    return wkv_chunked(r, k, v, logw, u, state)


def ssm_scan_ref(dtx, dta, b, c, state=None):
    """Oracle for kernels/ssm.py: associative-scan selective SSM.

    dtx (B,S,d); dta (B,S,d,N); b/c (B,S,N); state (B,d,N) or None.
    Returns (y (B,S,d) f32, final_state (B,d,N) f32).
    """
    bsz, s, d = dtx.shape
    n = b.shape[-1]
    abar = jnp.exp(dta.astype(jnp.float32))
    bx = dtx.astype(jnp.float32)[..., None] * b.astype(jnp.float32)[:, :, None, :]
    if state is not None:
        # fold the initial state in as a virtual step 0
        abar = jnp.concatenate([jnp.ones((bsz, 1, d, n), jnp.float32), abar], axis=1)
        bx = jnp.concatenate([state.astype(jnp.float32)[:, None], bx], axis=1)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (abar, bx), axis=1)
    if state is not None:
        h = h[:, 1:]
    y = (h * c.astype(jnp.float32)[:, :, None, :]).sum(-1)
    return y, h[:, -1]


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Oracle for kernels/attention.py.

    q: (sq, d), k/v: (skv, d) — single head; batching is vmapped by callers.
    """
    sq, d = q.shape
    skv = k.shape[0]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    logits = (q.astype(jnp.float32) * scale) @ k.astype(jnp.float32).T
    if causal:
        # Align the causal diagonal to the end (decode-style when sq < skv).
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return (probs @ v.astype(jnp.float32)).astype(q.dtype)
