"""Parameterized Pallas TPU flash attention.

Second tunable kernel family (the perf-critical op of every assigned
transformer): online-softmax attention with BlockSpec tiling over the query
and key/value sequence dimensions.

Tunable parameters (the analogue of the matmul tile space):
  * ``block_q``   — query rows per grid step (MXU rows / VMEM).
  * ``block_kv``  — key/value rows per inner step (VMEM vs revisit count).

Causal masking aligns the diagonal to the *end* of the KV sequence, so the
same kernel serves training (sq == skv), chunked prefill, and decode
(sq == 1, skv == cache length).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


@dataclasses.dataclass(frozen=True, order=True)
class AttentionConfig:
    block_q: int
    block_kv: int

    def name(self) -> str:
        return f"fa_bq{self.block_q}_bkv{self.block_kv}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "AttentionConfig":
        return AttentionConfig(**d)


_BLOCK_Q = (128, 256, 512)
_BLOCK_KV = (128, 256, 512, 1024)


@functools.cache
def attention_config_space() -> tuple[AttentionConfig, ...]:
    return tuple(AttentionConfig(bq, bkv) for bq, bkv in itertools.product(_BLOCK_Q, _BLOCK_KV))


DEFAULT_ATTN_CONFIG = AttentionConfig(block_q=256, block_kv=512)


def _flash_kernel(
    q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *, n_kv: int, causal: bool, scale: float, sq: int, skv: int
):
    kv_idx = pl.program_id(1)
    q_idx = pl.program_id(0)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale
    k = k_ref[...].astype(jnp.float32)
    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)

    bq, bkv = logits.shape
    cols = kv_idx * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = cols < skv  # padded KV columns contribute nothing
    if causal:
        # Global row/col positions; diagonal aligned to the end of KV.
        rows = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + (skv - sq)
        mask &= cols <= rows
    logits = jnp.where(mask, logits, _NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_cur = jnp.maximum(m_prev, logits.max(axis=-1))
    correction = jnp.exp(m_prev - m_cur)
    p = jnp.exp(logits - m_cur[:, None])
    l_cur = l_prev * correction + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + p @ v_ref[...].astype(jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(kv_idx == n_kv - 1)
    def _store():
        l = l_ref[:, 0]
        out_ref[...] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)[:, None]).astype(out_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    config: AttentionConfig = DEFAULT_ATTN_CONFIG,
    *,
    causal: bool = True,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Single-head flash attention: q (sq, d), k/v (skv, d) -> (sq, d)."""
    sq, d = q.shape
    skv = k.shape[0]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    bq = min(config.block_q, _round_up(sq, 8))
    bkv = min(config.block_kv, _round_up(skv, 128))
    # Pad sequences to block multiples; padded KV columns are masked off via
    # the causal/global column index test below, padded Q rows are sliced off.
    sqp, skvp = _round_up(sq, bq), _round_up(skv, bkv)
    orig_sq = sq
    if sqp != sq:
        q = jnp.pad(q, ((0, sqp - sq), (0, 0)))
    if skvp != skv:
        k = jnp.pad(k, ((0, skvp - skv), (0, 0)))
        v = jnp.pad(v, ((0, skvp - skv), (0, 0)))
    n_q = sqp // bq
    n_kv = skvp // bkv

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, causal=causal, scale=scale, sq=sq, skv=skv
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_q, n_kv),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(dimension_semantics=("parallel", "arbitrary")),
    )(q, k, v)
    if sqp != orig_sq:
        out = out[:orig_sq]
    return out


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
