"""Parameterized Pallas TPU matmul — the tunable kernel family (paper §3).

The paper's case study tunes a SYCL GEMM over tile sizes (R, A, C) and
work-group shapes, 640 configurations.  The TPU-native analogue of that
parameter space is the Pallas ``BlockSpec`` tiling:

  * ``block_m``  — output-tile rows per grid step.  Small values (8/16/32)
    under-fill the 128x128 MXU but are the right choice for tall-skinny /
    decode-GEMV problems (the paper's "tall skinny" pathology, §3.2).
  * ``block_n``  — output-tile cols (lane dimension, multiples of 128).
  * ``block_k``  — contraction-tile depth: trades VMEM footprint against
    grid-step overhead and, when ``k <= block_k`` (single k-step), unlocks
    LHS-tile reuse across the inner grid dimension.
  * ``order``    — grid iteration order ``mnk`` or ``nmk`` (which of M/N is
    the inner loop); controls which operand's tiles get revisited without
    an HBM reload (the analogue of the paper's (8,16) vs (16,8) work-groups).

Every config is a distinct compiled artifact, exactly like the paper's SPIR
blobs — hence the deployment-subset-selection problem that `repro.core`
solves.

The kernel accumulates in an f32 VMEM scratch accumulator and writes the
output tile once on the final k step (standard TPU matmul pipeline shape).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# v5e-flavoured VMEM budget used for config validity (conservative usable
# fraction; the perf model uses the same constant).
VMEM_BYTES = 48 * 1024 * 1024
_DOUBLE_BUFFER = 2  # Pallas pipelines input tiles with double buffering.


@dataclasses.dataclass(frozen=True, order=True)
class MatmulConfig:
    """One deployable kernel instantiation (a 'binary blob' in paper terms)."""

    block_m: int
    block_n: int
    block_k: int
    order: str = "mnk"  # 'mnk' (n inner) or 'nmk' (m inner); k always fastest

    def name(self) -> str:
        return f"mm_bm{self.block_m}_bn{self.block_n}_bk{self.block_k}_{self.order}"

    def vmem_bytes(self, dtype_bytes: int = 2) -> int:
        lhs = self.block_m * self.block_k * dtype_bytes
        rhs = self.block_k * self.block_n * dtype_bytes
        out = self.block_m * self.block_n * dtype_bytes
        acc = self.block_m * self.block_n * 4  # f32 accumulator scratch
        return _DOUBLE_BUFFER * (lhs + rhs + out) + acc

    def is_valid(self, dtype_bytes: int = 2) -> bool:
        if self.order not in ("mnk", "nmk"):
            return False
        if self.block_n % 128 or self.block_k % 128:
            return False  # lane dimension must be 128-aligned
        if self.block_m % 8:
            return False  # sublane alignment
        return self.vmem_bytes(dtype_bytes) <= VMEM_BYTES

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "MatmulConfig":
        return MatmulConfig(**d)


_BLOCK_M = (8, 16, 32, 64, 128, 256, 512)
_BLOCK_N = (128, 256, 512)
_BLOCK_K = (128, 256, 512, 1024, 2048)
_ORDERS = ("mnk", "nmk")


@functools.cache
def config_space() -> tuple[MatmulConfig, ...]:
    """The full tunable space (all VMEM-valid combinations)."""
    out = []
    for bm, bn, bk, order in itertools.product(_BLOCK_M, _BLOCK_N, _BLOCK_K, _ORDERS):
        cfg = MatmulConfig(bm, bn, bk, order)
        if cfg.is_valid():
            out.append(cfg)
    return tuple(out)


DEFAULT_CONFIG = MatmulConfig(block_m=128, block_n=128, block_k=512, order="mnk")


# ---------------------------------------------------------------------------
# kernel body
# ---------------------------------------------------------------------------
def _matmul_kernel(lhs_ref, rhs_ref, out_ref, acc_ref, *, n_k: int, out_dtype):
    """Grid step: accumulate lhs_block @ rhs_block into the f32 scratch."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        lhs_ref[...],
        rhs_ref[...],
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_idx == n_k - 1)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_dtype)


def matmul_pallas(
    lhs: jax.Array,
    rhs: jax.Array,
    config: MatmulConfig = DEFAULT_CONFIG,
    *,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """``lhs @ rhs`` via the parameterized Pallas kernel.

    ``lhs``: (m, k), ``rhs``: (k, n).  Blocks are padded by Pallas when the
    problem dims do not divide the block dims.
    """
    if lhs.ndim != 2 or rhs.ndim != 2:
        raise ValueError(f"matmul_pallas expects 2-D operands, got {lhs.shape} @ {rhs.shape}")
    m, k = lhs.shape
    k2, n = rhs.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {lhs.shape} @ {rhs.shape}")
    out_dtype = out_dtype or lhs.dtype
    orig_m, orig_n = m, n
    bm = min(config.block_m, _round_up(m, 8))
    bn = min(config.block_n, _round_up(n, 128))
    bk = min(config.block_k, _round_up(k, 128))
    # Zero-pad to block multiples: k-padding must be zeros for correctness
    # (it participates in the contraction); m/n padding is sliced off below.
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    if (mp, kp) != (m, k):
        lhs = jnp.pad(lhs, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        rhs = jnp.pad(rhs, ((0, kp - k), (0, np_ - n)))
    m, k, n = mp, kp, np_
    n_m = pl.cdiv(m, bm)
    n_n = pl.cdiv(n, bn)
    n_k = pl.cdiv(k, bk)

    if config.order == "mnk":
        grid = (n_m, n_n, n_k)
        lhs_map = lambda i, j, s: (i, s)
        rhs_map = lambda i, j, s: (s, j)
        out_map = lambda i, j, s: (i, j)
    else:  # 'nmk': m is the inner spatial loop
        grid = (n_n, n_m, n_k)
        lhs_map = lambda j, i, s: (i, s)
        rhs_map = lambda j, i, s: (s, j)
        out_map = lambda j, i, s: (i, j)

    kernel = functools.partial(_matmul_kernel, n_k=n_k, out_dtype=out_dtype)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lhs_map),
            pl.BlockSpec((bk, bn), rhs_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), out_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(lhs, rhs)
    if (orig_m, orig_n) != (m, n):
        out = out[:orig_m, :orig_n]
    return out


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
