"""Parameterized Pallas TPU kernel for the RWKV6 WKV recurrence.

Third tunable kernel family: the attention-free arch's perf-critical op.
The chunked WKV algorithm (see models/rwkv.py::wkv_chunked for the jnp
reference) splits the sequence into chunks; within a chunk the recurrence is
a small quadratic form, and a (hd, hd) key-value state carries across chunks.

TPU mapping:
  * grid = (n_chunks,), sequential ('arbitrary') — the state lives in a VMEM
    f32 scratch that persists across grid steps (the TPU-native analogue of
    a GPU persistent-CTA scan);
  * blocks are (chunk, hd) tiles of r/k/v/logw; hd = 64 aligns the MXU quarter
    tile, chunk is the tunable occupancy/VMEM knob (the config family);
  * all math f32 (the recurrence is exponentially sensitive; the reference
    does the same).

Config space: ``WkvConfig(chunk)`` — like the matmul/attention families,
every chunk size is a separate compiled binary that the deployment-selection
pipeline can prune.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True, order=True)
class WkvConfig:
    chunk: int = 16

    def name(self) -> str:
        return f"wkv_c{self.chunk}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "WkvConfig":
        return WkvConfig(**d)


@functools.cache
def wkv_config_space() -> tuple[WkvConfig, ...]:
    return tuple(WkvConfig(c) for c in (8, 16, 32, 64, 128))


DEFAULT_WKV_CONFIG = WkvConfig(16)


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref, s_ref, *, n_chunks: int):
    """One grid step = one chunk.  Blocks (L, hd); state scratch (hd, hd) f32."""
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _load_state():
        s_ref[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)  # (1, hd)
    s = s_ref[...]

    l = r.shape[0]
    cum = jnp.cumsum(w, axis=0)
    # Midpoint stabilization: the factored form r̃=r·e^{cum-w}, k̃=k·e^{-cum}
    # is exact but its exponents grow with the chunk length (the classic
    # chunked-WKV instability).  Shifting both by the per-channel midpoint
    # decay m halves the exponent range: scores are unchanged
    # (e^{cum-w-m}·e^{m-cum'} = e^{cum-w-cum'}), enabling chunks ≥ 32.
    m = cum[l // 2][None, :]
    r_t = r * jnp.exp(cum - w - m)
    k_t = k * jnp.exp(m - cum)
    # State-in term uses the unshifted r̃ (its exponent cum-w <= 0 is bounded).
    r_s = r * jnp.exp(cum - w)
    scores = jax.lax.dot_general(
        r_t, k_t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    scores = jnp.where(cols < rows, scores, 0.0)  # strictly causal within chunk
    diag = jnp.sum(r * (u * k), axis=1, keepdims=True)  # (L, 1)
    o = (
        jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        + diag * v
        + jax.lax.dot_general(r_s, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    )
    o_ref[...] = o.astype(o_ref.dtype)

    # state update: S' = e^{cum_L} ⊙_rows S + Σ_τ (k_τ e^{cum_L - cum_τ}) v_τᵀ
    cum_last = cum[-1:, :]  # (1, hd)
    k_hat = k * jnp.exp(cum_last - cum)
    s_new = jnp.exp(cum_last).T * s + jax.lax.dot_general(
        k_hat, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _store_state():
        sout_ref[...] = s_new.astype(sout_ref.dtype)


# Padding positions use logw = 0 (no decay) and zero k/v, so they alter
# neither the outputs nor the carried state (exactness for any chunk size).
_LOGW_PAD = 0.0


def wkv_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,
    state: jax.Array | None = None,
    config: WkvConfig = DEFAULT_WKV_CONFIG,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Single-head chunked WKV: r/k/v/logw (S, hd); u (hd,); state (hd, hd).

    Returns (o (S, hd) f32, final_state (hd, hd) f32).  Batch/head dims are
    vmapped by callers (see ops.wkv).
    """
    s_len, hd = r.shape
    chunk = min(config.chunk, max(s_len, 8))
    pad = (-s_len) % chunk
    if pad:
        zs = lambda t: jnp.pad(t, ((0, pad), (0, 0)))
        r, k, v = zs(r), zs(k), zs(v)
        logw = jnp.pad(logw, ((0, pad), (0, 0)), constant_values=_LOGW_PAD)
    n_chunks = (s_len + pad) // chunk
    if state is None:
        state = jnp.zeros((hd, hd), jnp.float32)

    kernel = functools.partial(_wkv_kernel, n_chunks=n_chunks)
    o, s_out = pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((chunk, hd), lambda i: (i, 0)),
            pl.BlockSpec((chunk, hd), lambda i: (i, 0)),
            pl.BlockSpec((chunk, hd), lambda i: (i, 0)),
            pl.BlockSpec((chunk, hd), lambda i: (i, 0)),
            pl.BlockSpec((1, hd), lambda i: (0, 0)),
            pl.BlockSpec((hd, hd), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((chunk, hd), lambda i: (i, 0)),
            pl.BlockSpec((hd, hd), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_chunks * chunk, hd), jnp.float32),
            jax.ShapeDtypeStruct((hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(dimension_semantics=("arbitrary",)),
    )(r, k, v, logw, u.reshape(1, hd), state)
    return o[:s_len], s_out
