"""Pure PartitionSpec rules for every pytree the launchers shard.

All functions are shape/name-driven and mesh-agnostic beyond ``axis_names``,
so they are unit-testable without devices (the specs are pure data; only
``NamedSharding`` construction needs a real mesh).

Mesh axis conventions (see ``repro.launch.mesh``):
  ``pod``   — outermost data-parallel axis across pods/slices (optional);
  ``data``  — data parallel / FSDP axis;
  ``model`` — tensor/expert parallel axis.

Parameter rules (name = innermost dict key, rank includes the scan-stacked
layer axis that all per-block params carry at axis 0):
  * rank-1/2 vectors and per-layer norms/gates — replicated;
  * ``embed`` (V, d) — vocab over ``model``, features over ``data``;
  * ``unembed`` (d, V) — column-parallel;
  * rank-3 GEMM weights — column-parallel ``P(None, data, model)`` by
    default; known output projections row-parallel ``P(None, model, data)``;
    tiny-state SSM/router matrices FSDP-only; per-head decay/bonus tables
    replicated;
  * rank-4 MoE expert stacks (L, E, d, ff) — experts over ``model`` (EP),
    FSDP over the next dim.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# Output projections: input dim is the sharded (model) dim — row-parallel.
_ROW_PARALLEL = {"wo", "w_o", "w_out", "w_down", "w_cv", "wd2", "w_dt2"}
# Tiny trailing state dims (SSM B/C/A, router logits): FSDP the d dim only.
_FSDP_ONLY = {"w_b", "w_c", "a_log", "router"}
# Per-head tables too small to shard at all.
_REPLICATED = {"ln_w", "u"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def _axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def param_pspecs(params, mesh):
    """PartitionSpec tree mirroring ``params`` (one P per leaf)."""
    axes = _axes(mesh)
    tp = "model" if "model" in axes else None
    fsdp = "data" if "data" in axes else None

    def spec(path, leaf) -> P:
        name = _leaf_name(path)
        rank = len(leaf.shape)
        if rank <= 1:
            return P()
        if rank == 2:
            if name == "embed":
                return P(tp, fsdp)
            if name == "unembed":
                return P(fsdp, tp)
            return P()  # per-layer (L, d) norms / mixing vectors
        if rank == 3:
            if name in _REPLICATED:
                return P()
            if name in _FSDP_ONLY:
                return P(None, fsdp, None)
            if name in _ROW_PARALLEL:
                return P(None, tp, fsdp)
            return P(None, fsdp, tp)  # column-parallel default
        if rank == 4:  # MoE expert stacks (L, E, d, ff) / (L, E, ff, d)
            return P(None, tp, fsdp, None)
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(spec, params)


def batch_pspecs(tree, mesh, *, shard_seq: bool = False):
    """Input batches: DP over the leading batch dim (SP over sequence)."""
    axes = _axes(mesh)
    dp = tuple(a for a in ("pod", "data") if a in axes)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec(leaf) -> P:
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        if shard_seq:
            # batch=1 long-context: shard the sequence dim instead.
            if rank == 1:
                return P(None)
            seq = "data" if "data" in axes else None
            return P(None, seq, *([None] * (rank - 2)))
        return P(dp_spec, *([None] * (rank - 1)))

    return jax.tree.map(spec, tree)


def cache_pspecs(cache, mesh, *, shard_seq: bool = False, kv_seq_axis: str | None = None):
    """KV/state caches: DP over batch; context-parallel over seq when asked.

    Layer-stacked leaves (rank >= 4: (L, B, T, H, hd) KV, (L, B, H, hd, hd)
    WKV/SSM state) carry batch at axis 1; flat leaves (e.g. encoder
    ``memory`` (B, S, d)) at axis 0.  With ``shard_seq`` the KV sequence dim
    is sharded over ``data`` (or ``kv_seq_axis``); sequence-free state leaves
    shard their head dim over ``model`` instead.
    """
    axes = _axes(mesh)
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None

    def spec(path, leaf) -> P:
        name = _leaf_name(path)
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        batch_axis = 1 if rank >= 4 else 0
        out = [None] * rank
        if not shard_seq:
            out[batch_axis] = dp if dp else None
            return P(*out)
        seq_axis = batch_axis + 1
        if seq_axis < rank:
            if name.startswith(("k", "v", "memory")):
                out[seq_axis] = kv_seq_axis or ("data" if "data" in axes else None)
            else:  # sequence-free resident state: split heads instead
                out[seq_axis] = tp
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, cache)


def opt_pspecs(opt, param_spec):
    """Optimizer-state specs: moments mirror the params, step is replicated."""
    return type(opt)(step=P(), m=param_spec, v=param_spec)
