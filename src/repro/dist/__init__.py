"""Distributed-execution support: sharding rules for params, batches, caches."""
