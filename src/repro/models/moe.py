"""Mixture-of-Experts FFN (token-choice top-k, capacity-bucketed, EP-sharded).

Dispatch uses scatter/gather with capacity buckets (no dense (T, E, C)
dispatch tensor, which would be quadratically infeasible at 1M tokens):

  1. router top-k -> (token, expert) assignments;
  2. position-in-expert via a cumsum over expert one-hots;
  3. scatter tokens into an (E, C, d) buffer — sharded E over the 'model'
     mesh axis, so under GSPMD the scatter lowers to the expert all-to-all;
  4. per-expert SwiGLU GEMMs (einsum over the local experts);
  5. gather back + weighted combine; tokens over capacity are dropped
     (standard capacity-factor semantics) and pass through the residual.

Returns an auxiliary load-balance loss (Switch-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .layers import shard_moe_buf, stacked_dense_init


def init_moe(rng, cfg, dtype=jnp.float32, n_layers: int | None = None) -> dict:
    n = n_layers if n_layers is not None else cfg.n_layers
    e = cfg.moe.n_experts
    ks = jax.random.split(rng, 4)
    return {
        "router": stacked_dense_init(ks[0], n, cfg.d_model, e, dtype),
        "w_gate": (jax.random.normal(ks[1], (n, e, cfg.d_model, cfg.d_ff)) * 0.02).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n, e, cfg.d_model, cfg.d_ff)) * 0.02).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n, e, cfg.d_ff, cfg.d_model)) * 0.02).astype(dtype),
    }


def moe_ffn(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  Params already sliced to one layer."""
    b, s, d = x.shape
    e, top_k = cfg.moe.n_experts, cfg.moe.top_k
    t = b * s
    xt = x.reshape(t, d)

    router_logits = ops.matmul(xt, p["router"], out_dtype=jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch): E * sum_e f_e * p_e.
    me = probs.mean(0)  # mean router prob per expert
    ce = jnp.zeros(e).at[expert_ids.reshape(-1)].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)

    # Position of each assignment within its expert's capacity bucket.
    flat_e = expert_ids.reshape(-1)  # (T*k,) — k-major per token
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_e = ((jnp.cumsum(onehot, axis=0) - 1) * onehot).sum(-1)  # (T*k,)
    capacity = max(1, int(t * top_k / e * cfg.moe.capacity_factor))
    keep = pos_in_e < capacity
    slot = jnp.minimum(pos_in_e, capacity - 1)

    # Dispatch: (E, C, d) buffer, sharded E over the 'model' axis by callers.
    tok_idx = jnp.repeat(jnp.arange(t), top_k)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[flat_e, slot].add(xt[tok_idx] * keep[:, None].astype(x.dtype))
    buf = shard_moe_buf(buf)

    # Expert SwiGLU (local experts under EP sharding).
    gate = shard_moe_buf(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    up = shard_moe_buf(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    y = shard_moe_buf(jnp.einsum("ecf,efd->ecd", h, p["w_down"]))

    # Combine: gather each assignment's expert output, weight, sum over k.
    # Cast y to the activation dtype BEFORE the gather: the gather crosses
    # the expert (EP) shards, so its collective moves half the bytes in bf16
    # (§Perf — the f32 combine all-reduce dominated the MoE prefill profile).
    out_flat = y.astype(x.dtype)[flat_e, slot] * (
        keep[:, None] * gate_vals.reshape(-1)[:, None]
    ).astype(x.dtype)
    out = out_flat.reshape(t, top_k, d).sum(axis=1)
    return out.reshape(b, s, d), aux
