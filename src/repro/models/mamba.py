"""Selective SSM (Mamba-style) head for the Hymba hybrid architecture.

Parallel-scan training path (jax.lax.associative_scan over the sequence) and
O(1)-state decode path.  The depthwise conv of full Mamba is omitted (noted
in DESIGN.md); the selective state-space core (input-dependent dt/B/C,
diagonal A) is faithful.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

_DT_RANK = 48


def init_mamba(rng, cfg, dtype=jnp.float32, n_layers: int | None = None) -> dict:
    n = n_layers if n_layers is not None else cfg.n_layers
    d, ns = cfg.d_model, cfg.ssm_state
    ks = jax.random.split(rng, 7)
    scale = lambda a, b: (2.0 / (a + b)) ** 0.5
    return {
        "w_in": (jax.random.normal(ks[0], (n, d, 2 * d)) * scale(d, 2 * d)).astype(dtype),
        "w_dt1": (jax.random.normal(ks[1], (n, d, _DT_RANK)) * scale(d, _DT_RANK)).astype(dtype),
        "w_dt2": (jax.random.normal(ks[2], (n, _DT_RANK, d)) * scale(_DT_RANK, d)).astype(dtype),
        "w_b": (jax.random.normal(ks[3], (n, d, ns)) * scale(d, ns)).astype(dtype),
        "w_c": (jax.random.normal(ks[4], (n, d, ns)) * scale(d, ns)).astype(dtype),
        "a_log": jnp.broadcast_to(jnp.log(jnp.arange(1, ns + 1, dtype=jnp.float32)), (n, d, ns)).astype(dtype)
        * 0.5,
        "d_skip": jnp.ones((n, d), dtype),
        "w_out": (jax.random.normal(ks[6], (n, d, d)) * scale(d, d)).astype(dtype),
    }


def _ssm_inputs(p: dict, x: jax.Array, cfg):
    """Common projections.  x: (B, S, d) -> (xin, z, dt, b, c, a)."""
    xz = ops.matmul(x, p["w_in"])
    xin, z = jnp.split(xz, 2, axis=-1)
    dt = jax.nn.softplus(ops.matmul(ops.matmul(xin, p["w_dt1"]), p["w_dt2"]).astype(jnp.float32))
    b = ops.matmul(xin, p["w_b"]).astype(jnp.float32)  # (B,S,N)
    c = ops.matmul(xin, p["w_c"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (d, N), negative
    return xin, z, dt, b, c, a


def mamba_layer(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Training/prefill path: fused selective scan (ops.ssm_scan dispatches
    to the Pallas kernel when enabled; jnp associative-scan oracle otherwise)."""
    bsz, s, d = x.shape
    xin, z, dt, b, c, a = _ssm_inputs(p, x, cfg)
    dtx = dt * xin.astype(jnp.float32)  # (B,S,d)
    dta = dt[..., None] * a  # (B,S,d,N)
    y, h_last = ops.ssm_scan(dtx, dta, b, c)
    y = y + p["d_skip"].astype(jnp.float32) * xin.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return ops.matmul(y.astype(x.dtype), p["w_out"]), h_last  # (B,S,d), (B,d,N)


def mamba_decode_step(p: dict, x: jax.Array, state: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Decode: x (B, 1, d), state (B, d, N) -> (out (B,1,d), new state)."""
    xin, z, dt, b, c, a = _ssm_inputs(p, x, cfg)
    abar = jnp.exp(dt[:, 0, :, None] * a)  # (B,d,N)
    bx = (dt[:, 0] * xin[:, 0].astype(jnp.float32))[..., None] * b[:, 0, None, :]
    new_state = abar * state + bx
    y = (new_state * c[:, 0, None, :]).sum(-1) + p["d_skip"].astype(jnp.float32) * xin[:, 0].astype(jnp.float32)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    return ops.matmul(y.astype(x.dtype), p["w_out"])[:, None], new_state
