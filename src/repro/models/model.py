"""Unified model API over all assigned architecture families.

Every family exposes the same functional surface, which the trainer, serving
engine, dry-run, and smoke tests consume uniformly:

  init(rng) -> params
  loss_fn(params, batch) -> (scalar loss, metrics)        [train shapes]
  prefill(params, batch, cache_len) -> (last_logits, cache)
  decode_step(params, cache, tokens, positions) -> (logits, cache)
  init_cache(batch, cache_len) -> cache pytree

Layer stacks are ``lax.scan``-ed (bounded HLO at 100 layers); the per-layer
body is ``jax.checkpoint``-ed in the training path (remat).  The hybrid
family (heterogeneous layer types) uses a python loop instead — it is the
smallest assigned model.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops

from . import layers as L
from . import mamba as M
from . import moe as MOE
from . import rwkv as R

_AUX_COEF = 0.01


def build_model(cfg: ArchConfig, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, kv_quant: bool = False):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        m = TransformerLM(cfg, dtype, param_dtype)
        m.kv_quant = kv_quant  # int8 KV cache (§Perf; transformer family)
        return m
    if fam == "hybrid":
        return HymbaLM(cfg, dtype, param_dtype)
    if fam == "ssm":
        return RWKV6LM(cfg, dtype, param_dtype)
    if fam == "audio":
        return EncDecLM(cfg, dtype, param_dtype)
    raise ValueError(f"unknown family {fam!r}")


@dataclasses.dataclass
class BaseModel:
    cfg: ArchConfig
    dtype: object = jnp.bfloat16
    param_dtype: object = jnp.bfloat16

    # shared helpers ------------------------------------------------------
    def _positions(self, b: int, s: int) -> jax.Array:
        return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def _kv_cache_zeros(self, b: int, t: int, n: int) -> dict:
        c = self.cfg
        shape = (n, b, t, c.n_kv_heads, c.head_dim)
        return {"k": jnp.zeros(shape, self.dtype), "v": jnp.zeros(shape, self.dtype)}


# ===========================================================================
# dense / moe / vlm decoder-only transformer
# ===========================================================================
class TransformerLM(BaseModel):
    """Decoder-only LM; MoE FFN if cfg.moe; interleaved cross-attn if vlm."""

    def __init__(self, cfg, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16):
        super().__init__(cfg, dtype, param_dtype)
        self.is_moe = cfg.moe is not None
        self.is_vlm = cfg.family == "vlm"
        self.kv_quant = False  # int8 KV cache (set via build_model)
        if self.is_vlm:
            assert cfg.n_layers % cfg.cross_every == 0
            self.n_groups = cfg.n_layers // cfg.cross_every
            self.self_per_group = cfg.cross_every - 1  # last layer of group is cross

    # -- params -----------------------------------------------------------
    def init(self, rng) -> dict:
        c, pd = self.cfg, self.param_dtype
        ks = jax.random.split(rng, 6)
        n_self = c.n_layers if not self.is_vlm else self.n_groups * self.self_per_group
        blocks = {
            "attn": L.init_attention(ks[0], c, pd, n_layers=n_self),
            "ln1": jnp.ones((n_self, c.d_model), pd),
            "ln2": jnp.ones((n_self, c.d_model), pd),
        }
        if self.is_moe:
            blocks["moe"] = MOE.init_moe(ks[1], c, pd)
        else:
            blocks["ffn"] = L.init_mlp(ks[1], c, pd, n_layers=n_self)
        params = {
            "emb": L.init_embedding(ks[2], c, pd),
            "final_norm": jnp.ones((c.d_model,), pd),
            "blocks": blocks,
        }
        if self.is_vlm:
            params["cross"] = {
                "attn": L.init_attention(ks[3], c, pd, n_layers=self.n_groups),
                "ffn": L.init_mlp(ks[4], c, pd, n_layers=self.n_groups),
                "ln1": jnp.ones((self.n_groups, c.d_model), pd),
                "ln2": jnp.ones((self.n_groups, c.d_model), pd),
                "ln_img": jnp.ones((self.n_groups, c.d_model), pd),
            }
        if self.is_moe:
            # MoE FFN applies to every layer; vlm never combines with moe here.
            assert not self.is_vlm
        return params

    # -- one transformer block (self-attn + ffn) ---------------------------
    def _self_block(self, blk: dict, x, positions, *, cache=None, cache_positions=None, window=0, chunk_start=None):
        c = self.cfg
        h, new_cache = L.attention_layer(
            blk["attn"],
            L.rms_norm(x, blk["ln1"], c.norm_eps),
            c,
            positions,
            cache=cache,
            cache_positions=cache_positions,
            window=window,
            chunk_start=chunk_start,
        )
        x = x + h
        xn = L.rms_norm(x, blk["ln2"], c.norm_eps)
        if self.is_moe:
            out, aux = MOE.moe_ffn(blk["moe"], xn, c)
        else:
            out, aux = L.mlp_layer(blk["ffn"], xn), 0.0
        return x + out, aux, new_cache

    def _cross_block(self, blk: dict, x, image_embs):
        c = self.cfg
        h, _ = L.attention_layer(
            blk["attn"],
            L.rms_norm(x, blk["ln1"], c.norm_eps),
            c,
            None,
            kv_input=L.rms_norm(image_embs, blk["ln_img"], c.norm_eps),
            causal=False,
            use_rope=False,
        )
        x = x + h
        return x + L.mlp_layer(blk["ffn"], L.rms_norm(x, blk["ln2"], c.norm_eps))

    # -- forward over the stack --------------------------------------------
    def _forward(self, params, tokens, *, image_embs=None, remat=False):
        c = self.cfg
        b, s = tokens.shape
        positions = self._positions(b, s)
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))

        def self_body(carry, blk):
            x, aux = carry
            x, aux_i, _ = self._self_block(blk, x, positions)
            return (L.shard_act(x), aux + aux_i), None

        body = L.ckpt(self_body) if remat else self_body
        aux0 = jnp.zeros((), jnp.float32)

        if not self.is_vlm:
            (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
        else:
            g, spg = self.n_groups, self.self_per_group
            grouped = jax.tree.map(lambda a: a.reshape(g, spg, *a.shape[1:]), params["blocks"])

            def group_body(carry, blks):
                self_blks, cross_blk = blks
                (x, aux), _ = jax.lax.scan(body, carry, self_blks)
                x = self._cross_block(cross_blk, x, image_embs)
                return (x, aux), None

            gbody = L.ckpt(group_body) if remat else group_body
            (x, aux), _ = jax.lax.scan(gbody, (x, aux0), (grouped, params["cross"]))

        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        return x, aux

    # -- public API ---------------------------------------------------------
    def loss_fn(self, params, batch):
        c = self.cfg
        x, aux = self._forward(
            params, batch["tokens"], image_embs=batch.get("image_embs"), remat=True
        )
        logits = L.logits_from_hidden(params["emb"], x, c)
        loss = L.cross_entropy_loss(logits, batch["targets"], c.vocab)
        total = loss + _AUX_COEF * aux if self.is_moe else loss
        return total, {"ce_loss": loss, "aux_loss": aux}

    # -- caches / decode ----------------------------------------------------
    def init_cache(self, b: int, cache_len: int) -> dict:
        c = self.cfg
        n_self = c.n_layers if not self.is_vlm else self.n_groups * self.self_per_group
        if self.kv_quant:
            shape = (n_self, b, cache_len, c.n_kv_heads, c.head_dim)
            cache = {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                "v_scale": jnp.zeros(shape[:-1], jnp.float32),
            }
        else:
            cache = self._kv_cache_zeros(b, cache_len, n_self)
        if self.is_vlm:
            cache["cross_k"] = jnp.zeros(
                (self.n_groups, b, c.n_image_tokens, c.n_kv_heads, c.head_dim), self.dtype
            )
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        return cache

    @property
    def _cache_keys(self) -> tuple[str, ...]:
        return ("k", "v", "k_scale", "v_scale") if self.kv_quant else ("k", "v")

    def prefill(self, params, batch, cache_len: int):
        c = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = self.init_cache(b, cache_len)
        positions = self._positions(b, s)
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))
        image_embs = batch.get("image_embs")
        keys = self._cache_keys

        def self_body(x, inp):
            blk, *kv = inp
            xc, _, kv = self._self_block(blk, x, positions, cache=tuple(kv))
            return L.shard_act(xc), kv

        if not self.is_vlm:
            x, kv = jax.lax.scan(
                self_body, x, (params["blocks"], *[cache[k] for k in keys])
            )
            cache.update(zip(keys, kv))
        else:
            g, spg = self.n_groups, self.self_per_group
            grouped = jax.tree.map(lambda a: a.reshape(g, spg, *a.shape[1:]), params["blocks"])
            kvg = [cache[k].reshape(g, spg, *cache[k].shape[1:]) for k in keys]

            def group_body(x, inp):
                self_blks, cross_blk, *kv = inp
                x, kv = jax.lax.scan(self_body, x, (self_blks, *kv))
                x = self._cross_block(cross_blk, x, image_embs)
                # Cross K/V are static per request: computed once here.
                imn = L.rms_norm(image_embs, cross_blk["ln_img"], c.norm_eps)
                ck = ops.matmul(imn, cross_blk["attn"]["wk"]).reshape(b, -1, c.n_kv_heads, c.head_dim)
                cv = ops.matmul(imn, cross_blk["attn"]["wv"]).reshape(b, -1, c.n_kv_heads, c.head_dim)
                return x, (*kv, ck, cv)

            x, (*kvg, cks, cvs) = jax.lax.scan(group_body, x, (grouped, params["cross"], *kvg))
            for key, arr in zip(keys, kvg):
                cache[key] = arr.reshape(g * spg, *arr.shape[2:])
            cache["cross_k"], cache["cross_v"] = cks, cvs

        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = L.logits_from_hidden(params["emb"], x[:, -1:], c)
        return logits, cache

    def supports_chunked_prefill(self) -> bool:
        """Chunk-append prefill works on the plain (non-vlm, non-int8) k/v
        layout; other layouts fall back to monolithic prefill."""
        return not self.is_vlm and not self.kv_quant

    def prefill_chunk(self, params, cache, tokens, start, last_row=None):
        """Append a prompt chunk at absolute positions ``[start, start+S)``.

        ``tokens``: (B, S); ``start``: scalar, may be traced — one compiled
        program per chunk *length* serves every chunk offset, which is what
        makes scheduler-granular chunked prefill affordable.  Returns
        ``(logits, cache)`` like :meth:`prefill`; ``last_row`` (scalar, may
        be traced, defaults to ``S-1``) selects the row whose logits are
        returned, so a padded final chunk can ask for its last *real* row —
        the first-token logits — without a separate decode program.  Rows
        past the real prompt (a padded final chunk) are causally dead; later
        chunks or decode steps overwrite them.
        """
        if not self.supports_chunked_prefill():
            raise NotImplementedError(
                f"chunked prefill unsupported for this layout "
                f"(vlm={self.is_vlm}, kv_quant={self.kv_quant})"
            )
        c = self.cfg
        b, s = tokens.shape
        start = jnp.asarray(start, jnp.int32)
        positions = start + self._positions(b, s)
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))
        keys = self._cache_keys

        def self_body(x, inp):
            blk, *kv = inp
            xc, _, kv = self._self_block(
                blk, x, positions, cache=tuple(kv), chunk_start=start
            )
            return L.shard_act(xc), kv

        x, kv = jax.lax.scan(
            self_body, x, (params["blocks"], *[cache[k] for k in keys])
        )
        cache = dict(cache, **dict(zip(keys, kv)))
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        if last_row is None:
            x_last = x[:, -1:]
        else:
            x_last = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(last_row, jnp.int32), 1, axis=1
            )
        return L.logits_from_hidden(params["emb"], x_last, c), cache

    def decode_step(self, params, cache, tokens, positions):
        """tokens: (B, 1); positions: (B,) — index of the new token."""
        c = self.cfg
        b = tokens.shape[0]
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))
        pos2d = positions[:, None]
        keys = self._cache_keys

        def self_body(x, inp):
            blk, *kv = inp
            xc, _, kv = self._self_block(blk, x, pos2d, cache=tuple(kv), cache_positions=positions)
            return L.shard_act(xc), kv

        if not self.is_vlm:
            x, kv = jax.lax.scan(self_body, x, (params["blocks"], *[cache[k] for k in keys]))
            cache = dict(cache, **dict(zip(keys, kv)))
        else:
            g, spg = self.n_groups, self.self_per_group
            grouped = jax.tree.map(lambda a: a.reshape(g, spg, *a.shape[1:]), params["blocks"])
            kvg = [cache[k].reshape(g, spg, *cache[k].shape[1:]) for k in keys]

            def group_body(x, inp):
                self_blks, cross_blk, ck, cv, *kv = inp
                x, kv = jax.lax.scan(self_body, x, (self_blks, *kv))
                q = ops.matmul(L.rms_norm(x, cross_blk["ln1"], c.norm_eps), cross_blk["attn"]["wq"])
                h = L.decode_attention_jnp(
                    q.reshape(b, 1, c.n_heads, c.head_dim),
                    ck,
                    cv,
                    jnp.full((b,), ck.shape[1], jnp.int32),  # attend over all image tokens
                )
                x = x + ops.matmul(h.reshape(b, 1, c.q_dim), cross_blk["attn"]["wo"])
                x = x + L.mlp_layer(cross_blk["ffn"], L.rms_norm(x, cross_blk["ln2"], c.norm_eps))
                return x, kv

            x, kvg = jax.lax.scan(
                group_body,
                x,
                (grouped, params["cross"], cache["cross_k"], cache["cross_v"], *kvg),
            )
            cache = dict(
                cache,
                **{key: arr.reshape(g * spg, *arr.shape[2:]) for key, arr in zip(keys, kvg)},
            )

        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        return L.logits_from_hidden(params["emb"], x, c), cache


# ===========================================================================
# hymba: parallel attention + mamba heads, SWA + 3 global layers
# ===========================================================================
class HymbaLM(BaseModel):
    def _layer_kinds(self) -> list[str]:
        n = self.cfg.n_layers
        glob = {0, n // 2, n - 1}
        return ["global" if i in glob else "swa" for i in range(n)]

    def init(self, rng) -> dict:
        c, pd = self.cfg, self.param_dtype
        ks = jax.random.split(rng, 4)
        return {
            "emb": L.init_embedding(ks[0], c, pd),
            "final_norm": jnp.ones((c.d_model,), pd),
            "blocks": {
                "attn": L.init_attention(ks[1], c, pd),
                "mamba": M.init_mamba(ks[2], c, pd),
                "ffn": L.init_mlp(ks[3], c, pd),
                "ln1": jnp.ones((c.n_layers, c.d_model), pd),
                "ln2": jnp.ones((c.n_layers, c.d_model), pd),
            },
        }

    def _windows(self):
        """Per-layer window sizes (0 = global) as a scannable array."""
        return jnp.array(
            [0 if k == "global" else self.cfg.window for k in self._layer_kinds()], jnp.int32
        )

    def _layer(self, blk, x, positions, kind, *, cache=None, cache_positions=None, cache_valid=None, window=None):
        """Parallel attn + mamba on the same normalized input (Hymba fusion).

        ``kind`` picks the static window ('global'/'swa'); pass ``window``
        (possibly traced, 0 = global) instead when scanning over layers.
        """
        c = self.cfg
        xn = L.rms_norm(x, blk["ln1"], c.norm_eps)
        if window is None:
            window = 0 if kind == "global" else c.window
        attn_cache = mamba_state = None
        if cache is not None:
            attn_cache, mamba_state = cache
        h_attn, new_attn_cache = L.attention_layer(
            blk["attn"],
            xn,
            c,
            positions,
            window=window,
            cache=attn_cache,
            cache_positions=cache_positions,
            cache_valid=cache_valid,
        )
        if cache is not None and x.shape[1] == 1:
            h_mamba, new_mamba_state = M.mamba_decode_step(blk["mamba"], xn, mamba_state, c)
        else:
            h_mamba, new_mamba_state = M.mamba_layer(blk["mamba"], xn, c)
        x = x + 0.5 * (h_attn + h_mamba)
        x = x + L.mlp_layer(blk["ffn"], L.rms_norm(x, blk["ln2"], c.norm_eps))
        return x, (new_attn_cache, new_mamba_state)

    def _slice_blocks(self, params, i):
        return jax.tree.map(lambda a: a[i], params["blocks"])

    def loss_fn(self, params, batch):
        c = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = self._positions(b, s)
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))

        # Layers are structurally homogeneous — only the window differs
        # (0 = global) — so the stack scans with a traced per-layer window,
        # keeping the HLO bounded like every other family.
        def body(x, inp):
            blk, w = inp
            x, _ = self._layer(blk, x, positions, None, window=w)
            return L.shard_act(x), None

        x, _ = jax.lax.scan(L.ckpt(body), x, (params["blocks"], self._windows()))
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = L.logits_from_hidden(params["emb"], x, c)
        loss = L.cross_entropy_loss(logits, batch["targets"], c.vocab)
        return loss, {"ce_loss": loss}

    def init_cache(self, b: int, cache_len: int) -> dict:
        c = self.cfg
        kinds = self._layer_kinds()
        cache = {}
        for i, kind in enumerate(kinds):
            t = cache_len if kind == "global" else min(c.window, cache_len)
            cache[f"layer{i}"] = {
                "k": jnp.zeros((b, t, c.n_kv_heads, c.head_dim), self.dtype),
                "v": jnp.zeros((b, t, c.n_kv_heads, c.head_dim), self.dtype),
                "ssm": jnp.zeros((b, c.d_model, c.ssm_state), jnp.float32),
            }
        return cache

    def prefill(self, params, batch, cache_len: int):
        c = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = self._positions(b, s)
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))
        cache = self.init_cache(b, cache_len)
        for i, kind in enumerate(self._layer_kinds()):
            blk = self._slice_blocks(params, i)
            entry = cache[f"layer{i}"]
            x, ((kc, vc), ssm) = self._layer(
                blk, x, positions, kind, cache=((entry["k"], entry["v"]), entry["ssm"])
            )
            cache[f"layer{i}"] = {"k": kc, "v": vc, "ssm": ssm}
            x = L.shard_act(x)
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        return L.logits_from_hidden(params["emb"], x[:, -1:], c), cache

    def decode_step(self, params, cache, tokens, positions):
        c = self.cfg
        b = tokens.shape[0]
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))
        new_cache = dict(cache)
        for i, kind in enumerate(self._layer_kinds()):
            blk = self._slice_blocks(params, i)
            entry = cache[f"layer{i}"]
            t = entry["k"].shape[1]
            # Ring-buffer slots + valid-count for SWA layers.
            cpos = positions if kind == "global" else positions % t
            cvalid = positions + 1 if kind == "global" else jnp.minimum(positions + 1, t)
            x, ((kc, vc), ssm) = self._layer(
                blk,
                x,
                positions[:, None],
                kind,
                cache=((entry["k"], entry["v"]), entry["ssm"]),
                cache_positions=cpos,
                cache_valid=cvalid,
            )
            new_cache[f"layer{i}"] = {"k": kc, "v": vc, "ssm": ssm}
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        return L.logits_from_hidden(params["emb"], x, c), new_cache


# ===========================================================================
# rwkv6
# ===========================================================================
class RWKV6LM(BaseModel):
    def init(self, rng) -> dict:
        c, pd = self.cfg, self.param_dtype
        ks = jax.random.split(rng, 2)
        return {
            "emb": L.init_embedding(ks[0], c, pd),
            "final_norm": jnp.ones((c.d_model,), pd),
            "blocks": {
                "rwkv": R.init_rwkv(ks[1], c, pd),
                "ln1": jnp.ones((c.n_layers, c.d_model), pd),
                "ln2": jnp.ones((c.n_layers, c.d_model), pd),
            },
        }

    def _layer(self, blk, x, *, state=None):
        """state: (wkv (B,H,hd,hd), x1 (B,d), x2 (B,d)) or None."""
        c = self.cfg
        wkv_state = x1 = x2 = None
        if state is not None:
            wkv_state, x1, x2 = state
        xn = L.rms_norm(x, blk["ln1"], c.norm_eps)
        h, (new_wkv, last1) = R.time_mix_layer(blk["rwkv"], xn, c, state=wkv_state, x_prev=x1)
        x = x + h
        xn2 = L.rms_norm(x, blk["ln2"], c.norm_eps)
        h2, last2 = R.channel_mix_layer(blk["rwkv"], xn2, c, x_prev=x2)
        x = x + h2
        return x, (new_wkv, last1, last2)

    def loss_fn(self, params, batch):
        c = self.cfg
        tokens = batch["tokens"]
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))

        def body(x, blk):
            x, _ = self._layer(blk, x)
            return L.shard_act(x), None

        x, _ = jax.lax.scan(L.ckpt(body), x, params["blocks"])
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = L.logits_from_hidden(params["emb"], x, c)
        loss = L.cross_entropy_loss(logits, batch["targets"], c.vocab)
        return loss, {"ce_loss": loss}

    def init_cache(self, b: int, cache_len: int) -> dict:
        c = self.cfg
        n, h, hd, d = c.n_layers, c.n_heads, c.head_dim, c.d_model
        return {
            "wkv": jnp.zeros((n, b, h, hd, hd), jnp.float32),
            "x1": jnp.zeros((n, b, d), self.dtype),
            "x2": jnp.zeros((n, b, d), self.dtype),
        }

    def _run(self, params, x, cache):
        def body(x, inp):
            blk, wkv, x1, x2 = inp
            x, (wkv, x1, x2) = self._layer(blk, x, state=(wkv, x1, x2))
            return L.shard_act(x), (wkv, x1, x2)

        x, (wkv, x1, x2) = jax.lax.scan(body, x, (params["blocks"], cache["wkv"], cache["x1"], cache["x2"]))
        return x, {"wkv": wkv, "x1": x1, "x2": x2}

    def prefill(self, params, batch, cache_len: int):
        c = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = L.embed_tokens(params["emb"], tokens).astype(self.dtype)
        x, cache = self._run(params, x, self.init_cache(b, cache_len))
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        return L.logits_from_hidden(params["emb"], x[:, -1:], c), cache

    def decode_step(self, params, cache, tokens, positions):
        del positions  # recurrent state carries all history
        c = self.cfg
        x = L.embed_tokens(params["emb"], tokens).astype(self.dtype)
        x, cache = self._run(params, x, cache)
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        return L.logits_from_hidden(params["emb"], x, c), cache


# ===========================================================================
# seamless (enc-dec)
# ===========================================================================
class EncDecLM(BaseModel):
    def init(self, rng) -> dict:
        c, pd = self.cfg, self.param_dtype
        ks = jax.random.split(rng, 6)
        ne = c.n_enc_layers
        return {
            "emb": L.init_embedding(ks[0], c, pd),
            "final_norm": jnp.ones((c.d_model,), pd),
            "enc_norm": jnp.ones((c.d_model,), pd),
            "encoder": {
                "attn": L.init_attention(ks[1], c, pd, n_layers=ne),
                "ffn": L.init_mlp(ks[2], c, pd, n_layers=ne),
                "ln1": jnp.ones((ne, c.d_model), pd),
                "ln2": jnp.ones((ne, c.d_model), pd),
            },
            "decoder": {
                "attn": L.init_attention(ks[3], c, pd),
                "cross": L.init_attention(ks[4], c, pd),
                "ffn": L.init_mlp(ks[5], c, pd),
                "ln1": jnp.ones((c.n_layers, c.d_model), pd),
                "ln_x": jnp.ones((c.n_layers, c.d_model), pd),
                "ln2": jnp.ones((c.n_layers, c.d_model), pd),
            },
        }

    def encode(self, params, frames):
        """frames: (B, S, d) stubbed audio-frontend embeddings."""
        c = self.cfg
        b, s, _ = frames.shape
        positions = self._positions(b, s)
        x = L.shard_act(frames.astype(self.dtype))

        def body(x, blk):
            h, _ = L.attention_layer(
                blk["attn"], L.rms_norm(x, blk["ln1"], c.norm_eps), c, positions, causal=False
            )
            x = x + h
            x = x + L.mlp_layer(blk["ffn"], L.rms_norm(x, blk["ln2"], c.norm_eps))
            return L.shard_act(x), None

        x, _ = jax.lax.scan(L.ckpt(body), x, params["encoder"])
        return L.rms_norm(x, params["enc_norm"], c.norm_eps)

    def _dec_layer(self, blk, x, positions, memory, *, cache=None, cache_positions=None):
        c = self.cfg
        h, new_cache = L.attention_layer(
            blk["attn"],
            L.rms_norm(x, blk["ln1"], c.norm_eps),
            c,
            positions,
            cache=cache,
            cache_positions=cache_positions,
        )
        x = x + h
        h, _ = L.attention_layer(
            blk["cross"],
            L.rms_norm(x, blk["ln_x"], c.norm_eps),
            c,
            None,
            kv_input=memory,
            causal=False,
            use_rope=False,
        )
        x = x + h
        x = x + L.mlp_layer(blk["ffn"], L.rms_norm(x, blk["ln2"], c.norm_eps))
        return x, new_cache

    def loss_fn(self, params, batch):
        c = self.cfg
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = self._positions(b, s)
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))

        def body(x, blk):
            x, _ = self._dec_layer(blk, x, positions, memory)
            return L.shard_act(x), None

        x, _ = jax.lax.scan(L.ckpt(body), x, params["decoder"])
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = L.logits_from_hidden(params["emb"], x, c)
        loss = L.cross_entropy_loss(logits, batch["targets"], c.vocab)
        return loss, {"ce_loss": loss}

    def init_cache(self, b: int, cache_len: int) -> dict:
        cache = self._kv_cache_zeros(b, cache_len, self.cfg.n_layers)
        return cache

    def prefill(self, params, batch, cache_len: int):
        """Encode frames + prefill the decoder with its token prefix."""
        c = self.cfg
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = self._positions(b, s)
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))
        cache = self.init_cache(b, cache_len)

        def body(x, inp):
            blk, kc, vc = inp
            x, (kc, vc) = self._dec_layer(blk, x, positions, memory, cache=(kc, vc))
            return L.shard_act(x), (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["decoder"], cache["k"], cache["v"]))
        cache.update(k=ks, v=vs)
        cache["memory"] = memory
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        return L.logits_from_hidden(params["emb"], x[:, -1:], c), cache

    def decode_step(self, params, cache, tokens, positions):
        c = self.cfg
        b = tokens.shape[0]
        memory = cache["memory"]
        x = L.shard_act(L.embed_tokens(params["emb"], tokens).astype(self.dtype))

        def body(x, inp):
            blk, kc, vc = inp
            x, (kc, vc) = self._dec_layer(
                blk, x, positions[:, None], memory, cache=(kc, vc), cache_positions=positions
            )
            return L.shard_act(x), (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["decoder"], cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs)
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        return L.logits_from_hidden(params["emb"], x, c), cache
