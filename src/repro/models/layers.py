"""Shared model building blocks (pure JAX, functional params-in/out).

All GEMMs route through ``repro.kernels.ops.matmul`` (the ML-guided kernel
dispatcher).  Attention uses a memory-bounded chunked online-softmax
implementation (flash-attention algorithm at the jnp level) so that 32k-token
prefill fits per-device HBM without relying on XLA fusion heuristics; on TPU
hosts the Pallas kernel path in ``repro.kernels`` takes over via
``KernelRuntime.set_pallas_enabled``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

_NEG_INF = -1e30

# ---------------------------------------------------------------------------
# activation-sharding context (set by the launcher per mesh/shape; models
# call shard_act() at layer boundaries to anchor GSPMD propagation — without
# it the embedding gather can leave the batch axis replicated).
# ---------------------------------------------------------------------------
_ACT_SPEC: dict = {"batch": None, "seq": None}


def set_activation_sharding(batch_axes=None, seq_axes=None) -> None:
    _ACT_SPEC["batch"] = batch_axes
    _ACT_SPEC["seq"] = seq_axes


def shard_act(x: jax.Array) -> jax.Array:
    """Constrain a (B, S, ...) activation to the configured DP/SP axes."""
    if _ACT_SPEC["batch"] is None and _ACT_SPEC["seq"] is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = [None] * x.ndim
    spec[0] = _ACT_SPEC["batch"]
    if x.ndim >= 2:
        spec[1] = _ACT_SPEC["seq"]
    return jax.lax.with_sharding_constraint(x, P(*spec))


# MoE dispatch-buffer sharding (set by the launcher; see moe.moe_ffn).
# Constraining the (E, C, …) buffers' capacity dim turns the expert-GEMM
# partial-sum all-reduce into a reduce-scatter (§Perf hillclimb).
_MOE_SPEC: dict = {"ep": None, "cap": None}


def set_moe_sharding(ep_axes=None, cap_axes=None) -> None:
    _MOE_SPEC["ep"] = ep_axes
    _MOE_SPEC["cap"] = cap_axes


def shard_moe_buf(x: jax.Array) -> jax.Array:
    """Constrain an (E, C, feature) MoE dispatch/expert buffer."""
    if _MOE_SPEC["ep"] is None and _MOE_SPEC["cap"] is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = [None] * x.ndim
    spec[0] = _MOE_SPEC["ep"]
    if x.ndim >= 2:
        spec[1] = _MOE_SPEC["cap"]
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# remat (activation checkpoint) policy — set by the launcher per §Perf config.
# 'full' recomputes the whole layer in the backward (min memory, 4F flops);
# 'dots' saves GEMM outputs and recomputes only cheap elementwise ops
# (3F flops, more activation memory).
# ---------------------------------------------------------------------------
_REMAT: dict = {"policy": "full"}


def set_remat_policy(policy: str) -> None:
    assert policy in ("full", "dots"), policy
    _REMAT["policy"] = policy


def ckpt(fn):
    """jax.checkpoint with the configured save policy (used by layer scans)."""
    if _REMAT["policy"] == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def stacked_dense_init(rng, n: int, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(rng, (n, d_in, d_out)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * scale) * w.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) absolute token positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, :, None, None] * freqs  # (B, S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked flash attention (jnp; grouped-query layout, no KV repeat)
# ---------------------------------------------------------------------------
def _attn_chunk(q, k, v, row0, col0, *, causal: bool, window: int, scale: float, valid_len=None):
    """One (q-chunk x kv-chunk) tile.  q: (B,KV,G,Lq,hd)  k/v: (B,KV,Lk,hd)."""
    logits = jnp.einsum("bkgqh,bkth->bkgqt", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    lq, lk = q.shape[-2], k.shape[-2]
    rows = row0 + jnp.arange(lq)[:, None]
    cols = col0 + jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask &= cols <= rows
    if isinstance(window, jax.Array):
        # traced per-layer window (hymba layer scan): 0 => global attention
        mask &= jnp.where(window > 0, cols > rows - window, True)
    elif window:
        mask &= cols > rows - window
    if valid_len is not None:
        mask = mask & (cols < valid_len)
    logits = jnp.where(mask, logits, _NEG_INF)
    m = logits.max(axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgqt,bkth->bkgqh", p, v.astype(jnp.float32))
    return m, l, o


def flash_attention_jnp(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    valid_len: jax.Array | None = None,
    q_start: jax.Array | int | None = None,
) -> jax.Array:
    """Grouped-query online-softmax attention.

    q: (B, S, H, hd) with H = KV * G;  k/v: (B, T, KV, hd).
    Memory is bounded by q_chunk x kv_chunk tiles (flash algorithm), which is
    what lets 32k prefill / 4k train fit per device without Pallas.

    ``q_start`` places the queries at absolute positions ``q_start + i``
    within the KV sequence (chunked prefill: a mid-prompt chunk attends over
    the whole cache, causally bounded at its own frontier).  Default aligns
    the causal diagonal to the *end* of KV (``t - s``), the train/prefill
    convention.  May be a traced scalar.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / (hd**0.5)
    qg = q.reshape(b, s, kv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,G,S,hd)
    kt = k.transpose(0, 2, 1, 3)  # (B,KV,T,hd)
    vt = v.transpose(0, 2, 1, 3)
    qc = min(q_chunk, s)
    kc = min(kv_chunk, t)
    n_q = -(-s // qc)
    n_k = -(-t // kc)
    # Pad sequence dims to chunk multiples.
    if n_q * qc != s:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, n_q * qc - s), (0, 0)))
    if n_k * kc != t:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, n_k * kc - t), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, n_k * kc - t), (0, 0)))
        valid_len = jnp.asarray(t) if valid_len is None else valid_len
    # Causal diagonal: queries sit at q_start..q_start+s-1 (chunked prefill)
    # or end-aligned (train/prefill default).
    diag_off = q_start if q_start is not None else t - s

    kt_c = kt.reshape(b, kv, n_k, kc, hd).transpose(2, 0, 1, 3, 4)  # (n_k,B,KV,kc,hd)
    vt_c = vt.reshape(b, kv, n_k, kc, hd).transpose(2, 0, 1, 3, 4)

    def q_block(carry, qi):
        del carry
        qblk = jax.lax.dynamic_slice_in_dim(qg, qi * qc, qc, axis=3)
        row0 = qi * qc + diag_off

        def kv_step(state, inputs):
            ki, kblk, vblk = inputs
            m_prev, l_prev, acc = state
            m_c, l_c, o_c = _attn_chunk(
                qblk, kblk, vblk, row0, ki * kc, causal=causal, window=window, scale=scale, valid_len=valid_len
            )
            m_new = jnp.maximum(m_prev, m_c)
            corr = jnp.exp(m_prev - m_new)
            corr_c = jnp.exp(m_c - m_new)
            l_new = l_prev * corr + l_c * corr_c
            acc = acc * corr[..., None] + o_c * corr_c[..., None]
            return (m_new, l_new, acc), None

        init = (
            jnp.full((b, kv, g, qc), _NEG_INF, jnp.float32),
            jnp.zeros((b, kv, g, qc), jnp.float32),
            jnp.zeros((b, kv, g, qc, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (jnp.arange(n_k), kt_c, vt_c))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(n_q))
    # blocks: (n_q, B, KV, G, qc, hd) -> (B, S, H, hd)
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(b, kv, g, n_q * qc, hd)
    out = out[:, :, :, :s].transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)
    return out


def decode_attention_jnp(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid_len: jax.Array,
    *,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """One-token attention over a (B, T, KV, hd) cache.

    ``valid_len`` (B,): number of valid cache slots per sequence (supports
    both linear caches — pos+1 — and full ring buffers — min(pos+1, W)).

    int8-quantized caches pass per-(B,T,KV) ``k_scale``/``v_scale``; the
    dequant folds into the einsums (logits *= k_scale along t; probs *=
    v_scale before the value einsum) so only int8 bytes leave HBM (§Perf).
    """
    b, one, h, hd = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / (hd**0.5)
    qg = q.reshape(b, kv, g, hd)
    kc = k_cache.astype(q.dtype) if k_cache.dtype == jnp.int8 else k_cache
    # preferred_element_type keeps the accumulate in f32 WITHOUT materializing
    # an f32 copy of the (huge, resident) cache — §Perf: halves decode HBM
    # traffic vs .astype(f32) on the cache operands.
    logits = (
        jnp.einsum("bkgh,btkh->bkgt", qg, kc, preferred_element_type=jnp.float32) * scale
    )
    if k_scale is not None:
        logits = logits * k_scale.transpose(0, 2, 1)[:, :, None, :]  # (B,KV,1,T)
    cols = jnp.arange(t)[None, :]
    mask = cols < valid_len[:, None]
    logits = jnp.where(mask[:, None, None, :], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        p = p * v_scale.transpose(0, 2, 1)[:, :, None, :]
    vc = v_cache.astype(q.dtype) if v_cache.dtype == jnp.int8 else v_cache
    out = jnp.einsum("bkgt,btkh->bkgh", p.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV quantization (per-token, per-kv-head absmax scales) — §Perf
# ---------------------------------------------------------------------------
def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(…, KV, hd) -> int8 values + f32 scales over the hd axis."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    s = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


# ---------------------------------------------------------------------------
# attention layer (GQA + optional cross-attention), cache-aware
# ---------------------------------------------------------------------------
def init_attention(rng, cfg, dtype=jnp.float32, n_layers: int | None = None) -> dict:
    """Stacked (n_layers leading dim) attention projection params."""
    n = n_layers if n_layers is not None else cfg.n_layers
    ks = jax.random.split(rng, 4)
    p = {
        "wq": stacked_dense_init(ks[0], n, cfg.d_model, cfg.q_dim, dtype),
        "wk": stacked_dense_init(ks[1], n, cfg.d_model, cfg.kv_dim, dtype),
        "wv": stacked_dense_init(ks[2], n, cfg.d_model, cfg.kv_dim, dtype),
        "wo": stacked_dense_init(ks[3], n, cfg.q_dim, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n, cfg.q_dim), dtype)
        p["bk"] = jnp.zeros((n, cfg.kv_dim), dtype)
        p["bv"] = jnp.zeros((n, cfg.kv_dim), dtype)
    return p


def attention_layer(
    p: dict,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    use_rope: bool = True,
    kv_input: jax.Array | None = None,
    cache: tuple[jax.Array, jax.Array] | None = None,
    cache_positions: jax.Array | None = None,
    cache_valid: jax.Array | None = None,
    chunk_start: jax.Array | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """GQA attention for one layer (params already sliced to this layer).

    Modes:
      * self-attention over x (train/prefill): kv_input is None, cache None.
      * cross-attention: kv_input is the memory sequence (no rope/causal).
      * cached decode: cache = (k_cache, v_cache) of shape (B, T, KV, hd),
        cache_positions (B,) current write positions; returns updated cache.
      * chunk append (chunked prefill): cache set, s > 1, ``chunk_start`` a
        traced scalar — writes k/v at absolute positions
        ``[chunk_start, chunk_start + s)`` and attends causally over the
        whole cache from those positions; returns updated cache.
    """
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = ops.matmul(x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    kv_src = kv_input if kv_input is not None else x
    k = ops.matmul(kv_src, p["wk"])
    v = ops.matmul(kv_src, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, kv_src.shape[1], kvh, hd)
    v = v.reshape(b, kv_src.shape[1], kvh, hd)
    if use_rope and kv_input is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        quant = len(cache) == 4  # (k, v, k_scale, v_scale): int8 KV cache
        if quant:
            k_cache, v_cache, ks_cache, vs_cache = cache
        else:
            k_cache, v_cache = cache
        if s == 1 and chunk_start is None:  # decode: write one token
            bidx = jnp.arange(b)
            if quant:
                kq, ks = quantize_kv(k[:, 0])
                vq, vs = quantize_kv(v[:, 0])
                k_cache = k_cache.at[bidx, cache_positions].set(kq)
                v_cache = v_cache.at[bidx, cache_positions].set(vq)
                ks_cache = ks_cache.at[bidx, cache_positions].set(ks)
                vs_cache = vs_cache.at[bidx, cache_positions].set(vs)
            else:
                k_cache = k_cache.at[bidx, cache_positions].set(k[:, 0])
                v_cache = v_cache.at[bidx, cache_positions].set(v[:, 0])
            valid = cache_valid if cache_valid is not None else cache_positions + 1
            out = decode_attention_jnp(
                q, k_cache, v_cache, valid,
                k_scale=ks_cache if quant else None,
                v_scale=vs_cache if quant else None,
            )
            new_cache = (k_cache, v_cache, ks_cache, vs_cache) if quant else (k_cache, v_cache)
        elif chunk_start is not None:  # chunk append: write [start, start+s)
            if quant:
                raise NotImplementedError(
                    "chunked prefill does not support int8 KV caches"
                )
            # Scatter with mode="drop" (not dynamic_update_slice, which would
            # clamp a partially-out-of-range start and corrupt real tokens):
            # a padded final chunk may extend past cache_len — those writes
            # must vanish, and pad rows inside range are causally dead.
            pos = chunk_start + jnp.arange(s)
            k_cache = k_cache.at[:, pos].set(k, mode="drop")
            v_cache = v_cache.at[:, pos].set(v, mode="drop")
            out = flash_attention_jnp(
                q, k_cache, v_cache, causal=causal, window=window,
                q_start=chunk_start,
            )
            new_cache = (k_cache, v_cache)
        else:  # prefill: write the whole prefix
            t_cache = k_cache.shape[1]
            if quant:
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                writes = ((k_cache, kq), (v_cache, vq), (ks_cache, ks), (vs_cache, vs))
            else:
                writes = ((k_cache, k), (v_cache, v))
            written = []
            for dst, src in writes:
                if s >= t_cache:
                    # Ring buffer (SWA): keep the last t_cache tokens, placed
                    # at their ring slots p % t_cache so decode can continue.
                    start = (s - t_cache) % t_cache
                    written.append(jnp.roll(src[:, -t_cache:], start, axis=1))
                else:
                    written.append(jax.lax.dynamic_update_slice_in_dim(dst, src, 0, axis=1))
            out = flash_attention_jnp(q, k, v, causal=causal, window=window)
            new_cache = tuple(written)
    else:
        out = flash_attention_jnp(q, k, v, causal=causal and kv_input is None, window=window)
    out = ops.matmul(out.reshape(b, s, h * hd), p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(rng, cfg, dtype=jnp.float32, n_layers: int | None = None) -> dict:
    n = n_layers if n_layers is not None else cfg.n_layers
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": stacked_dense_init(ks[0], n, cfg.d_model, cfg.d_ff, dtype),
        "w_up": stacked_dense_init(ks[1], n, cfg.d_model, cfg.d_ff, dtype),
        "w_down": stacked_dense_init(ks[2], n, cfg.d_ff, cfg.d_model, dtype),
    }


def mlp_layer(p: dict, x: jax.Array) -> jax.Array:
    gate = ops.matmul(x, p["w_gate"])
    up = ops.matmul(x, p["w_up"])
    return ops.matmul(jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up, p["w_down"])


# ---------------------------------------------------------------------------
# embeddings / loss
# ---------------------------------------------------------------------------
def init_embedding(rng, cfg, dtype=jnp.float32) -> dict:
    pv = cfg.padded_vocab()
    ks = jax.random.split(rng, 2)
    p = {"embed": (jax.random.normal(ks[0], (pv, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(ks[1], (cfg.d_model, pv)) * 0.02).astype(dtype)
    return p


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    return p["embed"][tokens]


def logits_from_hidden(p: dict, x: jax.Array, cfg) -> jax.Array:
    if "unembed" in p:
        return ops.matmul(x, p["unembed"], out_dtype=jnp.float32)
    return ops.matmul(x, p["embed"].T, out_dtype=jnp.float32)


def cross_entropy_loss(logits: jax.Array, targets: jax.Array, vocab: int) -> jax.Array:
    """Mean token NLL; padded-vocab slots are masked out of the softmax."""
    pv = logits.shape[-1]
    if pv != vocab:
        pad_mask = jnp.arange(pv) >= vocab
        logits = jnp.where(pad_mask, _NEG_INF, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
