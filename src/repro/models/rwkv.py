"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

Training path uses the chunked linear-recurrence algorithm (intra-chunk
factored matmuls + inter-chunk state scan), which is how RWKV6/GLA run on
matmul hardware; decode is the O(1)-state recurrence.  The paper's GEMM
selection technique is inapplicable to the WKV recurrence itself (noted in
DESIGN.md §4); all projections still route through the tuned matmul.

Numerics: per-channel log-decay is clamped to [-5, -1e-3] and the chunk
length kept at 16 so the factored intra-chunk exponentials stay within f32
range (|cum| <= 80 -> e^80 ~ 5.5e34 < f32 max).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .layers import stacked_dense_init

_CHUNK = 16
_LOGW_MIN, _LOGW_MAX = -5.0, -1e-3
_DECAY_RANK = 64


def init_rwkv(rng, cfg, dtype=jnp.float32, n_layers: int | None = None) -> dict:
    n = n_layers if n_layers is not None else cfg.n_layers
    d, ff = cfg.d_model, cfg.d_ff
    h, hd = cfg.n_heads, cfg.head_dim
    ks = jax.random.split(rng, 10)
    mk = lambda key, a, b: stacked_dense_init(key, n, a, b, dtype)
    return {
        # time-mix
        "mu_r": jnp.full((n, d), 0.5, dtype),
        "mu_k": jnp.full((n, d), 0.5, dtype),
        "mu_v": jnp.full((n, d), 0.5, dtype),
        "mu_w": jnp.full((n, d), 0.5, dtype),
        "mu_g": jnp.full((n, d), 0.5, dtype),
        "w_r": mk(ks[0], d, h * hd),
        "w_k": mk(ks[1], d, h * hd),
        "w_v": mk(ks[2], d, h * hd),
        "w_g": mk(ks[3], d, h * hd),
        "w_o": mk(ks[4], h * hd, d),
        "w0": jnp.full((n, d), -1.0, dtype),  # base log-log decay
        "wd1": mk(ks[5], d, _DECAY_RANK),
        "wd2": mk(ks[6], _DECAY_RANK, d),
        "u": jnp.zeros((n, h, hd), dtype),  # per-head bonus
        "ln_w": jnp.ones((n, h, hd), dtype),  # per-head output norm
        # channel-mix
        "mu_cr": jnp.full((n, d), 0.5, dtype),
        "mu_ck": jnp.full((n, d), 0.5, dtype),
        "w_ck": mk(ks[7], d, ff),
        "w_cv": mk(ks[8], ff, d),
        "w_cr": mk(ks[9], d, d),
    }


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} along the sequence (prev fills t=0)."""
    shifted = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _time_mix_inputs(p, xn, x_prev, cfg):
    """Projections for the WKV op. xn: (B,S,d) normalized input."""
    b, s, d = xn.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xs = _shift(xn, x_prev)
    mix = lambda mu: xn * mu + xs * (1.0 - mu)
    r = ops.matmul(mix(p["mu_r"]), p["w_r"]).reshape(b, s, h, hd)
    k = ops.matmul(mix(p["mu_k"]), p["w_k"]).reshape(b, s, h, hd)
    v = ops.matmul(mix(p["mu_v"]), p["w_v"]).reshape(b, s, h, hd)
    g = ops.matmul(mix(p["mu_g"]), p["w_g"])
    # Data-dependent per-channel decay (Finch): logw = -exp(w0 + lora(xw)).
    xw = mix(p["mu_w"])
    lora = ops.matmul(jnp.tanh(ops.matmul(xw, p["wd1"])), p["wd2"])
    logw = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    logw = jnp.clip(logw, _LOGW_MIN, _LOGW_MAX).reshape(b, s, h, hd)
    return r, k, v, g, logw


def _head_norm(o: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head RMS norm of the WKV output. o: (B,S,H,hd)."""
    of = o.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(of * of, axis=-1, keepdims=True) + eps)
    return of * scale * w.astype(jnp.float32)


def wkv_chunked(r, k, v, logw, u, state=None, chunk: int = _CHUNK):
    """Chunked WKV recurrence.

    r/k/v/logw: (B, S, H, hd) (f32 math); u: (H, hd).
    state: (B, H, hd, hd) initial (keys x values); defaults to zeros.
    Returns (o (B,S,H,hd) f32, final_state).
    """
    b, s, h, hd = r.shape
    rf, kf, vf, lw = (t.astype(jnp.float32) for t in (r, k, v, logw))
    pad = (-s) % chunk
    if pad:
        rf, kf, vf = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (rf, kf, vf))
        # Pad with zero decay + zero k/v: padding then alters neither the
        # outputs nor the carried state (exact for any pad length).
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=0.0)
    n_chunks = (s + pad) // chunk
    # (n, B, H, L, hd)
    resh = lambda t: t.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, lwc = resh(rf), resh(kf), resh(vf), resh(lw)
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)

    uu = u.astype(jnp.float32)[None, :, None, :]  # (1,H,1,hd)

    def step(S, inp):
        rr, kk, vv, ww = inp  # (B,H,L,hd)
        cum = jnp.cumsum(ww, axis=2)  # (B,H,L,hd), decreasing
        r_t = rr * jnp.exp(cum - ww)  # r̃_t = r_t e^{cum_{t-1}}
        k_t = kk * jnp.exp(-cum)  # k̃_τ = k_τ e^{-cum_τ}  (bounded by clamp)
        scores = jnp.einsum("bhlc,bhmc->bhlm", r_t, k_t)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(tri, scores, 0.0)
        diag = jnp.einsum("bhlc,bhlc->bhl", rr, uu * kk)
        o = jnp.einsum("bhlm,bhmv->bhlv", scores, vv) + diag[..., None] * vv
        o = o + jnp.einsum("bhlc,bhcv->bhlv", r_t, S)  # incoming-state term
        # State update: S' = e^{cum_L} ⊙_k S + Σ_τ (k_τ e^{cum_L - cum_τ}) v_τ^T
        decay_all = jnp.exp(cum[:, :, -1, :])  # (B,H,hd)
        k_hat = kk * jnp.exp(cum[:, :, -1:, :] - cum)
        S_new = decay_all[..., None] * S + jnp.einsum("bhlc,bhlv->bhcv", k_hat, vv)
        return S_new, o

    final_state, o_chunks = jax.lax.scan(step, state, (rc, kc, vc, lwc))
    o = o_chunks.transpose(1, 0, 3, 2, 4).reshape(b, n_chunks * chunk, h, hd)
    return o[:, :s], final_state


def wkv_decode_step(r, k, v, logw, u, state):
    """Single-token WKV. r/k/v/logw: (B,1,H,hd); state (B,H,hd,hd)."""
    rf, kf, vf = (t.astype(jnp.float32)[:, 0] for t in (r, k, v))  # (B,H,hd)
    lw = logw.astype(jnp.float32)[:, 0]
    uu = u.astype(jnp.float32)[None]
    kv = jnp.einsum("bhc,bhv->bhcv", kf, vf)
    o = jnp.einsum("bhc,bhcv->bhv", rf, state + uu[..., None] * kv)
    new_state = jnp.exp(lw)[..., None] * state + kv
    return o[:, None], new_state  # (B,1,H,hd)


def time_mix_layer(p, xn, cfg, *, state=None, x_prev=None):
    """Full RWKV6 time-mix sublayer on normalized input xn.

    Returns (out (B,S,d), (wkv_state, last_x)).
    """
    b, s, d = xn.shape
    r, k, v, g, logw = _time_mix_inputs(p, xn, x_prev, cfg)
    if s == 1 and state is not None:
        o, new_state = wkv_decode_step(r, k, v, logw, p["u"], state)
    else:
        # Dispatches to the Pallas WKV kernel when enabled (ops.wkv), else
        # the jnp reference below — identical math either way.
        o, new_state = ops.wkv(r, k, v, logw, p["u"], state)
    o = _head_norm(o, p["ln_w"])
    o = (o.reshape(b, s, -1) * jax.nn.silu(g.astype(jnp.float32))).astype(xn.dtype)
    return ops.matmul(o, p["w_o"]), (new_state, xn[:, -1])


def channel_mix_layer(p, xn, cfg, *, x_prev=None):
    """RWKV channel-mix sublayer. Returns (out, last_x)."""
    xs = _shift(xn, x_prev)
    xk = xn * p["mu_ck"] + xs * (1.0 - p["mu_ck"])
    xr = xn * p["mu_cr"] + xs * (1.0 - p["mu_cr"])
    k = ops.matmul(xk, p["w_ck"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(xn.dtype)
    kv = ops.matmul(k, p["w_cv"])
    return jax.nn.sigmoid(ops.matmul(xr, p["w_cr"]).astype(jnp.float32)).astype(xn.dtype) * kv, xn[:, -1]
