"""Deterministic, step-indexed synthetic token pipeline.

Design goals (1000+-node posture):

  * **Stateless addressing** — batch ``i`` is a pure function of
    ``(seed, step, shard)``; there is no iterator state to checkpoint.  Exact
    resume after preemption = "continue from step N".  Elastic resize =
    re-derive shards from the new topology; every host always computes only
    its own shard.
  * **Host-sharded** — each data-parallel host generates exactly its slice of
    the global batch (``host_index / host_count``); no cross-host traffic.
  * **Structured synthetic text** — a seeded Markov chain over the vocab (not
    iid-uniform) so the LM loss actually decreases and overfit bugs are
    visible in the examples; targets are next-token shifted.

The same pipeline serves all 10 architectures: the registry's ``input_specs``
decides which extra modality stubs (frames / image embeddings) are attached.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    host_index: int = 0
    host_count: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.host_count == 0, (self.global_batch, self.host_count)
        return self.global_batch // self.host_count


def _fold(*parts: int) -> np.random.Generator:
    """Deterministic RNG from structural coordinates (no global state)."""
    return np.random.default_rng(np.array(parts, dtype=np.uint64))


class MarkovChain:
    """Order-1 seeded Markov chain with a low-rank transition structure.

    Sampling is vectorized: states map to one of ``n_groups`` regimes, each
    regime has a peaked next-token distribution — cheap, deterministic, and
    learnable (a trained LM reaches materially lower loss than uniform).
    """

    def __init__(self, vocab: int, seed: int, n_groups: int = 64, peak: int = 8):
        self.vocab = vocab
        rng = _fold(seed, 0xC0FFEE)
        self.n_groups = min(n_groups, vocab)
        self.group_of = rng.integers(0, self.n_groups, size=vocab)
        # Each group strongly prefers `peak` particular successor tokens.
        self.peaks = rng.integers(0, vocab, size=(self.n_groups, peak))

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len), dtype=np.int32)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        # 85%: one of the group's peak tokens; 15%: uniform exploration.
        peak_choice = rng.integers(0, self.peaks.shape[1], size=(batch, seq_len))
        uniform = rng.integers(0, self.vocab, size=(batch, seq_len))
        explore = rng.random((batch, seq_len)) < 0.15
        for t in range(1, seq_len):
            g = self.group_of[out[:, t - 1]]
            nxt = self.peaks[g, peak_choice[:, t]]
            out[:, t] = np.where(explore[:, t], uniform[:, t], nxt)
        return out


class TokenPipeline:
    """``batch(step)`` -> host-local training batch for one architecture."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        self.chain = MarkovChain(cfg.vocab, data.seed)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        d = self.data
        rng = _fold(d.seed, step, d.host_index)
        b, s = d.local_batch, d.seq_len
        # +1 token then shift -> (tokens, targets).
        toks = self.chain.sample(rng, b, s + 1)
        out: dict[str, np.ndarray] = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
        }
        if self.cfg.family == "vlm":
            out["image_embs"] = rng.standard_normal(
                (b, self.cfg.n_image_tokens, self.cfg.d_model), dtype=np.float32
            ).astype(jnp.bfloat16)
        elif self.cfg.family == "audio":
            out["frames"] = rng.standard_normal((b, s, self.cfg.d_model), dtype=np.float32).astype(
                jnp.bfloat16
            )
        return out

    def device_batch(self, step: int, dtype=jnp.float32) -> dict[str, jax.Array]:
        np_batch = self.batch(step)
        return {
            k: jnp.asarray(v if v.dtype != np.float32 else v.astype(dtype))
            for k, v in np_batch.items()
        }


def reshard(data: DataConfig, host_index: int, host_count: int) -> DataConfig:
    """Elastic resize: same stream, new topology (stateless => trivial)."""
    return dataclasses.replace(data, host_index=host_index, host_count=host_count)
