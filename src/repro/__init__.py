"""ML-guided kernel selection for JAX/Pallas — the library facade.

The whole tune → deploy → serve → retune lifecycle in four lines::

    import repro

    bundle = repro.tune(["granite-8b"], devices=("tpu_v5e", "tpu_v4"))
    router = bundle.router(model, params)       # one engine per tuned device
    ticket = router.submit(prompt, latency_target_ms=8.0)
    for tok in ticket.tokens(): ...             # streams while the fleet serves

Single-engine serving is ``bundle.runtime(device=...).serve(model, params)``
— an explicit :class:`KernelRuntime` plus a :class:`ServingEngine` with the
same ``submit``/``step``/``drain`` surface (``repro.serve``).

Everything selection-related that a process does — which tuned policy is
live, the dispatch shape caches, the selection-telemetry log — belongs to an
explicit :class:`KernelRuntime` handle (DESIGN.md §10).  Handles are cheap;
build one per tenant/deployment and activate it around dispatch
(``with rt.activate(): ...``), or let a :class:`ServingEngine` own one.  Two
runtimes in one process are fully isolated: concurrent tunings, A/B shadow
policies, and test isolation without global teardown.

Submodule imports stay lazy (PEP 562): ``import repro`` pulls in neither JAX
nor the tuning stack until an attribute is touched.
"""
from __future__ import annotations

__version__ = "0.7.0"

__all__ = [
    "ArtifactRegistry",
    "ControlPlane",
    "ControlPlaneClient",
    "Deployment",
    "DeploymentBundle",
    "EngineStatus",
    "FaultPlan",
    "KernelRuntime",
    "PolicySubscriber",
    "Request",
    "Router",
    "ServingEngine",
    "TelemetrySnapshot",
    "Ticket",
    "__version__",
    "current_runtime",
    "default_runtime",
    "install_bundle",
    "load_bundle",
    "reset_default_runtime",
    "tune",
]

# name -> (module, attribute): resolved on first access, cached in globals().
_LAZY = {
    "ArtifactRegistry": ("repro.control.registry", "ArtifactRegistry"),
    "ControlPlane": ("repro.control.service", "ControlPlane"),
    "ControlPlaneClient": ("repro.control.client", "ControlPlaneClient"),
    "Deployment": ("repro.core.dispatch", "Deployment"),
    "DeploymentBundle": ("repro.core.bundle", "DeploymentBundle"),
    "FaultPlan": ("repro.core.faults", "FaultPlan"),
    "KernelRuntime": ("repro.core.runtime", "KernelRuntime"),
    "EngineStatus": ("repro.serve.engine", "EngineStatus"),
    "PolicySubscriber": ("repro.control.client", "PolicySubscriber"),
    "Request": ("repro.serve.engine", "Request"),
    "Router": ("repro.serve.router", "Router"),
    "ServingEngine": ("repro.serve.engine", "ServingEngine"),
    "Ticket": ("repro.serve.engine", "Ticket"),
    "TelemetrySnapshot": ("repro.core.retune", "TelemetrySnapshot"),
    "current_runtime": ("repro.core.runtime", "current_runtime"),
    "default_runtime": ("repro.core.runtime", "default_runtime"),
    "install_bundle": ("repro.core.bundle", "install_bundle"),
    "reset_default_runtime": ("repro.core.runtime", "reset_default_runtime"),
}


def tune(archs=None, *, devices=("tpu_v5e", "tpu_v4"), n_kernels: int = 8,
         families=None, **kwargs):
    """Tune every device and kernel family into one deployable bundle.

    The operator entry point (the paper's zero-developer-effort pitch):
    ``archs`` scopes the benchmark harvest to the model architectures you
    will actually launch (None = all registered), ``devices`` names the
    fleet (``host_cpu`` measures this host; TPU targets use the analytic
    perf model).  Returns a :class:`DeploymentBundle` — save it with
    ``bundle.save(path)``, serve it with ``bundle.runtime(device=...)``.
    Remaining keyword arguments pass through to
    :func:`repro.core.tuner.tune_fleet` (``method``, ``normalization``,
    ``classifier``, ``max_problems``, ...).
    """
    from repro.core.tuner import tune_fleet

    fleet = tune_fleet(
        list(archs) if archs is not None else None,
        device_names=tuple(devices), n_kernels=n_kernels, families=families,
        **kwargs,
    )
    return fleet.bundle


def load_bundle(path):
    """Load a saved :class:`DeploymentBundle` (any blob version, v1-v6).

    ``repro.load_bundle(path).runtime(device=...)`` is the serving-host
    bring-up path; plain v1/v2 single-device deployment files load as
    degenerate one-entry bundles.  ``path`` may also be a control-plane
    registry URI (``registry://host:port/name[/version]``) or a plain
    ``http(s)://`` URL — the artifact is fetched from a running
    :class:`repro.control.ControlPlane`.
    """
    from repro.core.bundle import DeploymentBundle

    return DeploymentBundle.load(path)


def __getattr__(name: str):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") from None
    import importlib

    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache: __getattr__ runs once per name
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
