"""repro.control — the tuning control plane (DESIGN.md §14).

Stdlib-only service + client turning the single-process tune→deploy→retune
loop fleet-wide: a job API running staged bring-up tunes in the background,
a content-hashed versioned artifact registry with tuning lineage, and
telemetry federation that merges per-device snapshots from many serving
hosts, drift-checks the aggregate, and pushes incremental-retune artifacts
to subscribed runtimes over a policy long-poll.

    from repro.control import ControlPlane, ControlPlaneClient, PolicySubscriber

    with ControlPlane(port=0) as plane:
        client = ControlPlaneClient(plane.url)
        job = client.submit({"devices": ["tpu_v5e"], "archs": ["granite-8b"]})
        client.wait_job(job["id"])
        bundle = repro.load_bundle(client.registry_uri("default"))
"""
from .client import ControlPlaneClient, ControlPlaneError, PolicySubscriber
from .registry import ArtifactRegistry, ArtifactVersion, content_version
from .service import ControlPlane, Job

__all__ = [
    "ArtifactRegistry",
    "ArtifactVersion",
    "ControlPlane",
    "ControlPlaneClient",
    "ControlPlaneError",
    "Job",
    "PolicySubscriber",
    "content_version",
]
