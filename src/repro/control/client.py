"""Control-plane client: job submission, telemetry posting, policy long-poll.

Stdlib-only (``urllib.request``) counterpart of
:class:`~repro.control.service.ControlPlane`.  Two pieces:

* :class:`ControlPlaneClient` — the request/response surface: submit and
  poll jobs, fetch artifacts (``registry://`` URIs resolve through
  :meth:`fetch_bundle`), post a runtime's :class:`TelemetrySnapshot`, and
  long-poll the per-device policy board.
* :class:`PolicySubscriber` — a background thread that long-polls
  ``GET /policy/<device>`` and delivers each newly announced artifact to a
  subscribed consumer: a :class:`~repro.serve.engine.ServingEngine` (via
  ``offer_deployment`` — adopted canary-gated on the next step boundary) or
  a bare :class:`~repro.core.runtime.KernelRuntime` (via
  ``apply_policy_update``).
"""
from __future__ import annotations

import json
import threading
import time
import urllib.error
import urllib.request


class ControlPlaneError(RuntimeError):
    """A control-plane request failed (HTTP error or unreachable service)."""


class ControlPlaneClient:
    """HTTP client for one control-plane service (``base_url`` = plane.url)."""

    def __init__(self, base_url: str, *, timeout: float = 30.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    # -- transport ---------------------------------------------------------------
    def _request(self, method: str, path: str, body: dict | None = None,
                 *, timeout: float | None = None):
        url = f"{self.base_url}{path}"
        data = json.dumps(body).encode() if body is not None else None
        req = urllib.request.Request(
            url, data=data, method=method,
            headers={"Content-Type": "application/json"} if data else {},
        )
        try:
            with urllib.request.urlopen(req, timeout=timeout or self.timeout) as resp:
                if resp.status == 204:
                    return None
                return json.loads(resp.read().decode("utf-8"))
        except urllib.error.HTTPError as e:
            try:
                detail = json.loads(e.read().decode("utf-8")).get("error", "")
            except Exception:  # noqa: BLE001 — error body is best-effort
                detail = ""
            raise ControlPlaneError(
                f"{method} {url} -> HTTP {e.code}" + (f": {detail}" if detail else "")
            ) from e
        except (urllib.error.URLError, OSError, TimeoutError) as e:
            raise ControlPlaneError(f"{method} {url} failed: {e}") from e

    # -- surface -----------------------------------------------------------------
    def healthz(self) -> dict:
        return self._request("GET", "/healthz")

    def submit(self, spec: dict) -> dict:
        """``POST /jobs``: returns the created job record (state ``queued``)."""
        return self._request("POST", "/jobs", spec)

    def job(self, job_id: str) -> dict:
        return self._request("GET", f"/jobs/{job_id}")

    def jobs(self) -> list[dict]:
        return self._request("GET", "/jobs")

    def wait_job(self, job_id: str, *, timeout: float = 600.0,
                 poll_interval: float = 0.2) -> dict:
        """Poll one job to a terminal state; raises on timeout."""
        deadline = time.monotonic() + timeout
        while True:
            job = self.job(job_id)
            if job["state"] in ("succeeded", "failed"):
                return job
            if time.monotonic() > deadline:
                raise ControlPlaneError(
                    f"job {job_id} still {job['state']!r} after {timeout:.0f}s"
                )
            time.sleep(poll_interval)

    def artifacts(self) -> dict:
        return self._request("GET", "/artifacts")

    def artifact(self, name: str, version: str = "latest") -> dict:
        """The registry envelope (record + bundle blob) for one version."""
        return self._request("GET", f"/artifacts/{name}/{version}")

    def registry_uri(self, name: str, version: str = "latest") -> str:
        """The ``registry://`` URI ``repro.load_bundle`` opens for this artifact."""
        host = self.base_url.split("://", 1)[-1]
        return f"registry://{host}/{name}/{version}"

    def fetch_bundle(self, name: str, version: str = "latest"):
        """Fetch and parse one artifact as a ``DeploymentBundle``."""
        from repro.core.bundle import DeploymentBundle

        return DeploymentBundle.from_blob(self.artifact(name, version)["blob"])

    def post_telemetry(self, device: str, snapshot, *, host: str | None = None,
                       artifact: str = "default") -> dict:
        """``POST /telemetry`` one snapshot (object or wire dict); returns the ack."""
        wire = snapshot.to_json() if hasattr(snapshot, "to_json") else dict(snapshot)
        return self._request("POST", "/telemetry", {
            "device": device,
            "snapshot": wire,
            "artifact": artifact,
            **({"host": host} if host else {}),
        })

    def policy(self, device: str, *, after: int = 0,
               timeout: float = 25.0) -> dict | None:
        """One policy long-poll; ``None`` when nothing newer than ``after``."""
        return self._request(
            "GET", f"/policy/{device}?after={int(after)}&timeout={float(timeout)}",
            timeout=timeout + 10.0,
        )


class PolicySubscriber:
    """Background long-poller delivering policy-board updates to one consumer.

    ``target`` is a ``ServingEngine`` (delivery = ``offer_deployment``, so
    the artifact adopts canary-gated on the engine's next step boundary) or
    a ``KernelRuntime`` (delivery = ``apply_policy_update``, the immediate
    lock+epoch hot-swap).  ``start_from="current"`` (default) skips whatever
    the board already announced — only *new* versions after subscription are
    delivered; ``start_from=0`` replays the newest existing entry first.
    ``updates`` records every delivered board entry, newest last.
    """

    def __init__(
        self,
        client: ControlPlaneClient,
        device: str,
        target,
        *,
        artifact: str = "default",
        start_from: int | str = "current",
        poll_timeout: float = 10.0,
    ):
        self.client = client
        self.device = device
        self.target = target
        self.artifact = artifact
        self.poll_timeout = poll_timeout
        self.updates: list[dict] = []
        self.errors: list[str] = []
        self._start_from = start_from
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "PolicySubscriber":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._run, name=f"policy-subscriber[{self.device}]", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.poll_timeout + 15.0)
            self._thread = None

    def __enter__(self) -> "PolicySubscriber":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _initial_seq(self) -> int:
        if self._start_from != "current":
            return int(self._start_from)
        try:
            ent = self.client.policy(self.device, after=0, timeout=0.0)
        except ControlPlaneError:
            return 0
        return int(ent["seq"]) if ent else 0

    def _deliver(self, ent: dict) -> None:
        bundle = self.client.fetch_bundle(ent["name"], ent["version"])
        dep, _resolved = bundle.deployment_for(self.device)
        if hasattr(self.target, "offer_deployment"):
            self.target.offer_deployment(dep, source="control-plane")
        elif hasattr(self.target, "apply_policy_update"):
            self.target.apply_policy_update(dep, self.device)
        else:
            raise TypeError(
                f"subscriber target {type(self.target).__name__} accepts neither "
                "offer_deployment (engine) nor apply_policy_update (runtime)"
            )
        self.updates.append(dict(ent))

    def _run(self) -> None:
        seq = self._initial_seq()
        while not self._stop.is_set():
            try:
                ent = self.client.policy(
                    self.device, after=seq, timeout=self.poll_timeout
                )
            except ControlPlaneError as e:
                if self._stop.is_set():
                    return
                self.errors.append(str(e))
                self._stop.wait(0.5)  # transient: back off and re-poll
                continue
            if ent is None:
                continue  # long-poll timed out: nothing newer yet
            if self.artifact and ent.get("name") != self.artifact:
                seq = int(ent["seq"])
                continue  # another artifact's announcement; not ours
            try:
                self._deliver(ent)
            except (ControlPlaneError, KeyError, TypeError) as e:
                self.errors.append(str(e))
            seq = int(ent["seq"])
