"""The tuning control plane: job service + registry + telemetry federation.

Stdlib-only (``http.server.ThreadingHTTPServer``): the service that makes
the tune→deploy→retune loop operable as a *fleet* instead of a process
(DESIGN.md §14).  Three coupled surfaces:

**Job API.**  ``POST /jobs`` accepts a tune spec (``device``/``devices``,
``families``, ``archs``, ``transfer``, ``prune_ratio``, ``measure_budget``
— including ``"auto"``) and runs the staged bring-up
(:func:`repro.core.tuner.tune_fleet`: ``devices.transfer_order``, donors
first) on a background worker.  Jobs move ``queued → running →
succeeded/failed`` with a timestamped history; ``GET /jobs/<id>`` polls,
``GET /healthz`` liveness-checks.

**Artifact registry.**  Every produced bundle is published to an
:class:`~repro.control.registry.ArtifactRegistry` — content-hashed
(same spec → same version), stored with its tuning lineage, fetchable via
``GET /artifacts/<name>/<version>`` (and ``latest``).
``repro.load_bundle("registry://host:port/name")`` opens it directly.

**Telemetry federation.**  Serving hosts ``POST /telemetry`` serialized
:class:`~repro.core.retune.TelemetrySnapshot`\\ s; the service merges them
per device (the commutative ``merge`` — arrival order cannot change the
verdict), runs :func:`~repro.core.retune.detect_drift_all` against the
artifact's provenance, and auto-schedules an incremental-retune job when a
family triggers.  The retuned bundle is published as a child version and
announced on the per-device **policy board**; subscribed runtimes long-poll
``GET /policy/<device>`` and feed the new artifact into the canary-gated,
rollback-protected hot-swap (``ServingEngine.adopt_deployment``).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import queue
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.core.retune import (
    DEFAULT_DRIFT_THRESHOLD,
    DEFAULT_MIN_EVENTS,
    TelemetrySnapshot,
    detect_drift_all,
    incremental_retune,
)

from .registry import ArtifactRegistry

DEFAULT_ARTIFACT = "default"


@dataclasses.dataclass
class Job:
    """One control-plane job and its lifecycle record."""

    id: str
    kind: str  # "tune" | "retune"
    spec: dict
    state: str = "queued"
    error: str | None = None
    artifact: dict | None = None  # {"name": ..., "version": ...} on success
    history: list = dataclasses.field(default_factory=list)  # [(state, t)]

    def transition(self, state: str) -> None:
        self.state = state
        self.history.append((state, time.time()))

    def to_json(self) -> dict:
        return {
            "id": self.id,
            "kind": self.kind,
            "spec": self.spec,
            "state": self.state,
            "error": self.error,
            "artifact": self.artifact,
            "history": [[s, t] for s, t in self.history],
        }


class ControlPlane:
    """In-process tuning control plane (service object + HTTP front end).

    ``port=0`` binds an ephemeral port (read ``plane.port`` after
    :meth:`start`); ``registry_root`` persists published artifacts to disk.
    ``tuner`` overrides the bring-up runner (``callable(spec) -> bundle``) —
    the test seam for fast or deliberately crashing tunes; the default runs
    :func:`repro.core.tuner.tune_fleet`.  Usable as a context manager::

        with ControlPlane(port=0) as plane:
            client = ControlPlaneClient(plane.url)
            job = client.submit({"devices": ["tpu_v5e"], "archs": ["granite-8b"]})
    """

    def __init__(
        self,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        registry: ArtifactRegistry | None = None,
        registry_root=None,
        drift_threshold: float = DEFAULT_DRIFT_THRESHOLD,
        min_events: int = DEFAULT_MIN_EVENTS,
        tuner=None,
    ):
        self.host = host
        self.port = port
        self.registry = registry if registry is not None else ArtifactRegistry(registry_root)
        self.drift_threshold = drift_threshold
        self.min_events = min_events
        self._tuner = tuner
        self._lock = threading.RLock()
        self._jobs: dict[str, Job] = {}
        self._job_ids = itertools.count(1)
        self._queue: queue.Queue = queue.Queue()
        # Telemetry federation: one merged snapshot per device.
        self._federation: dict[str, TelemetrySnapshot] = {}
        self._federation_hosts: dict[str, set] = {}
        # Policy board: device -> {"seq", "name", "version", "job"};
        # long-pollers wait on the condition for a seq advance.
        self._policy_cond = threading.Condition(self._lock)
        self._policy: dict[str, dict] = {}
        self._server: ThreadingHTTPServer | None = None
        self._threads: list[threading.Thread] = []
        self._started = time.time()

    # -- lifecycle -------------------------------------------------------------
    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ControlPlane":
        if self._server is not None:
            return self
        handler = _make_handler(self)
        self._server = ThreadingHTTPServer((self.host, self.port), handler)
        self._server.daemon_threads = True
        self.port = self._server.server_address[1]
        self._started = time.time()
        serve = threading.Thread(
            target=self._server.serve_forever, name="control-plane-http", daemon=True
        )
        work = threading.Thread(
            target=self._worker, name="control-plane-worker", daemon=True
        )
        self._threads = [serve, work]
        serve.start()
        work.start()
        return self

    def stop(self) -> None:
        if self._server is None:
            return
        self._queue.put(None)  # worker sentinel
        self._server.shutdown()
        self._server.server_close()
        for t in self._threads:
            t.join(timeout=10.0)
        self._server = None
        self._threads = []
        with self._policy_cond:
            self._policy_cond.notify_all()  # release any parked long-pollers

    def __enter__(self) -> "ControlPlane":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- job API ---------------------------------------------------------------
    def submit_job(self, spec: dict) -> Job:
        """Validate, enqueue, and return one job (the ``POST /jobs`` body)."""
        if not isinstance(spec, dict):
            raise ValueError("job spec must be a JSON object")
        kind = str(spec.get("kind", "tune"))
        if kind not in ("tune", "retune"):
            raise ValueError(f"unknown job kind {kind!r} (tune | retune)")
        if kind == "retune" and not spec.get("device"):
            raise ValueError("a retune job spec needs a 'device'")
        with self._lock:
            job = Job(id=f"job-{next(self._job_ids):04d}", kind=kind, spec=dict(spec))
            job.transition("queued")
            self._jobs[job.id] = job
        self._queue.put(job.id)
        return job

    def job(self, job_id: str) -> Job:
        with self._lock:
            if job_id not in self._jobs:
                raise KeyError(f"no job {job_id!r}")
            return self._jobs[job_id]

    def jobs(self) -> list[Job]:
        with self._lock:
            return sorted(self._jobs.values(), key=lambda j: j.id)

    def _worker(self) -> None:
        """Background runner: jobs execute one at a time, in submit order."""
        while True:
            job_id = self._queue.get()
            if job_id is None:
                return
            job = self.job(job_id)
            job.transition("running")
            try:
                artifact = (
                    self._run_tune(job) if job.kind == "tune" else self._run_retune(job)
                )
            except Exception as e:  # noqa: BLE001 — a crashed tune is a *failed job*
                job.error = f"{type(e).__name__}: {e}"
                job.transition("failed")
                continue
            job.artifact = artifact
            job.transition("succeeded")

    # -- bring-up tunes ----------------------------------------------------------
    def _run_tune(self, job: Job) -> dict:
        spec = job.spec
        name = str(spec.get("name", DEFAULT_ARTIFACT))
        if self._tuner is not None:
            bundle = self._tuner(spec)
        else:
            from repro.core.tuner import tune_fleet

            devices = spec.get("devices") or [spec.get("device") or "tpu_v5e"]
            kwargs = dict(
                device_names=tuple(devices),
                transfer=bool(spec.get("transfer", False)),
                prune_ratio=spec.get("prune_ratio"),
                measure_budget=spec.get("measure_budget"),
            )
            for key in ("n_kernels", "max_problems", "seed"):
                if spec.get(key) is not None:
                    kwargs[key] = int(spec[key])
            if spec.get("families") is not None:
                kwargs["families"] = list(spec["families"])
            bundle = tune_fleet(spec.get("archs"), **kwargs).bundle
        rec = self.registry.publish(name, bundle, spec=spec)
        self._announce(list(bundle.devices), name, rec.version, job.id)
        return {"name": name, "version": rec.version, "devices": list(bundle.devices)}

    # -- federation + retune -----------------------------------------------------
    def handle_telemetry(
        self,
        device: str,
        snapshot: dict | TelemetrySnapshot,
        *,
        artifact: str = DEFAULT_ARTIFACT,
        host: str | None = None,
    ) -> dict:
        """Merge one host's snapshot; drift-check; maybe schedule a retune.

        The ``POST /telemetry`` core: the snapshot folds into the device's
        federated aggregate (commutative merge — host arrival order is
        irrelevant), the aggregate is checked against the artifact's
        provenance, and the first triggering report enqueues an
        incremental-retune job (deduplicated: one in-flight retune per
        device/artifact pair).
        """
        snap = (
            snapshot
            if isinstance(snapshot, TelemetrySnapshot)
            else TelemetrySnapshot.from_json(snapshot)
        )
        with self._lock:
            merged = self._federation.setdefault(device, TelemetrySnapshot())
            merged.merge(snap)
            if host:
                self._federation_hosts.setdefault(device, set()).add(str(host))
            n_hosts = len(self._federation_hosts.get(device) or ())
            events = merged.n_events
        drift: dict[str, dict] = {}
        retune_job = None
        try:
            bundle = self.registry.get_bundle(artifact)
            dep, _resolved = bundle.deployment_for(device)
        except KeyError:
            dep = None  # nothing deployed yet: merge-only, no verdict
        if dep is not None:
            with self._lock:
                reports = detect_drift_all(
                    self._federation[device], dep,
                    threshold=self.drift_threshold, min_events=self.min_events,
                )
            drift = {
                f: {
                    "score": round(r.score, 6),
                    "n_events": r.n_events,
                    "triggered": r.triggered,
                }
                for f, r in reports.items()
            }
            triggered = sorted(f for f, r in reports.items() if r.triggered)
            if triggered and not self._retune_pending(device, artifact):
                retune_job = self.submit_job({
                    "kind": "retune",
                    "device": device,
                    "artifact": artifact,
                    "families": triggered,
                }).id
        return {
            "device": device,
            "merged_events": events,
            "hosts": n_hosts,
            "drift": drift,
            "retune_job": retune_job,
        }

    def _retune_pending(self, device: str, artifact: str) -> bool:
        with self._lock:
            return any(
                j.kind == "retune"
                and j.state in ("queued", "running")
                and j.spec.get("device") == device
                and j.spec.get("artifact", DEFAULT_ARTIFACT) == artifact
                for j in self._jobs.values()
            )

    def _run_retune(self, job: Job) -> dict:
        from repro.core.bundle import DeploymentBundle

        spec = job.spec
        device = spec["device"]
        name = str(spec.get("artifact", DEFAULT_ARTIFACT))
        rec, blob = self.registry.get(name)
        bundle = DeploymentBundle.from_blob(blob)
        dep, resolved = bundle.deployment_for(device)
        with self._lock:
            snap = self._federation.get(device)
            snap = TelemetrySnapshot.from_json(snap.to_json()) if snap else None
        if snap is None or snap.n_events == 0:
            raise ValueError(f"no federated telemetry for device {device!r}")
        reports = detect_drift_all(
            snap, dep, threshold=self.drift_threshold, min_events=self.min_events
        )
        families = [f for f in (spec.get("families") or sorted(reports)) if f in reports]
        new_dep, retuned = dep, []
        for fam in families:
            new_dep = incremental_retune(
                new_dep, snap, family=fam, report=reports[fam],
                threshold=self.drift_threshold, min_events=self.min_events,
            ).deployment
            retuned.append(fam)
        if not retuned:
            raise ValueError(
                f"retune job had no family to refresh (asked: {spec.get('families')})"
            )
        new_bundle = DeploymentBundle(
            deployments={**bundle.deployments, resolved: new_dep},
            meta=dict(bundle.meta),
        )
        new_rec = self.registry.publish(name, new_bundle, spec=spec, parent=rec.version)
        with self._lock:
            # Fresh federation window: the next drift verdict is judged
            # against the *retuned* artifact's provenance, not stale traffic.
            self._federation.pop(device, None)
            self._federation_hosts.pop(device, None)
        self._announce([resolved], name, new_rec.version, job.id)
        return {
            "name": name,
            "version": new_rec.version,
            "parent": rec.version,
            "device": resolved,
            "families": retuned,
        }

    # -- policy board ------------------------------------------------------------
    def _announce(self, devices: list[str], name: str, version: str, job_id: str) -> None:
        with self._policy_cond:
            for dev in devices:
                prev = self._policy.get(dev) or {"seq": 0}
                self._policy[dev] = {
                    "device": dev,
                    "seq": int(prev["seq"]) + 1,
                    "name": name,
                    "version": version,
                    "job": job_id,
                }
            self._policy_cond.notify_all()

    def policy_state(self, device: str) -> dict | None:
        with self._lock:
            ent = self._policy.get(device)
            return dict(ent) if ent else None

    def wait_policy(self, device: str, after: int = 0, timeout: float = 25.0) -> dict | None:
        """Block until the device's policy board advances past ``after``.

        The long-poll core of ``GET /policy/<device>``: returns the newest
        entry once its seq exceeds ``after``, or ``None`` on timeout (the
        HTTP layer answers 204 and the subscriber re-polls).
        """
        deadline = time.monotonic() + max(float(timeout), 0.0)
        with self._policy_cond:
            while True:
                ent = self._policy.get(device)
                if ent and int(ent["seq"]) > int(after):
                    return dict(ent)
                remaining = deadline - time.monotonic()
                if remaining <= 0 or self._server is None:
                    return None
                self._policy_cond.wait(remaining)

    # -- health -------------------------------------------------------------------
    def health(self) -> dict:
        with self._lock:
            states: dict[str, int] = {}
            for j in self._jobs.values():
                states[j.state] = states.get(j.state, 0) + 1
            return {
                "status": "ok",
                "uptime_s": round(time.time() - self._started, 3),
                "jobs": states,
                "artifacts": {
                    n: len(self.registry.versions(n)) for n in self.registry.names()
                },
                "devices": sorted(self._policy),
            }


# ---------------------------------------------------------------------------
# HTTP front end
# ---------------------------------------------------------------------------
def _make_handler(plane: ControlPlane):
    class Handler(BaseHTTPRequestHandler):
        # One small JSON API; request logging is the caller's business.
        def log_message(self, *args):  # noqa: D102
            pass

        def _send(self, code: int, payload=None) -> None:
            body = b"" if payload is None else json.dumps(payload).encode()
            self.send_response(code)
            if body:
                self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            if body:
                self.wfile.write(body)

        def _body(self) -> dict:
            n = int(self.headers.get("Content-Length") or 0)
            raw = self.rfile.read(n) if n else b"{}"
            blob = json.loads(raw.decode("utf-8"))
            if not isinstance(blob, dict):
                raise ValueError("request body must be a JSON object")
            return blob

        def _route(self) -> tuple[list[str], dict]:
            path, _, q = self.path.partition("?")
            parts = [p for p in path.split("/") if p]
            params = {}
            for pair in q.split("&"):
                if "=" in pair:
                    k, _, v = pair.partition("=")
                    params[k] = v
            return parts, params

        def do_GET(self) -> None:  # noqa: N802 (http.server API)
            parts, params = self._route()
            try:
                if parts == ["healthz"]:
                    return self._send(200, plane.health())
                if parts == ["jobs"]:
                    return self._send(200, [j.to_json() for j in plane.jobs()])
                if len(parts) == 2 and parts[0] == "jobs":
                    return self._send(200, plane.job(parts[1]).to_json())
                if parts == ["artifacts"]:
                    return self._send(200, {
                        n: [r.to_json() for r in plane.registry.versions(n)]
                        for n in plane.registry.names()
                    })
                if len(parts) == 2 and parts[0] == "artifacts":
                    return self._send(
                        200, [r.to_json() for r in plane.registry.versions(parts[1])]
                    )
                if len(parts) == 3 and parts[0] == "artifacts":
                    rec, blob = plane.registry.get(parts[1], parts[2])
                    return self._send(
                        200, {"format": "artifact", **rec.to_json(), "blob": blob}
                    )
                if len(parts) == 2 and parts[0] == "policy":
                    ent = plane.wait_policy(
                        parts[1],
                        after=int(params.get("after", 0)),
                        timeout=min(float(params.get("timeout", 25.0)), 60.0),
                    )
                    if ent is None:
                        return self._send(204)  # nothing newer: re-poll
                    return self._send(200, ent)
                return self._send(404, {"error": f"no route for GET {self.path}"})
            except KeyError as e:
                return self._send(404, {"error": str(e)})
            except ValueError as e:
                return self._send(400, {"error": str(e)})

        def do_POST(self) -> None:  # noqa: N802 (http.server API)
            parts, _params = self._route()
            try:
                body = self._body()
                if parts == ["jobs"]:
                    job = plane.submit_job(body)
                    return self._send(202, job.to_json())
                if parts == ["telemetry"]:
                    for key in ("device", "snapshot"):
                        if key not in body:
                            raise ValueError(f"telemetry post needs {key!r}")
                    ack = plane.handle_telemetry(
                        str(body["device"]),
                        body["snapshot"],
                        artifact=str(body.get("artifact", DEFAULT_ARTIFACT)),
                        host=body.get("host"),
                    )
                    return self._send(200, ack)
                return self._send(404, {"error": f"no route for POST {self.path}"})
            except (ValueError, KeyError) as e:
                return self._send(400, {"error": str(e)})

    return Handler
