"""Versioned, content-addressed artifact registry for tuned bundles.

Every bundle the control plane produces is published here: the **version is
the content hash** (sha256 of the bundle's canonical JSON, truncated to 12
hex chars), so publishing the same spec twice — the tuning pipeline is
deterministic for a fixed spec — lands on the same version instead of
minting a duplicate, while any change to the spec (archs, devices, budgets)
changes the blob and therefore the version.  Each version carries its
**tuning lineage**: the submitted spec, the parent version it was retuned
from (``None`` for a bring-up tune), and the bundle's own per-device
provenance block (train distributions, retune log, staged-pipeline cost
records).

The registry is an in-process object (the :class:`~repro.control.service.
ControlPlane` serves it over ``GET /artifacts/...``) with optional directory
persistence: with ``root`` set, every version is written to
``<root>/<name>/<version>.json`` and reloaded on construction, so a
restarted control plane still serves every artifact it ever produced.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
import time
from pathlib import Path


def content_version(blob: dict) -> str:
    """The content-hash version of a bundle blob (12 hex chars of sha256)."""
    payload = json.dumps(blob, sort_keys=True, separators=(",", ":")).encode()
    return hashlib.sha256(payload).hexdigest()[:12]


@dataclasses.dataclass(frozen=True)
class ArtifactVersion:
    """One published bundle version and its tuning lineage."""

    name: str
    version: str  # content hash — same blob, same version
    seq: int  # publish order within the name (latest = highest)
    created: float  # wall time of first publish
    lineage: dict  # {"spec": ..., "parent": ..., "provenance": {...}}

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "version": self.version,
            "seq": self.seq,
            "created": self.created,
            "lineage": self.lineage,
        }

    @staticmethod
    def from_json(rec: dict) -> "ArtifactVersion":
        return ArtifactVersion(
            name=str(rec["name"]),
            version=str(rec["version"]),
            seq=int(rec["seq"]),
            created=float(rec.get("created", 0.0)),
            lineage=dict(rec.get("lineage") or {}),
        )


class ArtifactRegistry:
    """Thread-safe versioned store of deployment bundles.

    ``publish`` is idempotent on content: re-publishing a byte-identical
    blob under the same name returns the existing :class:`ArtifactVersion`
    (no new version, no index churn).  ``get(name)`` / ``get(name,
    "latest")`` resolve to the most recently *published* version — lineage
    order, not hash order.
    """

    def __init__(self, root: str | Path | None = None):
        self._lock = threading.RLock()
        # name -> version -> (ArtifactVersion, blob); publish order per name.
        self._store: dict[str, dict[str, tuple[ArtifactVersion, dict]]] = {}
        self._order: dict[str, list[str]] = {}
        self.root = Path(root) if root is not None else None
        if self.root is not None:
            self._reload()

    # -- publish ---------------------------------------------------------------
    def publish(
        self,
        name: str,
        bundle,
        *,
        spec: dict | None = None,
        parent: str | None = None,
    ) -> ArtifactVersion:
        """Version and store one bundle (a ``DeploymentBundle`` or its blob).

        Returns the (possibly pre-existing) :class:`ArtifactVersion`.
        """
        blob = bundle.to_blob() if hasattr(bundle, "to_blob") else dict(bundle)
        version = content_version(blob)
        with self._lock:
            versions = self._store.setdefault(name, {})
            if version in versions:
                return versions[version][0]  # idempotent: same content, same version
            lineage = {
                "spec": dict(spec) if spec else {},
                "parent": parent,
                "provenance": blob.get("provenance") or {},
            }
            rec = ArtifactVersion(
                name=name,
                version=version,
                seq=len(self._order.setdefault(name, [])),
                created=time.time(),
                lineage=lineage,
            )
            versions[version] = (rec, blob)
            self._order[name].append(version)
            if self.root is not None:
                self._persist(rec, blob)
            return rec

    # -- lookup ----------------------------------------------------------------
    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._store)

    def versions(self, name: str) -> list[ArtifactVersion]:
        """Publish-ordered versions of one artifact (oldest first)."""
        with self._lock:
            if name not in self._store:
                raise KeyError(f"no artifact named {name!r} (have: {self.names()})")
            return [self._store[name][v][0] for v in self._order[name]]

    def latest(self, name: str) -> ArtifactVersion:
        return self.versions(name)[-1]

    def get(self, name: str, version: str = "latest") -> tuple[ArtifactVersion, dict]:
        """``(record, bundle blob)`` for one version (``"latest"`` resolves)."""
        with self._lock:
            if name not in self._store:
                raise KeyError(f"no artifact named {name!r} (have: {self.names()})")
            if version == "latest":
                version = self._order[name][-1]
            if version not in self._store[name]:
                have = self._order[name]
                raise KeyError(
                    f"artifact {name!r} has no version {version!r} (have: {have})"
                )
            return self._store[name][version]

    def get_bundle(self, name: str, version: str = "latest"):
        """The parsed ``DeploymentBundle`` for one version."""
        from repro.core.bundle import DeploymentBundle

        _rec, blob = self.get(name, version)
        return DeploymentBundle.from_blob(blob)

    # -- persistence -------------------------------------------------------------
    def _persist(self, rec: ArtifactVersion, blob: dict) -> None:
        d = self.root / rec.name
        d.mkdir(parents=True, exist_ok=True)
        payload = {"format": "artifact", **rec.to_json(), "blob": blob}
        (d / f"{rec.version}.json").write_text(json.dumps(payload))

    def _reload(self) -> None:
        if not self.root.exists():
            return
        recs: list[tuple[ArtifactVersion, dict]] = []
        for path in self.root.glob("*/*.json"):
            try:
                payload = json.loads(path.read_text())
                recs.append((ArtifactVersion.from_json(payload), payload["blob"]))
            except (ValueError, KeyError):
                continue  # a torn write never blocks the rest of the store
        for rec, blob in sorted(recs, key=lambda rb: (rb[0].name, rb[0].seq)):
            self._store.setdefault(rec.name, {})[rec.version] = (rec, blob)
            self._order.setdefault(rec.name, []).append(rec.version)
