"""Sharding-agnostic atomic checkpoints with async writer and keep-k GC.

Fault-tolerance contract (1000+-node posture):

  * **Atomic**: a checkpoint is written to ``step_N.tmp/`` and renamed to
    ``step_N/`` only after every array and the manifest are on disk; readers
    never observe a torn checkpoint, and a crash mid-write leaves only a
    ``.tmp`` dir that the next GC removes.
  * **Sharding-agnostic format**: arrays are stored as full (unsharded)
    ``.npy`` files keyed by their pytree path.  Restore re-shards onto
    *whatever mesh the restoring job has* — the elastic-resize path: a 512-chip
    checkpoint restores onto 256 chips (or 1 CPU) unchanged.  (At real fleet
    scale each host would write its owned shards; the manifest/commit protocol
    is identical and this container has one host.)
  * **Async writer**: ``save_async`` snapshots params to host memory and
    writes on a background thread — training continues during the write
    (collective/IO overlap). ``wait()`` joins before the next save or exit.
  * **keep-k GC** + ``latest_step`` discovery for auto-resume.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else (str(p.name) if hasattr(p, "name") else str(p.idx))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- discovery ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                if (p / _MANIFEST).exists():  # committed only
                    out.append(int(p.name.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None) -> None:
        """Blocking atomic save of a pytree of arrays."""
        flat = _flatten(tree)
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for key, arr in flat.items():
            fn = key.replace("/", "__") + ".npy"
            np.save(tmp / fn, arr)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "extra": extra or {},
        }
        (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # the commit point
        self._gc()

    def save_async(self, step: int, tree, *, extra: dict | None = None) -> None:
        """Snapshot to host memory now; write on a background thread."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)  # device->host now

        def work():
            try:
                self.save(step, host_tree, extra=extra)
            except BaseException as e:  # noqa: BLE001 — surfaced at next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------------
    def restore(self, step: int, like, *, shardings=None):
        """Restore into the structure of ``like`` (abstract or concrete).

        ``shardings``: optional matching pytree of NamedShardings — arrays are
        placed (re-sharded) as they load, so restore works on any mesh.
        """
        final = self.dir / f"step_{step}"
        manifest = json.loads((final / _MANIFEST).read_text())
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = None
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
        leaves = []
        for i, (path, leaf) in enumerate(paths):
            key = "/".join(
                str(p.key) if hasattr(p, "key") else (str(p.name) if hasattr(p, "name") else str(p.idx))
                for p in path
            )
            if key not in manifest["keys"]:
                raise KeyError(f"checkpoint step {step} missing array {key!r}")
            arr = np.load(final / (key.replace("/", "__") + ".npy"))
            expect = tuple(leaf.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {expect}")
            if shard_leaves is not None:
                leaves.append(jax.device_put(arr.astype(leaf.dtype), shard_leaves[i]))
            else:
                leaves.append(jax.numpy.asarray(arr.astype(leaf.dtype)))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]

    def restore_latest(self, like, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, shardings=shardings)
        return step, tree, extra

    # -- GC -------------------------------------------------------------------
    def _gc(self) -> None:
        for p in self.dir.iterdir():  # torn writes
            if p.name.endswith(".tmp"):
                shutil.rmtree(p, ignore_errors=True)
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
