"""Fault-tolerance runtime: preemption, stragglers, elastic resize.

On a real fleet each of these hooks binds to infrastructure signals (SIGTERM
from the scheduler, per-host step heartbeats, topology-change events).  The
*logic* is host-agnostic and fully exercised by tests on this single-host
container:

  * :class:`PreemptionGuard` — converts SIGTERM/SIGINT into a "checkpoint
    now, then exit cleanly" request the training loop polls between steps.
  * :class:`StragglerDetector` — rolling per-step wall-time percentiles; a
    step slower than ``threshold`` x median flags a straggler (on a fleet:
    per-host heartbeat times, same math).  The trainer's mitigation is to log
    + (optionally) trigger an elastic checkpoint so the scheduler can swap
    the slow host.
  * :func:`elastic_plan` — given old/new host counts, returns the resume plan
    (new DataConfig shards + whether the global batch stays divisible).
    Checkpoints are sharding-agnostic (see repro.ckpt), so resize = restore
    on the new mesh + re-derive data shards.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from collections import deque

from repro.data.pipeline import DataConfig, reshard


class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful 'save and exit' request (poll per step)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = threading.Event()
        self._signals = signals
        self._prev = {}

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False

    def _handler(self, signum, frame):
        self._requested.set()

    def request(self) -> None:  # for tests / in-process triggers
        self._requested.set()

    @property
    def preempted(self) -> bool:
        return self._requested.is_set()


class StragglerDetector:
    """Rolling step-time stats; flags steps slower than threshold x median."""

    def __init__(self, window: int = 50, threshold: float = 2.0, warmup: int = 5):
        self.window = window
        self.threshold = threshold
        self.warmup = warmup
        self.times: deque[float] = deque(maxlen=window)
        self.flagged: list[tuple[int, float, float]] = []  # (step, t, median)
        self._step = 0
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record the step; returns True if it was a straggler step."""
        assert self._t0 is not None, "stop() without start()"
        dt = time.monotonic() - self._t0
        self._t0 = None
        self._step += 1
        is_straggler = self.observe(dt)
        return is_straggler

    def observe(self, dt: float) -> bool:
        med = self.median()
        straggler = (
            len(self.times) >= self.warmup and med > 0 and dt > self.threshold * med
        )
        if straggler:
            self.flagged.append((self._step, dt, med))
        self.times.append(dt)
        return straggler

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    ok: bool
    reason: str
    data: DataConfig | None = None


def elastic_plan(data: DataConfig, new_host_index: int, new_host_count: int) -> ElasticPlan:
    """Resume plan after the fleet grows/shrinks.

    The checkpoint needs no conversion (sharding-agnostic). The only
    constraint is global-batch divisibility across the new host count.
    """
    if new_host_count <= 0:
        return ElasticPlan(False, "host count must be positive")
    if data.global_batch % new_host_count != 0:
        return ElasticPlan(
            False,
            f"global_batch={data.global_batch} not divisible by {new_host_count} hosts",
        )
    if not (0 <= new_host_index < new_host_count):
        return ElasticPlan(False, f"host index {new_host_index} out of range")
    return ElasticPlan(True, "ok", reshard(data, new_host_index, new_host_count))
