"""Trainer: data + train_step + checkpointing + fault-tolerance, composed.

The production loop (used by launch/train.py and the examples):

  * auto-resume from the latest committed checkpoint;
  * async checkpoint every ``ckpt_every`` steps (+ final), keep-k GC;
  * preemption guard: SIGTERM => checkpoint + clean exit (resumable);
  * straggler detector on per-step wall time;
  * deterministic step-indexed data => exact resume, elastic re-shard.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.core.faults import PreemptionGuard, StragglerDetector
from repro.optim import adamw
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    num_microbatches: int = 1
    dtype: object = jnp.float32


class Trainer:
    def __init__(self, model, arch_cfg, data_cfg: DataConfig, opt_cfg=None, tcfg=None):
        self.model = model
        self.arch_cfg = arch_cfg
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.tcfg = tcfg or TrainerConfig()
        self.pipeline = TokenPipeline(arch_cfg, data_cfg)
        self.ckpt = CheckpointManager(self.tcfg.ckpt_dir, keep=self.tcfg.ckpt_keep)
        self.straggler = StragglerDetector()
        self.step_fn = jax.jit(
            make_train_step(model, self.opt_cfg, num_microbatches=self.tcfg.num_microbatches),
            donate_argnums=(0, 1),
        )
        self.history: list[dict] = []

    # -- state init / resume --------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        return params, adamw.init(params)

    def _state_tree(self, params, opt_state):
        return {"params": params, "opt": opt_state._asdict()}

    def resume_or_init(self, seed: int = 0):
        params, opt_state = self.init_state(seed)
        like = self._state_tree(params, opt_state)
        got = self.ckpt.restore_latest(like)
        if got is None:
            return 0, params, opt_state
        step, tree, _extra = got
        opt = adamw.AdamWState(**tree["opt"])
        return step, tree["params"], opt

    # -- loop -------------------------------------------------------------------
    def train(self, *, seed: int = 0, stop_after: int | None = None):
        """Run to total_steps (or stop_after more steps); returns final metrics."""
        start, params, opt_state = self.resume_or_init(seed)
        end = self.tcfg.total_steps if stop_after is None else min(
            self.tcfg.total_steps, start + stop_after
        )
        metrics = {}
        with PreemptionGuard() as guard:
            for step in range(start, end):
                self.straggler.start()
                batch = self.pipeline.device_batch(step, dtype=self.tcfg.dtype)
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                if self.straggler.stop():
                    print(f"[ft] straggler step {step}: {self.straggler.times[-1]:.2f}s")
                if (step + 1) % self.tcfg.log_every == 0 or step + 1 == end:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step + 1
                    self.history.append(m)
                    print(
                        f"step {step + 1}/{self.tcfg.total_steps} "
                        f"loss={m.get('loss', float('nan')):.4f} "
                        f"gnorm={m.get('grad_norm', float('nan')):.2f}",
                        flush=True,
                    )
                if (step + 1) % self.tcfg.ckpt_every == 0:
                    self.ckpt.save_async(step + 1, self._state_tree(params, opt_state))
                if guard.preempted:
                    print(f"[ft] preemption at step {step + 1}: checkpointing and exiting")
                    self.ckpt.wait()
                    self.ckpt.save(step + 1, self._state_tree(params, opt_state))
                    return step + 1, params, opt_state, metrics
        self.ckpt.wait()
        self.ckpt.save(end, self._state_tree(params, opt_state))
        t = time.strftime("%H:%M:%S")
        print(f"[{t}] training done at step {end}")
        return end, params, opt_state, metrics
