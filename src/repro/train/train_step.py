"""Train-step construction: grads + AdamW + distributed-optimization knobs.

Knobs (all config-driven, exercised in §Perf iterations):
  * microbatch gradient accumulation (``num_microbatches``) — bounds
    activation memory and overlaps per-microbatch gradient reductions with
    the next microbatch's compute (XLA async collectives);
  * gradient compression: all-reduce in bf16 (``grad_dtype='bfloat16'``) —
    halves the DP-reduction bytes, with f32 accumulation inside AdamW;
  * remat is handled inside the models (per-layer ``jax.checkpoint``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import adamw


def make_train_step(model, opt_cfg: adamw.AdamWConfig, *, num_microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            k = num_microbatches
            micro = jax.tree.map(lambda a: a.reshape(k, a.shape[0] // k, *a.shape[1:]), batch)

            def acc(carry, mb):
                loss_sum, grads_sum = carry
                (loss, _), grads = grad_fn(params, mb)
                grads_sum = jax.tree.map(jnp.add, grads_sum, grads)
                return (loss_sum + loss, grads_sum), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss_sum / k
            grads = jax.tree.map(lambda g: g / k, grads)
            metrics = {"ce_loss": loss}
        if opt_cfg.grad_dtype == "bfloat16":
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        params, opt_state, opt_metrics = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
