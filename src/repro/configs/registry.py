"""Architecture registry: ``--arch`` lookup, input shapes, specs, GEMM harvest."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import ArchConfig
from .dbrx_132b import CONFIG as _dbrx
from .glm4_9b import CONFIG as _glm4
from .granite_8b import CONFIG as _granite
from .hymba_1_5b import CONFIG as _hymba
from .llama3_2_vision_90b import CONFIG as _llama_vis
from .phi4_mini_3_8b import CONFIG as _phi4
from .qwen2_5_32b import CONFIG as _qwen25
from .qwen3_moe_235b import CONFIG as _qwen3moe
from .rwkv6_7b import CONFIG as _rwkv6
from .seamless_m4t_v2 import CONFIG as _seamless

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        _phi4,
        _qwen25,
        _granite,
        _glm4,
        _llama_vis,
        _qwen3moe,
        _dbrx,
        _hymba,
        _seamless,
        _rwkv6,
    )
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only SSM/hybrid run it; the 8 pure
# full-attention archs skip it (documented in DESIGN.md §4).
_SUBQUADRATIC = {"hymba-1.5b", "rwkv6-7b"}


def get(arch: str) -> ArchConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}") from None


def shapes_for(arch: str) -> list[str]:
    cfg = get(arch)
    out = []
    for name in SHAPES:
        if name == "long_500k" and cfg.name not in _SUBQUADRATIC:
            continue
        out.append(name)
    return out


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) dry-run cell."""
    return [(a, s) for a in ARCHS for s in shapes_for(a)]


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for a in ARCHS:
        cfg = get(a)
        if cfg.name not in _SUBQUADRATIC:
            out.append((a, "long_500k", "pure full-attention arch; 500k ctx needs sub-quadratic attention"))
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------
def input_specs(arch: str, shape: str) -> dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch x shape) cell, as ShapeDtypeStructs.

    train/prefill: full-sequence tokens (+ stub modality embeddings).
    decode: one new token per sequence (the KV/SSM cache is built separately
    by the serving engine; see repro/serve/engine.py).
    """
    cfg = get(arch)
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if sp.kind in ("train", "prefill"):
        if cfg.family == "audio":
            # Stub frontend: precomputed frame embeddings for the encoder;
            # decoder consumes text tokens of the same nominal length.
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if cfg.family == "vlm":
                specs["image_embs"] = jax.ShapeDtypeStruct((b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
        if sp.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct(specs["tokens"].shape, i32)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        specs["positions"] = jax.ShapeDtypeStruct((b,), i32)
    return specs


# ---------------------------------------------------------------------------
# GEMM harvesting (tuning-dataset problems; paper §3 'matrix sizes from
# three popular neural networks' — here: from the assigned architectures)
# ---------------------------------------------------------------------------
def gemm_problems(arch: str, shape: str) -> list[tuple[int, int, int, int]]:
    """The (m, k, n, batch) GEMMs this arch launches for this input shape.

    The convention matches what ``repro.kernels.ops.matmul`` featurizes at
    trace time: projections run on un-flattened ``(B, S, D)`` activations, so
    they are recorded as ``(m=S, k, n, batch=B)`` (``m=1`` for decode) — NOT
    flattened to ``(B*S, k, n, 1)``.  Only GEMMs whose call sites genuinely
    flatten (the MoE router on ``(T, d)`` tokens) keep ``batch=1``; per-head
    attention internals and per-expert FFNs keep their own batch counts.
    """
    cfg = get(arch)
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    m_tok = 1 if sp.kind == "decode" else s  # per-sequence GEMM M at runtime
    tokens = b * m_tok  # flattened token count (router and capacity math)
    d, ff = cfg.d_model, cfg.d_ff
    probs: list[tuple[int, int, int, int]] = []

    def gemm(m, k, n, batch=1):
        probs.append((int(m), int(k), int(n), int(batch)))

    # attention / time-mix projections — launched on (B, S, D) activations
    if cfg.family == "ssm":
        for out in (cfg.q_dim, cfg.q_dim, cfg.q_dim, cfg.q_dim, d):  # r,k,v,g,o
            gemm(m_tok, d, out, b)
    else:
        gemm(m_tok, d, cfg.q_dim, b)  # Q
        gemm(m_tok, d, cfg.kv_dim, b)  # K
        gemm(m_tok, d, cfg.kv_dim, b)  # V
        gemm(m_tok, cfg.q_dim, d, b)  # out proj
        if sp.kind != "decode":
            # score/context GEMMs per head (flash-attn internal shapes)
            hd = cfg.head_dim
            gemm(s, hd, s, b * cfg.n_heads)
            gemm(s, s, hd, b * cfg.n_heads)
    # FFN
    if cfg.moe is not None:
        e, k_ = cfg.moe.n_experts, cfg.moe.top_k
        gemm(tokens, d, e)  # router (moe_ffn flattens to (T, d) before matmul)
        cap_tokens = max(1, (tokens * k_) // e)
        for _ in range(2):
            gemm(cap_tokens, d, ff, e)  # gate/up per expert
        gemm(cap_tokens, ff, d, e)  # down per expert
    else:
        gemm(m_tok, d, ff, b)
        gemm(m_tok, d, ff, b)
        gemm(m_tok, ff, d, b)
    # vocab head — (B, S, D) in train, (B, 1, D) in decode
    if sp.kind != "prefill":
        gemm(m_tok, d, cfg.padded_vocab(), b)
    if cfg.family == "vlm":
        gemm(m_tok, d, cfg.q_dim, b)  # cross-q
        gemm(cfg.n_image_tokens, d, cfg.kv_dim, b)
        gemm(cfg.n_image_tokens, d, cfg.kv_dim, b)
    if cfg.family == "hybrid":
        gemm(m_tok, d, 2 * d, b)  # mamba in-proj
        gemm(m_tok, d, d, b)  # mamba out-proj
    if sp.kind == "prefill":
        # Chunked prefill (repro/serve/engine.py): the serving tier replays
        # the same projections one lane at a time over scheduler-budgeted
        # chunk widths from the geometric bucket ladder, so those GEMMs are
        # harvested on-distribution too — batch=1, m=chunk width.  Train
        # shapes are untouched (the fig7 dataset is train_4k-only).
        for c in _chunk_widths(s):
            if cfg.family == "ssm":
                for out in (cfg.q_dim, cfg.q_dim, cfg.q_dim, cfg.q_dim, d):
                    gemm(c, d, out, 1)
            else:
                gemm(c, d, cfg.q_dim, 1)  # Q
                gemm(c, d, cfg.kv_dim, 1)  # K
                gemm(c, d, cfg.kv_dim, 1)  # V
                gemm(c, cfg.q_dim, d, 1)  # out proj
            if cfg.moe is not None:
                gemm(c, d, cfg.moe.n_experts)  # router on the chunk's tokens
            else:
                gemm(c, d, ff, 1)
                gemm(c, d, ff, 1)
                gemm(c, ff, d, 1)
    return probs


def _chunk_widths(seq_len: int, floor: int = 512) -> list[int]:
    """Chunk widths the serving ladder would use for ``seq_len`` prompts:
    geometric rungs from ``floor`` up to (exclusive) the sequence length."""
    widths, c = [], floor
    while c < seq_len:
        widths.append(c)
        c *= 2
    return widths
