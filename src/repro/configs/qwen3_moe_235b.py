"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B; hf] — MoE 128e top-8."""
from .base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    moe=MoESpec(n_experts=128, top_k=8),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
