"""dbrx-132b [hf:databricks/dbrx-base; unverified] — MoE 16e top-4."""
from .base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoESpec(n_experts=16, top_k=4),
    source="hf:databricks/dbrx-base; unverified",
)
