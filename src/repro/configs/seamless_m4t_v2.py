"""seamless-m4t-large-v2 [arXiv:2308.11596; hf] — enc-dec, multimodal.

Backbone only: the audio frontend is a STUB; ``input_specs()`` provides
precomputed frame embeddings for the encoder (per assignment).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,  # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    source="arXiv:2308.11596; hf",
)
