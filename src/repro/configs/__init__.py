from . import registry
from .base import ArchConfig, MoESpec
from .registry import ARCHS, SHAPES, all_cells, get, gemm_problems, input_specs, shapes_for, skipped_cells

__all__ = [
    "ARCHS",
    "SHAPES",
    "ArchConfig",
    "MoESpec",
    "all_cells",
    "gemm_problems",
    "get",
    "input_specs",
    "registry",
    "shapes_for",
    "skipped_cells",
]
