"""rwkv6-7b (Finch) [arXiv:2404.05892; hf] — attention-free, data-dependent decay."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # wkv heads (head_dim = 64)
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    source="arXiv:2404.05892; hf",
)
