"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Cross-attn image layers every 5th layer; modality frontend is a STUB —
``input_specs()`` provides precomputed patch embeddings (per assignment).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    cross_every=5,
    n_image_tokens=1024,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
