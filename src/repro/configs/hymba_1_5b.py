"""hymba-1.5b [arXiv:2411.13676; hf] — parallel attn + mamba heads.

Sliding-window attention on all but 3 global layers (first/middle/last),
so long-context decode keeps an O(window) KV cache.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    window=2048,
    source="arXiv:2411.13676; hf",
)
