"""Architecture config schema shared by all assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One selectable ``--arch`` configuration.

    ``family`` picks the model implementation:
      'dense'  — decoder-only transformer (GQA, RoPE, SwiGLU)
      'moe'    — dense backbone with MoE FFN every layer
      'vlm'    — dense backbone with cross-attention layers every
                 ``cross_every``-th layer over stubbed image embeddings
      'hybrid' — parallel attention + Mamba(SSM) heads per layer (Hymba)
      'audio'  — encoder-decoder (Seamless backbone; stubbed frame embeddings)
      'ssm'    — RWKV6 (attention-free)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    moe: MoESpec | None = None
    qkv_bias: bool = False  # qwen2.5
    cross_every: int = 0  # vlm: 1 cross-attn layer per this many layers
    n_image_tokens: int = 1024  # vlm stub frontend
    ssm_state: int = 0  # hybrid: mamba state size
    window: int = 0  # hybrid: sliding-window size for SWA layers
    n_enc_layers: int = 0  # audio: encoder depth (decoder uses n_layers)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""  # provenance note ([arXiv/hf; tier])

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def padded_vocab(self, multiple: int = 128) -> int:
        return ((self.vocab + multiple - 1) // multiple) * multiple

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "ssm":
            attn = 6 * d * d  # rwkv time-mix r/k/v/g/o + decay
        if self.moe is not None:
            ffn = self.moe.n_experts * 3 * d * ff + d * self.moe.n_experts
        else:
            ffn = 3 * d * ff
        block = attn + ffn + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        enc = self.n_enc_layers * block if self.family == "audio" else 0
        return L * block + enc + emb

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.n_params()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn = self.moe.top_k * 3 * d * ff + d * self.moe.n_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * d) + emb

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small_moe = None
        if self.moe is not None:
            small_moe = MoESpec(n_experts=min(self.moe.n_experts, 4), top_k=min(self.moe.top_k, 2))
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab=256,
            moe=small_moe,
            n_image_tokens=16,
            cross_every=2 if self.cross_every else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            window=32 if self.window else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
        )
