"""Paper Fig. 2: how often each kernel configuration is optimal.

Reproduces the long-tail phenomenon: a few configs win often, but many
distinct configs are best at least once — the reason naive Top-N pruning
loses performance.
"""
from __future__ import annotations

import numpy as np

from .common import arch_dataset, save_json


def run(device_name: str = "tpu_v5e", quick: bool = False) -> dict:
    ds = arch_dataset(device_name, max_problems=120 if quick else 300)
    winners = ds.perf.argmax(axis=1)
    counts = np.bincount(winners, minlength=ds.perf.shape[1])
    order = np.argsort(-counts)
    top = [
        {"config": ds.configs[i].name(), "best_count": int(counts[i])}
        for i in order[:10]
        if counts[i] > 0
    ]
    n_distinct = int((counts > 0).sum())
    result = {
        "device": device_name,
        "n_problems": len(ds.problems),
        "n_configs": len(ds.configs),
        "n_distinct_winners": n_distinct,
        "top10": top,
    }
    save_json(f"fig2_best_counts_{device_name}.json", result)
    return result


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    for dev in ("tpu_v5e", "tpu_v4"):
        r = run(dev, quick=quick)
        rows.append(
            (
                f"fig2_distinct_winners_{dev}",
                float(r["n_distinct_winners"]),
                f"top1={r['top10'][0]['best_count']}x of {r['n_problems']} problems",
            )
        )
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
