"""Selection fast-path microbenchmark: CART fit time + dispatch throughput.

Tracks the two costs the paper says must be negligible (§5.1, and the
companion case study's retuning economics):

  * **fit** — CART training on the synthetic tuning dataset, new vectorized
    Gini sweep vs the seed per-threshold Python loop (vendored below as the
    baseline so the speedup stays measurable forever);
  * **predict** — batch classification of 10k feature rows, flat-array
    frontier descent vs the seed per-row nested walk;
  * **dispatch** — policy selections/sec through a ``KernelRuntime`` handle,
    cold (featurize+predict every call) vs shape-cache-hit;
  * **handle vs legacy** — the same warm dispatch through an explicit
    ``KernelRuntime`` handle vs the deprecated module-level
    ``repro.kernels.ops`` shim path (which resolves the current runtime per
    call).  Gated in ``perf_gate.py`` so the api_redesign's indirection can
    never quietly eat the PR-1 compiled fast path.
  * **guarded dispatch overhead** — a full ``ops.matmul`` call (select +
    kernel under the DESIGN.md §11 fault guard, everything disarmed) vs the
    identical dispatch body with the guard frame deleted.  Gated at 5% in
    ``perf_gate.py``: robustness must stay ~free on the happy path.

Run:  PYTHONPATH=src python benchmarks/bench_selection.py [--smoke] [--json out]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.classify import DecisionTreeClassifier
from repro.core.dataset import build_model_dataset, problem_features, synthetic_problems
from repro.core.dispatch import build_labels, train_deployment
from repro.core.runtime import KernelRuntime, current_runtime, default_runtime
from repro.core.selection import select_from_dataset
from repro.kernels import ops


# ---------------------------------------------------------------------------
# Vendored seed implementation (pre-fast-path), kept verbatim as the baseline.
# ---------------------------------------------------------------------------
class SeedDecisionTree(DecisionTreeClassifier):
    """The seed CART: per-threshold Python inner loop + per-row nested walk."""

    def fit(self, x, y, sample_weight=None):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=int)
        self.n_classes_ = int(y.max()) + 1 if y.size else 1
        rng = np.random.default_rng(self.seed)
        w = np.ones(len(y)) if sample_weight is None else np.asarray(sample_weight, float)
        self.root_ = self._grow(x, y, w, depth=0, rng=rng)
        return self

    def _grow(self, x, y, w, depth, rng):
        from repro.core.classify import _Node

        node = _Node()
        counts = np.bincount(y, weights=w, minlength=self.n_classes_)
        node.counts = counts
        node.label = int(counts.argmax())
        if (
            (self.max_depth is not None and depth >= self.max_depth)
            or len(y) < 2 * self.min_samples_leaf
            or counts.max() == counts.sum()
        ):
            return node
        nf = x.shape[1]
        feats = np.arange(nf)
        if self.max_features is not None and self.max_features < nf:
            feats = rng.choice(nf, size=self.max_features, replace=False)
        best = None
        parent_gini = self._gini(counts)
        for f in feats:
            order = np.argsort(x[:, f], kind="stable")
            xs, ys, ws = x[order, f], y[order], w[order]
            onehot = np.zeros((len(ys), self.n_classes_))
            onehot[np.arange(len(ys)), ys] = ws
            left_csum = np.cumsum(onehot, axis=0)
            total = left_csum[-1]
            for i in range(self.min_samples_leaf, len(ys) - self.min_samples_leaf + 1):
                if i < len(ys) and xs[i - 1] == xs[min(i, len(ys) - 1)]:
                    continue
                lc = left_csum[i - 1]
                rc = total - lc
                nl, nr = lc.sum(), rc.sum()
                if nl <= 0 or nr <= 0:
                    continue
                g = (nl * self._gini(lc) + nr * self._gini(rc)) / (nl + nr)
                if best is None or g < best[0]:
                    thr = 0.5 * (xs[i - 1] + xs[min(i, len(ys) - 1)])
                    best = (g, int(f), float(thr))
        if best is None or best[0] >= parent_gini - 1e-12:
            return node
        _, f, thr = best
        mask = x[:, f] <= thr
        if mask.sum() < self.min_samples_leaf or (~mask).sum() < self.min_samples_leaf:
            return node
        node.feature, node.threshold = f, thr
        node.left = self._grow(x[mask], y[mask], w[mask], depth + 1, rng)
        node.right = self._grow(x[~mask], y[~mask], w[~mask], depth + 1, rng)
        return node


def _best_of(fn, reps: int) -> float:
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _best_of_pair(fn_a, fn_b, reps: int) -> tuple[float, float]:
    """Interleaved best-of timing: A/B alternate so background load skews
    both sides equally, and the pair order flips each rep so neither side
    always pays the first-in-pair cache/branch-warmup cost (measured at a
    systematic ~4-6us on eager JAX dispatch — enough to fake a 5% "overhead"
    between byte-identical code paths)."""
    ta, tb = [], []
    for i in range(reps):
        pair = (fn_a, ta), (fn_b, tb)
        for fn, acc in pair if i % 2 == 0 else reversed(pair):
            t0 = time.perf_counter()
            fn()
            acc.append(time.perf_counter() - t0)
    return min(ta), min(tb)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--reps", type=int, default=None,
                    help="override timing repetitions (CI perf-gate uses a few "
                         "reps even at --smoke sizes to damp scheduler noise)")
    args = ap.parse_args(argv)

    n_problems = 80 if args.smoke else 300
    n_predict = 2_000 if args.smoke else 10_000
    n_dispatch = 500 if args.smoke else 5_000
    reps = args.reps if args.reps else (1 if args.smoke else 3)

    ds = build_model_dataset(synthetic_problems(n_problems))
    chosen = select_from_dataset(ds, 8, "topn", "standard")
    feats = ds.features
    labels = build_labels(ds.perf, chosen)
    print(f"tuning dataset: {feats.shape[0]} problems x {len(ds.configs)} configs, "
          f"{len(chosen)} deployed")

    # -- fit: DecisionTreeA (unlimited depth) on the tuning dataset ----------
    t_seed, t_fast = _best_of_pair(
        lambda: SeedDecisionTree().fit(feats, labels),
        lambda: DecisionTreeClassifier().fit(feats, labels),
        reps if (args.smoke or args.reps) else 7,
    )
    fit_speedup = t_seed / t_fast
    print(f"fit   seed {t_seed * 1e3:8.1f} ms   vectorized {t_fast * 1e3:8.1f} ms   "
          f"speedup {fit_speedup:6.1f}x")

    # -- predict: 10k rows, per-row nested walk vs flat frontier descent ----
    clf = DecisionTreeClassifier().fit(feats, labels)
    rng = np.random.default_rng(0)
    big = feats[rng.integers(0, len(feats), size=n_predict)]
    t_walk, t_flat = _best_of_pair(
        lambda: clf.predict_nested(big), lambda: clf.predict(big), reps
    )
    np.testing.assert_array_equal(clf.predict(big), clf.predict_nested(big))
    pred_speedup = t_walk / t_flat
    print(f"pred  nested {t_walk * 1e3:6.1f} ms   flat {t_flat * 1e3:12.1f} ms   "
          f"speedup {pred_speedup:6.1f}x   ({n_predict} rows)")

    # -- dispatch: selections/sec, cold vs shape-cache-hit -------------------
    dep = train_deployment(ds, chosen, "DecisionTreeA")
    rt = KernelRuntime(name="bench-selection")
    rt.install(dep)
    shapes = [tuple(int(v) for v in p) for p in ds.problems]

    def cold():
        rt.clear_shape_cache()
        for i in range(n_dispatch):
            m, k, n, b = shapes[i % len(shapes)]
            # bypass the cache: a fresh shape key every call
            dep.select_matmul(m, k, n, b)

    def warm():
        rt.clear_shape_cache()
        for i in range(n_dispatch):
            m, k, n, b = shapes[i % len(shapes)]
            rt.select_matmul_config(m, k, n, b)

    t_cold = _best_of(cold, reps)
    t_warm = _best_of(warm, reps)
    stats = rt.shape_cache_stats()
    assert stats["hits"] >= n_dispatch - len(shapes), stats
    cold_rate = n_dispatch / t_cold
    warm_rate = n_dispatch / t_warm
    print(f"disp  cold {cold_rate:10.0f} sel/s   cached {warm_rate:10.0f} sel/s   "
          f"speedup {warm_rate / cold_rate:6.1f}x   "
          f"(cache: {stats['hits']} hits / {stats['misses']} misses)")

    # -- handle vs legacy-global: the api_redesign dispatch microbench -------
    # Same deployment, same warm shapes: explicit KernelRuntime methods vs
    # the deprecated ops.* shim (one extra current_runtime() resolution per
    # call).  The ratio should sit near 1.0; a fall-off means the redesign's
    # indirection started taxing the serving fast path.
    default_runtime().install(dep)  # the shims' target (no deprecated call)
    # All-cache-hit loops are so fast that n_dispatch iterations time ~1 ms;
    # stretch the timed region and interleave more reps so one scheduler
    # preemption cannot flip the gated ratio.
    n_ab = n_dispatch * 4
    try:
        def handle():
            for i in range(n_ab):
                m, k, n, b = shapes[i % len(shapes)]
                rt.select_matmul_config(m, k, n, b)

        def legacy():
            for i in range(n_ab):
                m, k, n, b = shapes[i % len(shapes)]
                ops.select_matmul_config(m, k, n, b)

        handle()  # prime both caches outside the timed region
        legacy()
        t_handle, t_legacy = _best_of_pair(handle, legacy, max(reps, 5))
    finally:
        default_runtime().install(None)
    handle_rate = n_ab / t_handle
    legacy_rate = n_ab / t_legacy
    runtime_ratio = handle_rate / legacy_rate
    print(f"disp  handle {handle_rate:8.0f} sel/s   legacy shim {legacy_rate:8.0f} sel/s   "
          f"handle/legacy {runtime_ratio:5.2f}x")

    # -- guarded dispatch overhead: the fault guard's happy-path tax ---------
    # ops.matmul runs select + jnp.dot inside _guarded_call (injection sites,
    # non-finite validation, and the circuit breaker all disarmed: no fault
    # plan, no quarantine entries); the plain loop replicates the op's full
    # dispatch body — shape featurization, selection, the same jnp.dot — with
    # the guard frame deleted, so the ratio isolates exactly what the fault
    # guard adds and nothing the op wrapper always cost.  Each pair runs
    # back-to-back in the same scheduler window and the median of per-pair
    # ratios is taken: a min over all pairs would let the two sides pick
    # their minima from *different* windows, which on a loaded box fakes a
    # 10%+ "overhead" between code paths that differ by nothing.
    import jax.numpy as jnp

    xg = jnp.ones((64, 128), jnp.float32)
    wg = jnp.ones((128, 64), jnp.float32)
    n_guard = max(n_dispatch // 2, 200)

    def _matmul_unguarded(lhs, rhs):
        # ops.matmul's dispatch body with _guarded_call stripped — keep in
        # sync with repro.kernels.ops.matmul so the comparison stays honest.
        r = current_runtime()
        *lead, k = lhs.shape
        n = rhs.shape[1]
        m = lead[-1] if lead else 1
        batch = 1
        for d in lead[:-1]:
            batch *= d
        r.select_matmul_config(m, k, n, batch)
        return jnp.dot(lhs, rhs, preferred_element_type=jnp.float32).astype(lhs.dtype)

    with rt.activate():
        def guarded():
            for _ in range(n_guard):
                ops.matmul(xg, wg)

        def plain():
            for _ in range(n_guard):
                _matmul_unguarded(xg, wg)

        guarded()  # prime compile/dispatch + shape caches outside the timing
        plain()
        pairs = []
        for i in range(max(reps * 3, 9)):
            order = (guarded, plain) if i % 2 == 0 else (plain, guarded)
            t = {}
            for fn in order:
                t0 = time.perf_counter()
                fn()
                t[fn] = time.perf_counter() - t0
            pairs.append((t[guarded], t[plain]))
    ratios = sorted(tg / tp for tg, tp in pairs)
    guard_overhead = ratios[len(ratios) // 2]
    t_guard = min(tg for tg, _ in pairs)
    t_plain = min(tp for _, tp in pairs)
    print(f"disp  guarded {t_guard / n_guard * 1e6:7.1f} us/call   "
          f"plain {t_plain / n_guard * 1e6:7.1f} us/call   "
          f"overhead {guard_overhead:5.3f}x   (budget 1.05x)")

    results = {
        "n_problems": n_problems,
        "fit_seed_s": t_seed,
        "fit_fast_s": t_fast,
        "fit_speedup": fit_speedup,
        "predict_rows": n_predict,
        "predict_nested_s": t_walk,
        "predict_flat_s": t_flat,
        "predict_speedup": pred_speedup,
        "dispatch_cold_per_s": cold_rate,
        "dispatch_cached_per_s": warm_rate,
        "dispatch_speedup": warm_rate / cold_rate,
        "dispatch_handle_per_s": handle_rate,
        "dispatch_legacy_per_s": legacy_rate,
        "runtime_dispatch_ratio": runtime_ratio,
        "guarded_call_us": t_guard / n_guard * 1e6,
        "plain_call_us": t_plain / n_guard * 1e6,
        "guarded_dispatch_overhead": guard_overhead,
    }
    if args.json:
        from pathlib import Path

        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(results, indent=1))
        print(f"wrote {args.json}")
    # Regression tripwire: quiet machines measure 10-12x; a genuine fall
    # back to the per-threshold-loop implementation would read ~1x.  The
    # guard sits below the noise floor so scheduler jitter can't trip it.
    if not args.smoke and fit_speedup < 8:
        raise SystemExit(f"fit speedup regressed: {fit_speedup:.1f}x (expect ~10-12x)")
    return results


if __name__ == "__main__":
    main()
