"""Paper Fig. 5: pruning methods x normalizations x n_kernels (TPU device).

The 'AMD R9 Nano' analogue: the analytic TPU-v5e benchmark table over GEMMs
harvested from the assigned architectures.  Reports the achievable (oracle)
fraction of optimal performance on the held-out test split.
"""
from __future__ import annotations

from repro.core.cluster import CLUSTER_METHODS
from repro.core.normalize import NORMALIZATIONS
from repro.core.selection import evaluate_methods

from .common import arch_dataset, save_json

N_RANGE = (4, 6, 8, 11, 15)


def run(device_name: str = "tpu_v5e", quick: bool = False) -> dict:
    ds = arch_dataset(device_name, max_problems=120 if quick else 300)
    train, test = ds.split(0.25, seed=0)
    methods = list(CLUSTER_METHODS)
    norms = list(NORMALIZATIONS) if not quick else ["standard", "sigmoid"]
    n_range = list(N_RANGE) if not quick else [4, 8]
    table = evaluate_methods(train, test, n_range, methods, norms)
    result = {
        "device": device_name,
        "fractions": {f"{m}|{nm}|{n}": float(v) for (m, nm, n), v in table.items()},
    }
    save_json(f"fig5_pruning_{device_name}.json", result)
    return result


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    r = run("tpu_v5e", quick=quick)
    rows = []
    # headline: best method at 4 kernels and at 8, vs the TopN baseline
    fr = r["fractions"]
    for n in (4, 8):
        items = {k: v for k, v in fr.items() if k.endswith(f"|standard|{n}")}
        if not items:
            continue
        best = max(items, key=items.get)
        topn = items.get(f"topn|standard|{n}", 0.0)
        rows.append(
            (
                f"fig5_best_at_{n}_kernels",
                round(items[best] * 100, 2),
                f"{best.split('|')[0]} vs topn={topn * 100:.1f}%",
            )
        )
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
