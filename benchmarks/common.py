"""Shared helpers for the paper-artifact benchmarks."""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core.dataset import TuningDataset, build_model_dataset, harvest_problems

OUT_DIR = Path(__file__).resolve().parent.parent / "experiments" / "paper"


def out_path(name: str) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    return OUT_DIR / name


def save_json(name: str, obj) -> Path:
    p = out_path(name)
    p.write_text(json.dumps(obj, indent=1, default=_np_default))
    return p


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


_DATASET_CACHE: dict[tuple, TuningDataset] = {}


def arch_dataset(device_name: str = "tpu_v5e", max_problems: int = 300) -> TuningDataset:
    """Analytic benchmark table: GEMMs harvested from the 10 assigned archs,
    topped up with the paper-flavoured synthetic mix to ``max_problems``
    (the paper's dataset is 300 size-sets from 3 networks)."""
    from repro.core.dataset import synthetic_problems

    key = (device_name, max_problems)
    if key not in _DATASET_CACHE:
        problems = harvest_problems(max_problems=max_problems)
        if len(problems) < max_problems:
            extra = [p for p in synthetic_problems(2 * max_problems) if p not in set(problems)]
            problems = sorted(problems + extra[: max_problems - len(problems)])
        _DATASET_CACHE[key] = build_model_dataset(problems, device_name=device_name)
    return _DATASET_CACHE[key]
