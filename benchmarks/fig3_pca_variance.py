"""Paper Fig. 3: PCA variance concentration of the benchmark dataset.

Determines how many deployed kernels may encapsulate the dataset's variance
(the paper finds 80% in 4 components, 90% in 6-7, 95% in 11-14).
"""
from __future__ import annotations

from repro.core.normalize import normalize
from repro.core.pca import PCA

from .common import arch_dataset, save_json


def run(device_name: str = "tpu_v5e", quick: bool = False) -> dict:
    ds = arch_dataset(device_name, max_problems=120 if quick else 300)
    norm = normalize(ds.perf, "standard")
    pca = PCA().fit(norm)
    result = {
        "device": device_name,
        "ratio_head": [float(r) for r in pca._full_ratio[:15]],
        "n_for_80": pca.n_components_for_variance(0.80),
        "n_for_90": pca.n_components_for_variance(0.90),
        "n_for_95": pca.n_components_for_variance(0.95),
    }
    save_json(f"fig3_pca_variance_{device_name}.json", result)
    return result


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    for dev in ("tpu_v5e", "tpu_v4"):
        r = run(dev, quick=quick)
        rows.append(
            (
                f"fig3_pca_components_{dev}",
                float(r["n_for_90"]),
                f"80%:{r['n_for_80']} 90%:{r['n_for_90']} 95%:{r['n_for_95']} comps",
            )
        )
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
