"""Beyond-paper: the pipeline applied to the flash-attention kernel family.

The paper's §7 hopes the method extends "to more complicated kernels".  This
benchmark quantifies that on the Pallas flash-attention space: oracle and
classifier fractions when deploying k of the 12 (block_q, block_kv) configs
for the attention shapes the 10 architectures actually launch.
"""
from __future__ import annotations

import numpy as np

from repro.core.attnmodel import (
    attn_problem_features,
    build_attn_matrix,
    harvest_attn_problems,
)
from repro.core.classify import DecisionTreeClassifier
from repro.core.cluster import select_configs
from repro.core.normalize import normalize
from repro.kernels.attention import attention_config_space

from .common import save_json


def run(quick: bool = False) -> dict:
    space = list(attention_config_space())
    problems = harvest_attn_problems()
    perf = build_attn_matrix(problems)
    feats = attn_problem_features(problems)
    norm = normalize(perf, "standard")
    out = {}
    for k in (2, 3, 4, 6):
        chosen = select_configs(norm, k, "pca_kmeans", features=feats)
        best = perf.max(axis=1)
        oracle = perf[:, chosen].max(axis=1)
        labels = perf[:, chosen].argmax(axis=1)
        tree = DecisionTreeClassifier(max_depth=6).fit(feats, labels)
        pred = np.clip(tree.predict(feats), 0, len(chosen) - 1)
        picked = perf[np.arange(len(problems)), [chosen[i] for i in pred]]
        gm = lambda r: float(np.exp(np.mean(np.log(np.maximum(r / best, 1e-12)))))
        out[str(k)] = {"oracle": gm(oracle), "classifier": gm(picked)}
    result = {"n_problems": len(problems), "n_configs": len(space), "fractions": out}
    save_json("fig8_attention_family.json", result)
    return result


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    r = run(quick=quick)
    rows = []
    for k, v in r["fractions"].items():
        rows.append(
            (
                f"fig8_attn_{k}_kernels",
                round(v["classifier"] * 100, 2),
                f"oracle={v['oracle'] * 100:.1f}% over {r['n_problems']} attention shapes",
            )
        )
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
