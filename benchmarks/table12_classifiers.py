"""Paper Tables 1-2: runtime classifier quality x number of deployed configs.

For each device, configs are selected with PCA+K-means (the paper's choice),
then every classifier in the zoo is trained to pick among them; reported is
the geomean fraction of absolute-optimal performance on the test split.
"""
from __future__ import annotations

from repro.core.classify import CLASSIFIERS
from repro.core.dispatch import classifier_fraction, train_deployment
from repro.core.selection import achievable_fraction, select_from_dataset

from .common import arch_dataset, save_json

N_CONFIGS = (5, 6, 8, 15)


def run(device_name: str = "tpu_v5e", quick: bool = False) -> dict:
    ds = arch_dataset(device_name, max_problems=120 if quick else 300)
    train, test = ds.split(0.25, seed=0)
    ns = list(N_CONFIGS) if not quick else [5, 8]
    names = sorted(CLASSIFIERS) if not quick else ["DecisionTreeA", "RandomForest", "MLP"]
    table: dict[str, dict[int, float]] = {name: {} for name in names}
    ceiling: dict[int, float] = {}
    for n in ns:
        chosen = select_from_dataset(train, n, "pca_kmeans", "standard")
        ceiling[n] = achievable_fraction(test.perf, chosen)
        for name in names:
            dep = train_deployment(train, chosen, name) if name.startswith("DecisionTree") else None
            if dep is None:
                # non-tree classifiers are not shippable launcher artifacts;
                # evaluate them directly (paper compares them as references)
                from repro.core.classify import make_classifier
                from repro.core.dispatch import build_labels
                import numpy as np

                clf = make_classifier(name)
                clf.fit(train.features, build_labels(train.perf, chosen))
                pred = np.clip(clf.predict(test.features), 0, len(chosen) - 1)
                picked = test.perf[np.arange(len(test.problems)), [chosen[i] for i in pred]]
                best = test.perf.max(axis=1)
                ratio = np.where(best > 0, picked / np.maximum(best, 1e-12), 1.0)
                table[name][n] = float(np.exp(np.mean(np.log(np.maximum(ratio, 1e-12)))))
            else:
                table[name][n] = classifier_fraction(test, chosen, dep)
    result = {
        "device": device_name,
        "ceiling": {str(k): float(v) for k, v in ceiling.items()},
        "table": {k: {str(n): float(v) for n, v in d.items()} for k, d in table.items()},
    }
    save_json(f"table12_classifiers_{device_name}.json", result)
    return result


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    for dev in ("tpu_v5e", "tpu_v4"):
        r = run(dev, quick=quick)
        ns = sorted(r["ceiling"])
        for name in ("DecisionTreeA", "RandomForest"):
            if name not in r["table"]:
                continue
            vals = r["table"][name]
            best_n = max(vals, key=vals.get)
            rows.append(
                (
                    f"table12_{name}_{dev}",
                    round(vals[best_n] * 100, 2),
                    f"best at {best_n} configs (ceiling {float(r['ceiling'][best_n]) * 100:.1f}%)",
                )
            )
        del ns
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
