"""Control-plane microbenchmark: job overhead, fetch, federation, push.

The control plane (DESIGN.md §14) wraps the tuning pipeline in an HTTP
service; this benchmark measures what that wrapper *costs* so the answer to
"why not just call ``tune_fleet`` in-process?" stays quantified:

  * **job overhead** — wall time of submit -> succeeded over HTTP minus the
    same tuner invoked inline: queueing, JSON transport, registry publish,
    and policy announcement.  Should be a few ms against tunes that take
    seconds.
  * **artifact fetch** — ``repro.load_bundle("registry://...")`` end to end
    (HTTP GET + envelope unwrap + bundle parse + checksum verify), and the
    idempotent republish (content-hash hit) rate.
  * **telemetry federation** — serialized snapshot posts merged per second,
    each one drift-checked against the live artifact's provenance.
  * **policy push** — announce-to-delivery latency of the long-poll board:
    the time from a retune's publish to a parked subscriber waking with the
    new version.

Run:  PYTHONPATH=src python -m benchmarks.run --only control
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.control import ControlPlane, ControlPlaneClient
from repro.core import retune
from repro.core.bundle import DeploymentBundle
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.tuner import tune

from .common import save_json

DEVICE = "tpu_v5e"


def _median_of(fn, reps: int) -> float:
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _snapshot(rng, n: int) -> retune.TelemetrySnapshot:
    snap = retune.TelemetrySnapshot()
    for _ in range(n):
        p = (int(rng.choice([1, 2, 4])), int(rng.choice([8192, 16384])),
             int(rng.choice([1024, 2048])), 1)
        b = retune.shape_bucket(p)
        snap.matmul_counts[b] = snap.matmul_counts.get(b, 0) + 1
        snap.problems[b] = p
        snap.n_events += 1
    return snap


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    n_problems = 40 if quick else 120
    reps = 3 if quick else 7
    n_posts = 20 if quick else 100

    ds = build_model_dataset(synthetic_problems(n_problems), device_name=DEVICE)

    def tuner(spec):
        return DeploymentBundle({DEVICE: tune(ds, n_kernels=6).deployment})

    t_inline = _median_of(lambda: tuner({}), reps)

    plane = ControlPlane(port=0, min_events=10_000_000, tuner=tuner)
    plane.start()
    try:
        client = ControlPlaneClient(plane.url)

        # -- job overhead ----------------------------------------------------
        def job_round_trip():
            job = client.submit({"kind": "tune", "name": "bench"})
            client.wait_job(job["id"], timeout=120, poll_interval=0.01)

        t_job = _median_of(job_round_trip, reps)
        overhead_ms = max(0.0, (t_job - t_inline) * 1e3)

        # every publish after the first was a content-hash hit (same spec)
        versions = len(plane.registry.versions("bench"))

        # -- artifact fetch --------------------------------------------------
        import repro

        uri = client.registry_uri("bench")
        t_fetch = _median_of(lambda: repro.load_bundle(uri), max(reps, 5))

        # -- telemetry federation -------------------------------------------
        rng = np.random.default_rng(0)
        snaps = [_snapshot(rng, 50).to_json() for _ in range(n_posts)]
        t0 = time.perf_counter()
        for i, wire in enumerate(snaps):
            client.post_telemetry(DEVICE, wire, host=f"h{i % 8}",
                                  artifact="bench")
        t_fed = time.perf_counter() - t0
        posts_per_s = n_posts / t_fed
        merged = plane._federation[DEVICE].n_events

        # -- policy push latency --------------------------------------------
        lat: list[float] = []

        def push_once():
            ent0 = plane.policy_state(DEVICE) or {"seq": 0}
            woke = {}

            def poll():
                woke["ent"] = client.policy(DEVICE, after=ent0["seq"], timeout=20.0)
                woke["t"] = time.perf_counter()

            t = threading.Thread(target=poll)
            t.start()
            time.sleep(0.05)  # let the poller park
            t0 = time.perf_counter()
            plane._announce([DEVICE], "bench", plane.registry.latest("bench").version,
                            "bench-push")
            t.join(timeout=30.0)
            assert woke["ent"] is not None
            lat.append(woke["t"] - t0)

        for _ in range(max(reps, 5)):
            push_once()
        lat.sort()
        push_ms = lat[len(lat) // 2] * 1e3
    finally:
        plane.stop()

    results = {
        "inline_tune_s": t_inline,
        "job_round_trip_s": t_job,
        "job_overhead_ms": overhead_ms,
        "artifact_fetch_ms": t_fetch * 1e3,
        "artifact_versions": versions,
        "telemetry_posts_per_s": posts_per_s,
        "federated_events": merged,
        "policy_push_ms": push_ms,
        "quick": quick,
    }
    save_json("bench_control.json", results)
    return [
        ("control_job_overhead_ms", round(overhead_ms, 2),
         f"HTTP job {t_job * 1e3:.0f} ms vs inline tune {t_inline * 1e3:.0f} ms"),
        ("control_artifact_fetch_ms", round(t_fetch * 1e3, 2),
         f"registry:// load incl checksum verify; {versions} version(s) after "
         f"{reps} identical publishes (content-hash dedup)"),
        ("control_telemetry_posts_per_s", round(posts_per_s, 1),
         f"{n_posts} posts from 8 hosts merged to {merged} events, "
         f"drift-checked each post"),
        ("control_policy_push_ms", round(push_ms, 2),
         "announce -> parked long-poller wakes with the new version"),
    ]


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
