"""CI perf-gate: compare benchmark results against a committed baseline.

Holds the line on the PR1 selection fast path and the end-to-end numbers:

  PYTHONPATH=src python benchmarks/bench_selection.py --smoke --reps 3 --json sel.json
  PYTHONPATH=src python -m benchmarks.run --quick --only fig7 --json fig7.json
  PYTHONPATH=src python benchmarks/perf_gate.py \\
      --selection sel.json --fig7 fig7.json \\
      --baseline benchmarks/baseline_ci.json --out BENCH_ci.json

Gated metrics are chosen to be robust on shared CI runners: speedup *ratios*
(seed-vs-fast fit, nested-vs-flat predict, cold-vs-cached dispatch — both
sides of each ratio run on the same machine in the same process) and the
fig7 totals (analytic perf model, fully deterministic).  Absolute throughput
numbers are recorded in the artifact but not gated.

A metric regresses when it moves more than ``--tolerance`` (default 25%) in
its bad direction vs the committed baseline; any regression exits nonzero.
``--update-baseline`` rewrites the baseline file from the current run.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# metric name -> good direction ("higher" / "lower")
SELECTION_METRICS = {
    "fit_speedup": "higher",
    "predict_speedup": "higher",
    "dispatch_speedup": "higher",
    # api_redesign guard: explicit KernelRuntime handle dispatch vs the
    # deprecated ops.* shim path — a fall-off below baseline means runtime
    # indirection crept into the serving fast path.
    "runtime_dispatch_ratio": "higher",
    # robustness guard: guarded ops.matmul vs the same select+dot without the
    # guard frame — the fault-containment layer's happy-path tax.
    "guarded_dispatch_overhead": "lower",
}

# Metrics whose budget is a hard design contract, tighter than the global
# noise tolerance: the dispatch guard must cost <5% on the happy path
# (DESIGN.md §11), however forgiving --tolerance is for the rest.
PER_METRIC_TOLERANCE = {
    "guarded_dispatch_overhead": 0.05,
}
# fig7 rows named fig7_<arch>_tuned8_ms are totals in ms: lower is better.
FIG7_SUFFIX = "_tuned8_ms"
# bench_families rows named families_<family>_speedup are tuned-vs-default
# dispatch ratios from the family's analytic model: higher is better.
FAMILIES_PREFIX = "families_"
FAMILIES_SUFFIX = "_speedup"
# bench_transfer rows: the staged-pipeline bring-up contract (DESIGN.md §12).
# transfer_<family>_quality_ratio is staged/full selection quality (higher is
# better); transfer_<family>_measured_fraction is measured cells over the
# full-harvest cell count (lower is better).
TRANSFER_PREFIX = "transfer_"
TRANSFER_QUALITY_SUFFIX = "_quality_ratio"
TRANSFER_COST_SUFFIX = "_measured_fraction"
# bench_serving rows: fully deterministic (simulated clock).  *_ms rows are
# latencies (lower is better); everything else is a throughput or a ratio
# (higher is better).
SERVING_PREFIX = "serving_"

# Hard absolute bounds, independent of the committed baseline: a transfer
# tune must reach >=95% of full-tune selection quality at <=40% of the
# measurements, or bringing up new hardware cheaply is no longer true.
# The serving tier's contracts (DESIGN.md §13): paged continuous batching
# beats the fixed-slot engine >=1.3x at equal KV memory, SLO-aware
# selection improves targeted p99 at <=5% throughput cost, prefix sharing
# buys >=1.5x tokens/s on shared-system-prompt traffic at equal KV memory,
# and chunked prefill improves short-request p99 >=1.3x while keeping
# >=95% of monolithic throughput.
HARD_BOUNDS = {
    TRANSFER_QUALITY_SUFFIX: ("min", 0.95),
    TRANSFER_COST_SUFFIX: ("max", 0.40),
    "serving_paged_speedup": ("min", 1.3),
    "serving_slo_p99_improvement": ("min", 1.0),
    "serving_slo_throughput_ratio": ("min", 0.95),
    "serving_prefix_share_speedup": ("min", 1.5),
    "serving_chunked_p99_improvement": ("min", 1.3),
    "serving_chunked_throughput_ratio": ("min", 0.95),
}

# recorded in the artifact for trend-watching, never gated (machine-dependent)
UNGATED_RECORD = ("dispatch_cold_per_s", "dispatch_cached_per_s",
                  "dispatch_handle_per_s", "dispatch_legacy_per_s",
                  "fit_seed_s", "fit_fast_s", "predict_nested_s", "predict_flat_s",
                  "guarded_call_us", "plain_call_us")


def collect_metrics(selection: dict | None, fig7: dict | None) -> tuple[dict, dict]:
    """(gated, recorded-only) metric dicts from the two benchmark artifacts."""
    gated: dict[str, tuple[float, str]] = {}
    recorded: dict[str, float] = {}
    if selection:
        for name, direction in SELECTION_METRICS.items():
            if name in selection:
                gated[name] = (float(selection[name]), direction)
        for name in UNGATED_RECORD:
            if name in selection:
                recorded[name] = float(selection[name])
    if fig7:
        for row in fig7.get("rows", []):
            name, value = str(row[0]), row[1]
            if name.endswith(FIG7_SUFFIX):
                gated[name] = (float(value), "lower")
            elif name.startswith(FAMILIES_PREFIX) and name.endswith(FAMILIES_SUFFIX):
                gated[name] = (float(value), "higher")
            elif name.startswith(TRANSFER_PREFIX) and name.endswith(TRANSFER_QUALITY_SUFFIX):
                gated[name] = (float(value), "higher")
            elif name.startswith(TRANSFER_PREFIX) and name.endswith(TRANSFER_COST_SUFFIX):
                gated[name] = (float(value), "lower")
            elif name.startswith(SERVING_PREFIX):
                direction = "lower" if name.endswith("_ms") else "higher"
                gated[name] = (float(value), direction)
    return gated, recorded


def check_hard_bounds(gated: dict) -> list[str]:
    """Absolute-bound violations (baseline-independent design contracts)."""
    violations: list[str] = []
    for name, (value, _direction) in sorted(gated.items()):
        for suffix, (kind, bound) in HARD_BOUNDS.items():
            if not name.endswith(suffix):
                continue
            if kind == "min" and value < bound:
                violations.append(f"{name}: {value:.4g} below hard minimum {bound:.4g}")
            elif kind == "max" and value > bound:
                violations.append(f"{name}: {value:.4g} above hard maximum {bound:.4g}")
    return violations


def gate(gated: dict, baseline: dict, tolerance: float) -> tuple[dict, list[str]]:
    """Verdict per metric + the list of regressions."""
    verdicts: dict[str, dict] = {}
    regressions: list[str] = []
    # A baseline metric the current run no longer emits is itself a failure:
    # a rename/removal must not silently shrink the gate's coverage.
    for name in sorted(set(baseline) - set(gated)):
        verdicts[name] = {"value": None, "baseline": baseline[name], "ok": False,
                          "note": "metric missing from current run"}
        regressions.append(
            f"{name}: present in baseline but missing from the current run "
            f"(renamed/removed? update {name!r} via --update-baseline deliberately)"
        )
    for name, (value, direction) in sorted(gated.items()):
        base = baseline.get(name)
        tol = PER_METRIC_TOLERANCE.get(name, tolerance)
        entry = {"value": value, "baseline": base, "direction": direction,
                 "tolerance": tol}
        if base is None:
            entry["ok"] = True
            entry["note"] = "no baseline (new metric; commit one with --update-baseline)"
        else:
            base = float(base)
            if direction == "higher":
                ok = value >= base * (1.0 - tol)
            else:
                ok = value <= base * (1.0 + tol)
            entry["ok"] = bool(ok)
            entry["ratio"] = value / base if base else None
            if not ok:
                regressions.append(
                    f"{name}: {value:.4g} vs baseline {base:.4g} "
                    f"({direction} is better, tolerance {tol:.0%})"
                )
        verdicts[name] = entry
    return verdicts, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--selection", default=None, help="bench_selection --json output")
    ap.add_argument("--fig7", default=None, help="benchmarks.run --json output (fig7)")
    ap.add_argument("--baseline", default="benchmarks/baseline_ci.json")
    ap.add_argument("--out", default="BENCH_ci.json", help="artifact to write")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run instead of gating")
    args = ap.parse_args(argv)

    selection = json.loads(Path(args.selection).read_text()) if args.selection else None
    fig7 = json.loads(Path(args.fig7).read_text()) if args.fig7 else None
    if fig7 and fig7.get("failures"):
        print(f"perf-gate: upstream benchmark failures: {fig7['failures']}", file=sys.stderr)
        return 1
    gated, recorded = collect_metrics(selection, fig7)
    if not gated:
        print("perf-gate: no gated metrics found in inputs", file=sys.stderr)
        return 1
    hard_violations = check_hard_bounds(gated)

    if args.update_baseline:
        if hard_violations:
            # A broken design contract must never be committed as the new normal.
            print("perf-gate: refusing to update baseline, hard bounds violated:",
                  file=sys.stderr)
            for v in hard_violations:
                print(f"  {v}", file=sys.stderr)
            return 1
        Path(args.baseline).write_text(
            json.dumps({name: value for name, (value, _d) in sorted(gated.items())}, indent=1)
        )
        print(f"baseline updated: {args.baseline} ({len(gated)} metrics)")
        return 0

    baseline = json.loads(Path(args.baseline).read_text()) if Path(args.baseline).exists() else {}
    verdicts, regressions = gate(gated, baseline, args.tolerance)
    regressions.extend(hard_violations)
    artifact = {
        "tolerance": args.tolerance,
        "metrics": verdicts,
        "recorded": recorded,
        "regressions": regressions,
        "ok": not regressions,
    }
    Path(args.out).write_text(json.dumps(artifact, indent=1))
    for name, v in sorted(verdicts.items()):
        mark = "ok " if v["ok"] else "REG"
        base = v["baseline"]
        if v["value"] is None:
            print(f"  [{mark}] {name:32s} {'missing':>12s}  (baseline {base})")
            continue
        print(f"  [{mark}] {name:32s} {v['value']:12.4g}  "
              f"(baseline {base if base is not None else '—'}, {v['direction']} better)")
    print(f"wrote {args.out}")
    if regressions:
        print("perf-gate FAILED:", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print(f"perf-gate passed: {len(verdicts)} metrics within {args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
