"""Serving-tier benchmark: paged KV cache + SLO-aware continuous batching.

Two gated comparisons, both fully deterministic (an injected simulated clock
advances by an analytic per-step cost model, so CI runners' noise never
touches the numbers):

  * **paged vs fixed-slot** — the same Poisson-arrival workload served by the
    seed-style dense engine (``max_batch=4, cache_len=256`` — one fixed slot
    per resident) and by the paged engine at *equal KV memory*
    (``max_batch=16`` lanes over ``64`` blocks of 16 tokens = the same 1024
    token-slots).  Paging turns the dead reservation tail of short sequences
    into extra lanes, so the decode batch runs wider and tokens/s go up —
    ``serving_paged_speedup`` must stay >= 1.3x (hard bound).

  * **SLO-aware vs SLO-blind** — the same workload with latency targets on a
    slice of requests, served with ``slo_aware`` on and off.  Under pressure
    the aware engine caps admissions and re-selects kernels through
    ``KernelRuntime.set_objective`` / ``select_for_objective`` (a latency-
    biased config: lower fixed cost, steeper width slope).  Tail latency of
    targeted requests must improve (``serving_slo_p99_improvement`` >= 1.0)
    at <= 5% throughput cost (``serving_slo_throughput_ratio`` >= 0.95).

  * **prefix sharing** — a shared-system-prompt workload served with
    ``prefix_sharing`` on and off at *equal KV memory*.  Aliasing the shared
    blocks both skips the redundant prefill work and frees the pool to host
    more concurrent decode lanes, so ``serving_prefix_share_speedup`` must
    stay >= 1.5x (hard bound).

  * **chunked prefill** — steady decode traffic with occasional very long
    prompts, served monolithically (the legacy regime) vs in scheduler-
    budgeted chunks.  The monolithic prefill stalls every decode lane for
    the whole prompt; chunks interleave, so the decode-token p99 must
    improve >= 1.3x (``serving_chunked_p99_improvement``) at >= 95% of the
    monolithic throughput (``serving_chunked_throughput_ratio``).

The cost model is the interesting part: per decode step the engine's
``on_decode`` hook *actually queries the runtime's kernel selection* for the
step's GEMM and advances the clock by that config's cost.  The SLO win is
therefore produced by the real objective-threading path (engine -> runtime
objective -> policy ``select_for_objective``), not hard-coded.

Run:  PYTHONPATH=src python -m benchmarks.run --only serving
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import KernelRuntime
from repro.kernels.matmul import config_space
from repro.serve.engine import ServingEngine

from .common import save_json

# Two deployable configs with opposite biases.  The plain classifier path
# always answers THROUGHPUT (best aggregate tokens/s at full width); the
# objective-aware path answers LATENCY (cheaper fixed cost, so narrow
# SLO-capped batches finish each step sooner).
_SPACE = config_space()
THROUGHPUT_CFG = _SPACE[0]
LATENCY_CFG = _SPACE[-1]
assert THROUGHPUT_CFG.name() != LATENCY_CFG.name()

# cfg.name() -> (fixed ms per step, ms per lane of decode width)
STEP_COST_MS = {
    THROUGHPUT_CFG.name(): (1.5, 0.25),
    LATENCY_CFG.name(): (0.6, 0.30),
}
PREFILL_COST_MS = (0.2, 0.005)  # fixed, per prompt token


class SimClock:
    """Deterministic clock the engine reads; hooks advance it."""

    def __init__(self):
        self.now = 0.0  # seconds

    def __call__(self) -> float:
        return self.now

    def advance(self, ms: float) -> None:
        self.now += ms / 1e3


class _BenchPolicy:
    """KernelPolicy whose objective-aware answer differs from its plain one."""

    cacheable = True

    def select_matmul(self, m, k, n, batch):
        return THROUGHPUT_CFG

    def select_for_objective(self, family, problem, objective):
        return LATENCY_CFG


class _SimLM:
    """Echo+1 LM with a single (B, L) cache leaf — model math is not under
    test here, only the engine's scheduling around it."""

    vocab = 64

    def init_cache(self, b, cache_len):
        return {"k": jnp.zeros((b, cache_len), jnp.float32)}

    def prefill(self, params, batch, cache_len):
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = self.init_cache(b, cache_len)
        cache["k"] = cache["k"].at[:, :s].set(tokens.astype(jnp.float32))
        logits = jax.nn.one_hot((tokens[:, -1:] + 1) % self.vocab, self.vocab)
        return logits, cache

    def decode_step(self, params, cache, tokens, positions):
        b = tokens.shape[0]
        cache = dict(cache)
        cache["k"] = cache["k"].at[jnp.arange(b), positions].set(
            tokens[:, 0].astype(jnp.float32)
        )
        logits = jax.nn.one_hot((tokens + 1) % self.vocab, self.vocab)
        return logits, cache


class _ChunkSimLM(_SimLM):
    """Sim LM that also speaks the chunked-prefill protocol, opting the
    engine into the streaming regime (left-aligned, prefix-shareable)."""

    def supports_chunked_prefill(self):
        return True

    def prefill_chunk(self, params, cache, tokens, start, last_row=None):
        cache = dict(cache)
        pos = start + jnp.arange(tokens.shape[1])
        cache["k"] = cache["k"].at[:, pos].set(
            tokens.astype(jnp.float32), mode="drop"
        )
        if last_row is None:
            last = tokens[:, -1:]
        else:
            last = jax.lax.dynamic_slice_in_dim(
                tokens, jnp.asarray(last_row, jnp.int32), 1, axis=1
            )
        logits = jax.nn.one_hot((last + 1) % self.vocab, self.vocab)
        return logits, cache


@dataclasses.dataclass
class _Arrival:
    arrival_s: float
    prompt: list[int]
    max_new_tokens: int
    priority: int
    latency_target_ms: float | None


def make_workload(
    n: int, *, slo_fraction: float = 0.0, target_ms: float = 2.5, seed: int = 0
) -> list[_Arrival]:
    """Poisson arrivals (mean gap 1.2 ms) of short mixed-priority prompts.

    Latency targets go only to requests past the warm-up ramp (index >= 8):
    the comparison should measure steady-state SLO behavior, not the shared
    cold-start spike both modes pay identically.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(0.0012, size=n)
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n):
        plen = int(rng.integers(4, 15))
        targeted = slo_fraction > 0 and i >= 8 and rng.random() < slo_fraction
        out.append(
            _Arrival(
                arrival_s=float(arrivals[i]),
                prompt=list(rng.integers(1, 40, size=plen)),
                max_new_tokens=int(rng.integers(12, 20)),
                priority=int(rng.integers(0, 3)),
                latency_target_ms=target_ms if targeted else None,
            )
        )
    return out


def _run_workload(workload, *, label, slo_aware=True, model=None,
                  prefill_cost=PREFILL_COST_MS, **engine_kwargs):
    """Serve one workload on a fresh engine/runtime/clock; return stats."""
    clock = SimClock()
    rt = KernelRuntime(name=f"bench-serving-{label}")
    rt.install(_BenchPolicy())

    def on_prefill(plen):
        base, per_tok = prefill_cost
        clock.advance(base + per_tok * plen)

    def on_decode(width):
        # The real selection path: trace-time GEMM selection on THIS
        # runtime, objective-aware iff the engine entered SLO mode.
        with rt.activate():
            cfg = rt.select_matmul_config(1, 4096, 4096, width)
        base, slope = STEP_COST_MS[cfg.name()]
        clock.advance(base + slope * width)

    eng = ServingEngine(
        model if model is not None else _SimLM(),
        params={},
        runtime=rt,
        prefill_buckets=(16,),
        slo_aware=slo_aware,
        clock=clock,
        on_prefill=on_prefill,
        on_decode=on_decode,
        **engine_kwargs,
    )
    tickets, i, guard = [], 0, 0
    t0 = clock.now
    while (i < len(workload) or eng.pending()) and guard < 200_000:
        guard += 1
        while i < len(workload) and workload[i].arrival_s <= clock.now:
            w = workload[i]
            tickets.append(
                eng.submit(
                    w.prompt,
                    max_new_tokens=w.max_new_tokens,
                    priority=w.priority,
                    latency_target_ms=w.latency_target_ms,
                )
            )
            i += 1
        if eng.pending():
            if not eng.step():
                break
        elif i < len(workload):
            clock.now = max(clock.now, workload[i].arrival_s)  # idle until next arrival
    status = eng.drain()
    reqs = [t.request for t in tickets]
    tokens = sum(len(r.output) for r in reqs)
    elapsed = max(clock.now - t0, 1e-9)
    return {
        "label": label,
        "status": status,
        "requests": reqs,
        "tokens": tokens,
        "elapsed_s": elapsed,
        "tokens_per_s": tokens / elapsed,
        "slo_events": list(eng.slo_events),
        "pool": eng.pool.stats(),
    }


def _percentiles(reqs, *, targeted_only=False) -> tuple[float, float]:
    xs = [
        ms
        for r in reqs
        if not targeted_only or r.latency_target_ms is not None
        for ms in r.token_ms
    ]
    if not xs:
        return 0.0, 0.0
    return float(np.percentile(xs, 50)), float(np.percentile(xs, 99))


def bench_paged_vs_fixed(quick: bool = False) -> dict:
    """Equal-memory comparison: dense 4x256 pool vs 16 lanes over 64x16 blocks."""
    n = 32 if quick else 96
    workload = make_workload(n)
    fixed = _run_workload(
        workload, label="fixed", max_batch=4, cache_len=256, slo_aware=False
    )
    paged = _run_workload(
        workload, label="paged", max_batch=16, cache_len=256,
        block_size=16, n_blocks=64, slo_aware=False,
    )
    for res in (fixed, paged):
        assert res["status"].completed == n, (res["label"], res["status"])
    p50, p99 = _percentiles(paged["requests"])
    return {
        "n_requests": n,
        "fixed_tokens_per_s": fixed["tokens_per_s"],
        "paged_tokens_per_s": paged["tokens_per_s"],
        "speedup": paged["tokens_per_s"] / fixed["tokens_per_s"],
        "paged_p50_ms": p50,
        "paged_p99_ms": p99,
        "paged_pool": paged["pool"],
    }


def bench_slo(quick: bool = False) -> dict:
    """Same targeted workload, slo_aware on vs off (targets ignored)."""
    n = 32 if quick else 96
    workload = make_workload(n, slo_fraction=0.3, target_ms=2.5)
    kw = dict(max_batch=8, cache_len=128, block_size=16, n_blocks=64)
    blind = _run_workload(workload, label="slo-blind", slo_aware=False, **kw)
    aware = _run_workload(workload, label="slo-aware", slo_aware=True, **kw)
    for res in (blind, aware):
        assert res["status"].completed == n, (res["label"], res["status"])
    assert aware["slo_events"], "SLO-aware run never entered SLO mode"
    _, p99_blind = _percentiles(blind["requests"], targeted_only=True)
    _, p99_aware = _percentiles(aware["requests"], targeted_only=True)
    return {
        "n_requests": n,
        "n_targeted": sum(
            1 for w in workload if w.latency_target_ms is not None
        ),
        "target_ms": 2.5,
        "p99_blind_ms": p99_blind,
        "p99_aware_ms": p99_aware,
        "p99_improvement": p99_blind / max(p99_aware, 1e-9),
        "blind_tokens_per_s": blind["tokens_per_s"],
        "aware_tokens_per_s": aware["tokens_per_s"],
        "throughput_ratio": aware["tokens_per_s"] / blind["tokens_per_s"],
        "slo_events": aware["slo_events"],
    }


def make_prefix_workload(
    n: int, *, sys_len: int = 96, seed: int = 0
) -> list[_Arrival]:
    """Shared-system-prompt traffic: every request opens with the same
    ``sys_len`` tokens and appends a short unique user tail.  Arrival gaps
    are wider than ``make_workload`` (mean 3 ms) so the first requests
    finish registering blocks before most of the fleet looks them up."""
    rng = np.random.default_rng(seed)
    system = list(rng.integers(1, 40, size=sys_len))
    arrivals = np.cumsum(rng.exponential(0.003, size=n))
    out = []
    for i in range(n):
        tail = list(rng.integers(40, 60, size=int(rng.integers(4, 13))))
        out.append(
            _Arrival(
                arrival_s=float(arrivals[i]),
                prompt=system + tail,
                max_new_tokens=int(rng.integers(12, 20)),
                priority=0,
                latency_target_ms=None,
            )
        )
    return out


def bench_prefix_share(quick: bool = False) -> dict:
    """Equal-KV-memory comparison: prefix sharing on vs off.

    Geometry is deliberately tight (32 blocks of 16 = 512 token-slots for 16
    lanes of ~110-token sequences): without sharing the pool hosts ~4
    concurrent residents and re-prefills the 96-token system prompt for every
    one of them; with sharing the system prompt is cached once and lanes pay
    only for their tails, so the decode batch runs wider AND prefill work
    drops.
    """
    n = 16 if quick else 48
    workload = make_prefix_workload(n)
    kw = dict(
        max_batch=16, cache_len=256, block_size=16, n_blocks=32,
        prefill_chunk_tokens=32, slo_aware=False,
    )
    unshared = _run_workload(
        workload, label="no-share", model=_ChunkSimLM(),
        prefix_sharing=False, **kw,
    )
    shared = _run_workload(
        workload, label="share", model=_ChunkSimLM(),
        prefix_sharing=True, **kw,
    )
    for res in (shared, unshared):
        assert res["status"].completed == n, (res["label"], res["status"])
    pool = shared["pool"]
    assert pool["prefix_hits"] > 0, "sharing run never aliased a prefix"
    p50, p99 = _percentiles(shared["requests"])
    return {
        "n_requests": n,
        "unshared_tokens_per_s": unshared["tokens_per_s"],
        "shared_tokens_per_s": shared["tokens_per_s"],
        "speedup": shared["tokens_per_s"] / unshared["tokens_per_s"],
        "prefix_hit_rate": pool["prefix_hit_rate"],
        "prefix_hit_tokens": pool["prefix_hit_tokens"],
        "shared_p50_ms": p50,
        "shared_p99_ms": p99,
        "shared_pool": pool,
    }


def make_mixed_chunk_workload(n: int, *, seed: int = 0) -> list[_Arrival]:
    """Steady short decode traffic with a very long prompt every 8th request
    (the monolithic-prefill decode-stall scenario)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.002, size=n))
    out = []
    for i in range(n):
        if i and i % 8 == 0:
            plen, new = 320, 8
        else:
            plen, new = int(rng.integers(4, 13)), int(rng.integers(16, 25))
        out.append(
            _Arrival(
                arrival_s=float(arrivals[i]),
                prompt=list(rng.integers(1, 40, size=plen)),
                max_new_tokens=new,
                priority=0,
                latency_target_ms=None,
            )
        )
    return out


# Chunk-stall scenario cost model: prefill compute per token is comparable
# to a decode lane-step, so a 512-token monolithic prefill stalls decode for
# many token periods while 32-token chunks barely register.
CHUNK_PREFILL_COST_MS = (0.2, 0.05)


def bench_chunked_prefill(quick: bool = False) -> dict:
    """Decode-token p99 with monolithic vs chunked prefill of long prompts.

    Sharing is off in both runs so chunking is the only variable; the
    monolithic run uses the legacy (non-chunk-capable) model, the chunked
    run budgets 32-token chunks through the scheduler.
    """
    n = 24 if quick else 64
    workload = make_mixed_chunk_workload(n)
    kw = dict(
        max_batch=8, cache_len=1024, block_size=16, slo_aware=False,
        prefill_cost=CHUNK_PREFILL_COST_MS,
    )
    mono = _run_workload(workload, label="monolithic", model=_SimLM(), **kw)
    chunked = _run_workload(
        workload, label="chunked", model=_ChunkSimLM(),
        prefill_chunk_tokens=32, prefix_sharing=False, **kw,
    )
    for res in (mono, chunked):
        assert res["status"].completed == n, (res["label"], res["status"])

    def short_reqs(res):
        return [r for r in res["requests"] if len(r.prompt) < 320]

    _, p99_mono = _percentiles(short_reqs(mono))
    _, p99_chunked = _percentiles(short_reqs(chunked))
    return {
        "n_requests": n,
        "n_long": sum(1 for w in workload if len(w.prompt) >= 320),
        "p99_monolithic_ms": p99_mono,
        "p99_chunked_ms": p99_chunked,
        "p99_improvement": p99_mono / max(p99_chunked, 1e-9),
        "monolithic_tokens_per_s": mono["tokens_per_s"],
        "chunked_tokens_per_s": chunked["tokens_per_s"],
        "throughput_ratio": chunked["tokens_per_s"] / mono["tokens_per_s"],
    }


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    paged = bench_paged_vs_fixed(quick)
    slo = bench_slo(quick)
    prefix = bench_prefix_share(quick)
    chunk = bench_chunked_prefill(quick)
    rows = [
        ("serving_paged_speedup", paged["speedup"],
         f"tokens/s paged vs fixed-slot at equal KV memory ({paged['n_requests']} reqs)"),
        ("serving_fixed_tokens_per_s", paged["fixed_tokens_per_s"],
         "dense 4x256 pool (sim clock)"),
        ("serving_paged_tokens_per_s", paged["paged_tokens_per_s"],
         "16 lanes over 64 blocks of 16 (sim clock)"),
        ("serving_p50_ms", paged["paged_p50_ms"], "paged run per-token latency"),
        ("serving_p99_ms", paged["paged_p99_ms"], "paged run per-token latency"),
        ("serving_slo_p99_improvement", slo["p99_improvement"],
         f"targeted-request p99: blind {slo['p99_blind_ms']:.2f} ms"
         f" / aware {slo['p99_aware_ms']:.2f} ms"),
        ("serving_slo_throughput_ratio", slo["throughput_ratio"],
         "SLO-aware tokens/s over SLO-blind (>=0.95 hard)"),
        ("serving_prefix_share_speedup", prefix["speedup"],
         f"tokens/s sharing vs no-sharing at equal KV memory"
         f" ({prefix['n_requests']} reqs, hit rate"
         f" {prefix['prefix_hit_rate']:.2f}, >=1.5 hard)"),
        ("serving_prefix_hit_rate", prefix["prefix_hit_rate"],
         "admissions that aliased at least one cached block"),
        ("serving_chunked_p99_improvement", chunk["p99_improvement"],
         f"short-request decode-token p99: monolithic"
         f" {chunk['p99_monolithic_ms']:.2f} ms / chunked"
         f" {chunk['p99_chunked_ms']:.2f} ms (>=1.3 hard)"),
        ("serving_chunked_throughput_ratio", chunk["throughput_ratio"],
         "chunked tokens/s over monolithic (>=0.95 hard)"),
    ]
    save_json("bench_serving.json", {
        "paged_vs_fixed": paged,
        "slo": {k: v for k, v in slo.items() if k != "slo_events"},
        "slo_events": [list(e) for e in slo["slo_events"]],
        "prefix_share": {k: v for k, v in prefix.items() if k != "shared_pool"},
        "chunked_prefill": chunk,
        "quick": quick,
    })
    return rows


if __name__ == "__main__":
    for name, value, derived in main(quick=True):
        print(f"{name},{value:.4g},{derived}")
