"""Continuous-tuning microbenchmark: hot-swap latency + retune economics.

Tracks the two costs that make the runtime loop (DESIGN.md §8) viable:

  * **swap** — policy hot-swap latency: `KernelRuntime.install_for_device`
    on the live device plus the first post-swap selection (the epoch resync
    that rebuilds the dispatch fast path), vs a full `install_bundle`;
  * **retune vs full tune** — `retune.incremental_retune` (bucket-level
    dataset, warm-started clustering, weighted refit) vs rerunning the whole
    `tuner.tune` pipeline on the union workload;
  * **availability** — dispatch throughput while a background thread swaps
    the policy continuously (zero-downtime check: every selection succeeds).

Run:  PYTHONPATH=src python benchmarks/bench_retune.py [--smoke] [--json out]
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core import retune
from repro.core.bundle import DeploymentBundle, install_bundle
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.runtime import KernelRuntime
from repro.core.tuner import tune

DEVICE = "tpu_v5e"


def _median_of(fn, reps: int) -> float:
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _shifted_traffic(rng, n: int) -> list[tuple]:
    """Decode-heavy deep-k problems, disjoint from the synthetic tuning mix."""
    out = []
    for _ in range(n):
        m = int(rng.choice([1, 2, 4]))
        k = int(rng.choice([8192, 16384]))
        n_ = int(rng.choice([1024, 2048, 4096]))
        out.append((m, k, n_, 1))
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--json", default=None, help="write results to this path")
    args = ap.parse_args(argv)

    n_problems = 60 if args.smoke else 200
    n_traffic = 150 if args.smoke else 1_000
    reps = 3 if args.smoke else 9

    ds = build_model_dataset(synthetic_problems(n_problems), device_name=DEVICE)
    res = tune(ds, n_kernels=8)
    dep = res.deployment
    print(f"initial deployment: {len(dep.configs)} kernels from {n_problems} problems")

    # -- drive shifted traffic through an isolated runtime handle ------------
    rt = KernelRuntime(name="bench-retune")
    rt.install_for_device(DEVICE, dep)
    rt.activate_device(DEVICE)
    rt.set_selection_logging(True, cap=8192)
    rng = np.random.default_rng(0)
    traffic = _shifted_traffic(rng, n_traffic)
    for p in traffic:
        rt.select_matmul_config(*p)
    snap = retune.TelemetrySnapshot.from_runtime(rt)
    report = retune.detect_drift(snap, dep)
    print(f"drift {report.score:.3f} (unseen {report.unseen_fraction:.1%}), "
          f"{len(report.drifted_buckets)} drifted buckets / {snap.n_events} events")

    # -- retune vs full tune -------------------------------------------------
    t_retune = _median_of(
        lambda: retune.incremental_retune(dep, snap, report=report), reps
    )
    union = sorted(set(ds.problems) | set(traffic))
    t_full = _median_of(
        lambda: tune(build_model_dataset(union, device_name=DEVICE), n_kernels=8), reps
    )
    result = retune.incremental_retune(dep, snap, report=report)
    new_dep = result.deployment
    retune_speedup = t_full / t_retune
    print(f"tune  full {t_full * 1e3:8.1f} ms   incremental {t_retune * 1e3:8.1f} ms   "
          f"speedup {retune_speedup:6.1f}x   "
          f"({result.n_problems} bucket problems vs {len(union)} union problems)")

    # -- hot-swap latency ----------------------------------------------------
    probe = traffic[0]
    deps = [dep, new_dep]
    state = {"i": 0}

    def swap_only():
        state["i"] ^= 1
        rt.install_for_device(DEVICE, deps[state["i"]])

    def swap_and_select():
        swap_only()
        rt.select_matmul_config(*probe)  # first post-swap selection (resync)

    t_swap_only = _median_of(swap_only, max(reps, 5))
    t_swap = _median_of(swap_and_select, max(reps, 5))
    bundle = DeploymentBundle({DEVICE: dep})

    def install_and_select():
        install_bundle(bundle, DEVICE, runtime=rt)
        rt.select_matmul_config(*probe)

    t_install = _median_of(install_and_select, max(reps, 5))
    print(f"swap  registry {t_swap_only * 1e6:6.0f} us   +first-selection {t_swap * 1e6:6.0f} us   "
          f"install_bundle+selection {t_install * 1e6:6.0f} us")
    # re-pin the registry state install_bundle replaced
    rt.install_for_device(DEVICE, dep)
    rt.activate_device(DEVICE)

    # -- availability under continuous swapping ------------------------------
    n_sel = 2_000 if args.smoke else 20_000
    stop = threading.Event()
    swaps = {"n": 0}

    def swapper():
        i = 0
        while not stop.is_set():
            i ^= 1
            rt.install_for_device(DEVICE, deps[i])
            swaps["n"] += 1

    def dispatch_loop():
        for j in range(n_sel):
            cfg = rt.select_matmul_config(*traffic[j % len(traffic)])
            assert cfg is not None  # never unpoliced mid-swap

    t_quiet = _median_of(dispatch_loop, 1)
    th = threading.Thread(target=swapper, daemon=True)
    th.start()
    t_swapping = _median_of(dispatch_loop, 1)
    stop.set()
    th.join()
    quiet_rate = n_sel / t_quiet
    swapping_rate = n_sel / t_swapping
    print(f"disp  quiet {quiet_rate:10.0f} sel/s   under-swap {swapping_rate:10.0f} sel/s "
          f"({swaps['n']} swaps during run)")

    # rt is a local handle: nothing process-global to tear down
    results = {
        "n_problems": n_problems,
        "n_traffic": n_traffic,
        "drift_score": report.score,
        "retune_full_s": t_full,
        "retune_incremental_s": t_retune,
        "retune_speedup": retune_speedup,
        "swap_registry_s": t_swap_only,
        "swap_hot_s": t_swap,
        "swap_install_bundle_s": t_install,
        "dispatch_quiet_per_s": quiet_rate,
        "dispatch_under_swap_per_s": swapping_rate,
        "swaps_observed": swaps["n"],
    }
    if args.json:
        from pathlib import Path

        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(results, indent=1))
        print(f"wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
