"""Tune-time vs quality frontier for the staged pipeline (DESIGN.md §12).

For each family, compare the artifact a *full harvest* produces against the
staged alternatives — model-guided pruning plus a measurement budget, and
(for matmul) a true cross-device transfer warm-start: tune tpu_v5e from
scratch, then bring up tpu_v4 measuring only where the roofline model and
the v5e donor disagree.  Every artifact is scored on the same full textured
benchmark table (the "ground truth" the full harvest saw), so the frontier
is honest: quality_ratio = staged selection quality / full-tune selection
quality, measured_fraction = measured cells / full-harvest cells.

Gated rows (benchmarks/perf_gate.py holds hard bounds on both, beyond the
usual baseline tolerance — the bring-up-new-hardware-cheaply contract):

  * ``transfer_<family>_quality_ratio``      >= 0.95 (higher is better);
  * ``transfer_<family>_measured_fraction``  <= 0.40 (lower is better).

All numbers come from the analytic perf models, so they are fully
deterministic and CI-gateable.

Run:  PYTHONPATH=src python -m benchmarks.run --only transfer
"""
from __future__ import annotations

import numpy as np

from repro.core.dataset import harvest_problems, problem_features
from repro.core.families import get_family
from repro.core.selection import geomean_fraction
from repro.core.tuner import tune_family, tune_for_archs

from .common import save_json

DONOR_DEVICE = "tpu_v5e"
TARGET_DEVICE = "tpu_v4"
PRUNE_RATIO = 0.5
MEASURE_BUDGET = 0.4


def _matmul_quality(deployment, problems, perf, space) -> float:
    """Geomean fraction-of-optimal of the artifact's picks on the full table."""
    feats = problem_features(problems)
    pred = np.clip(deployment.classifier.predict(feats), 0, len(deployment.configs) - 1)
    cols = [space.index(c) for c in deployment.configs]
    picked = perf[np.arange(len(problems)), [cols[i] for i in pred]]
    return geomean_fraction(picked, perf.max(axis=1))


def bench_matmul_transfer(quick: bool = False) -> dict:
    """Full v4 harvest vs v5e-transfer-warm-started v4 bring-up."""
    max_problems = 60 if quick else 160
    donor = tune_for_archs(
        None, device_name=DONOR_DEVICE, max_problems=max_problems, families=[]
    )
    full = tune_for_archs(
        None, device_name=TARGET_DEVICE, max_problems=max_problems, families=[]
    )
    staged = tune_for_archs(
        None, device_name=TARGET_DEVICE, max_problems=max_problems, families=[],
        transfer_from=donor, prune_ratio=PRUNE_RATIO, measure_budget=MEASURE_BUDGET,
    )
    fam = get_family("matmul")
    space = list(fam.config_space())
    problems = harvest_problems(None, max_problems=max_problems)
    perf = np.asarray(fam.perf_matrix(problems, space, TARGET_DEVICE))
    q_full = _matmul_quality(full.deployment, problems, perf, space)
    q_staged = _matmul_quality(staged.deployment, problems, perf, space)
    lin = staged.deployment.meta["tuning_lineage"]["matmul"]
    return {
        "family": "matmul",
        "donor_device": lin["source_device"],
        "quality_full": q_full,
        "quality_staged": q_staged,
        "quality_ratio": q_staged / q_full,
        "measured_fraction": lin["measured_fraction"],
        "prune_ratio": lin["prune_ratio"],
        "model_error": lin["model_error"],
        "n_problems": len(problems),
    }


def bench_family_transfer(name: str, quick: bool = False) -> dict:
    """Full harvest vs pruned+budgeted self-transfer for one registered family."""
    fam = get_family(name)
    full = tune_family(name)
    staged = tune_family(
        name, transfer_from=full, prune_ratio=PRUNE_RATIO, measure_budget=MEASURE_BUDGET
    )
    space = list(fam.config_space())
    problems = fam.harvest(None)
    if quick:
        problems = problems[:: max(1, len(problems) // 8)]
    perf = np.asarray(fam.perf_matrix(problems, space, DONOR_DEVICE))
    feats = fam.features(problems)

    def quality(res) -> float:
        pred = np.clip(res.tree.predict(feats), 0, len(res.configs) - 1)
        cols = [space.index(c) for c in res.configs]
        picked = perf[np.arange(len(problems)), [cols[i] for i in pred]]
        return geomean_fraction(picked, perf.max(axis=1))

    q_full, q_staged = quality(full), quality(staged)
    return {
        "family": name,
        "quality_full": q_full,
        "quality_staged": q_staged,
        "quality_ratio": q_staged / q_full,
        "measured_fraction": staged.lineage["measured_fraction"],
        "prune_ratio": staged.lineage["prune_ratio"],
        "model_error": staged.lineage["model_error"],
        "n_problems": len(problems),
    }


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    results = [bench_matmul_transfer(quick=quick)]
    for name in ("wkv", "ssm_scan"):
        results.append(bench_family_transfer(name, quick=quick))
    rows: list[tuple[str, float, str]] = []
    for r in results:
        derived = (
            f"staged {r['quality_staged'] * 100:.1f}% vs full "
            f"{r['quality_full'] * 100:.1f}% of oracle over {r['n_problems']} problems"
        )
        rows.append((f"transfer_{r['family']}_quality_ratio",
                     round(r["quality_ratio"], 4), derived))
        rows.append((f"transfer_{r['family']}_measured_fraction",
                     round(r["measured_fraction"], 4),
                     f"kept {r['prune_ratio']:.0%} of config space; "
                     f"model error {r['model_error']:.1%}" if r["model_error"] is not None
                     else f"kept {r['prune_ratio']:.0%} of config space"))
    save_json("bench_transfer.json", {"results": results, "quick": quick})
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
