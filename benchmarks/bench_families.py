"""Tuned vs default dispatch for the recurrence kernel families (wkv, ssm).

The generic-registry analogue of fig7: for each non-matmul family, run the
full prune+classify pipeline (``tuner.tune_family``) and compare the
classifier-picked kernel against the single default config an untuned
library would ship, over the family's harvested problem set plus a
serving-flavoured synthetic mix.  All numbers come from the family's
analytic perf model, so they are fully deterministic and CI-gateable:

  * ``families_<name>_speedup``   geomean(picked / default) gflops — the
                                  headline "tuning this family pays" number
                                  (gated, higher is better);
  * oracle fraction rides in the derived column (how close the tree gets to
    the best deployed kernel).

A dispatch-throughput smoke (shape-memoized ``select_*_config`` calls/s)
is recorded in the JSON artifact but never gated (machine-dependent).

Run:  PYTHONPATH=src python -m benchmarks.run --only families
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.families import get_family
from repro.core.selection import geomean_fraction
from repro.core.tuner import tune_family

from .common import save_json

FAMILIES = ("wkv", "ssm_scan")

# Serving-flavoured probe shapes beyond the harvest (decode bursts, reduced
# models, chunked prefill) — the traffic a serving host actually sees.
PROBES = {
    "wkv": [(1, 64), (64, 64), (256, 64), (1024, 64), (8192, 64), (128, 16)],
    "ssm_scan": [(64, 1600), (256, 1600), (1024, 1600), (96, 48), (512, 256)],
}


def bench_family(name: str, quick: bool = False) -> dict:
    fam = get_family(name)
    res = tune_family(name)
    space = list(fam.config_space())
    problems = sorted(set(fam.harvest(None)) | set(PROBES.get(name, [])))
    if quick:
        problems = problems[:: max(1, len(problems) // 6)]
    perf = fam.perf_matrix(problems, space, "tpu_v5e")
    j_default = space.index(fam.default_config)

    feats = fam.features(problems)
    pred = np.clip(res.tree.predict(feats), 0, len(res.configs) - 1)
    cols = [space.index(c) for c in res.configs]
    picked = perf[np.arange(len(problems)), [cols[i] for i in pred]]
    default = perf[:, j_default]
    best = perf.max(axis=1)

    speedup = geomean_fraction(picked, default)
    oracle_frac = geomean_fraction(picked, best)
    return {
        "family": name,
        "n_problems": len(problems),
        "n_deployed": len(res.configs),
        "n_space": len(space),
        "speedup_vs_default": speedup,
        "oracle_fraction": oracle_frac,
        "deployed": [c.name() for c in res.configs],
    }


def bench_dispatch(n: int = 2000) -> dict:
    """Shape-memoized tuned dispatch throughput for the new families."""
    from repro.core.dataset import build_model_dataset, synthetic_problems
    from repro.core.tuner import tune

    from repro.core.runtime import KernelRuntime

    ds = build_model_dataset(synthetic_problems(60))
    dep = tune(ds, n_kernels=5).deployment
    rt = KernelRuntime(name="bench-families")
    rt.install(dep)
    shapes = [(s, hd) for s in (1, 128, 2048, 32768) for hd in (16, 64)]
    t0 = time.perf_counter()
    for i in range(n):
        rt.select_wkv_config(*shapes[i % len(shapes)])
    wkv_rate = n / max(time.perf_counter() - t0, 1e-9)
    stats = rt.shape_cache_stats()["per_family"].get("wkv", {})
    return {"wkv_selects_per_s": wkv_rate, "wkv_cache": stats}


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    blob = {"families": {}, "dispatch": bench_dispatch(500 if quick else 2000)}
    for name in FAMILIES:
        r = bench_family(name, quick=quick)
        blob["families"][name] = r
        rows.append(
            (
                f"families_{name}_speedup",
                round(r["speedup_vs_default"], 4),
                f"{r['n_deployed']}/{r['n_space']} kernels deployed; "
                f"{r['oracle_fraction'] * 100:.1f}% of oracle over {r['n_problems']} problems",
            )
        )
    save_json("bench_families.json", blob)
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
