"""Benchmark driver: one module per paper table/figure.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig5] [--json out]

Prints ``name,value,derived`` CSV rows (one per headline number) and writes
full JSON artifacts to experiments/paper/.  ``--json`` additionally writes
the printed rows (plus any failures) to one machine-readable file — the CI
perf-gate consumes it.  Exits nonzero if any module failed.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (
    bench_control,
    bench_families,
    bench_serving,
    bench_transfer,
    fig2_best_counts,
    fig3_pca_variance,
    fig4_normalization,
    fig5_pruning_tpu,
    fig6_pruning_cpu,
    fig7_end_to_end,
    fig8_attention_family,
    table12_classifiers,
)

MODULES = {
    "fig2": fig2_best_counts,
    "fig3": fig3_pca_variance,
    "fig4": fig4_normalization,
    "fig5": fig5_pruning_tpu,
    "fig6": fig6_pruning_cpu,
    "table12": table12_classifiers,
    "fig7": fig7_end_to_end,
    "fig8": fig8_attention_family,  # beyond-paper: attention kernel family
    "families": bench_families,  # beyond-paper: wkv/ssm via the family registry
    "transfer": bench_transfer,  # staged pipeline: tune-time-vs-quality frontier
    "serving": bench_serving,  # fleet tier: paged KV + SLO-aware batching
    "control": bench_control,  # control plane: job/fetch/federation/push costs
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced problem counts")
    ap.add_argument("--only", default=None, metavar="NAMES",
                    help=f"comma-separated subset of {sorted(MODULES)}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + failures to this JSON file")
    args = ap.parse_args(argv)

    if args.only:
        names = [n for n in args.only.replace(" ", "").split(",") if n]
        unknown = sorted(set(names) - set(MODULES))
        if unknown:
            ap.error(f"unknown module(s) {unknown}; choose from {sorted(MODULES)}")
    else:
        names = list(MODULES)
    print("name,value,derived")
    failures: list[tuple[str, str]] = []
    all_rows: list[tuple] = []
    for name in names:
        t0 = time.time()
        try:
            rows = MODULES[name].main(quick=args.quick)
        except (Exception, SystemExit) as e:  # noqa: BLE001 — report all, fail at the end
            # SystemExit too: a module's internal regression tripwire must
            # fail the suite, not skip the remaining modules' reporting.
            failures.append((name, repr(e)))
            print(f"{name},ERROR,{e!r}", flush=True)
            continue
        all_rows.extend(rows)
        for row in rows:
            print(",".join(str(x) for x in row), flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr, flush=True)
    if args.json:
        import json
        from pathlib import Path

        out = {
            "rows": [list(r) for r in all_rows],
            "failures": [list(f) for f in failures],
            "quick": bool(args.quick),
        }
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(out, indent=1))
    if failures:
        # Explicit nonzero exit: the CI perf-gate (and any shell caller)
        # must see benchmark failures as a failed command, never exit 0.
        print(f"benchmark failures: {failures}", file=sys.stderr, flush=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
