"""Benchmark driver: one module per paper table/figure.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig5]

Prints ``name,value,derived`` CSV rows (one per headline number) and writes
full JSON artifacts to experiments/paper/.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (
    fig2_best_counts,
    fig3_pca_variance,
    fig4_normalization,
    fig5_pruning_tpu,
    fig6_pruning_cpu,
    fig7_end_to_end,
    fig8_attention_family,
    table12_classifiers,
)

MODULES = {
    "fig2": fig2_best_counts,
    "fig3": fig3_pca_variance,
    "fig4": fig4_normalization,
    "fig5": fig5_pruning_tpu,
    "fig6": fig6_pruning_cpu,
    "table12": table12_classifiers,
    "fig7": fig7_end_to_end,
    "fig8": fig8_attention_family,  # beyond-paper: attention kernel family
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced problem counts")
    ap.add_argument("--only", default=None, choices=sorted(MODULES))
    args = ap.parse_args()

    names = [args.only] if args.only else list(MODULES)
    print("name,value,derived")
    failures = []
    for name in names:
        t0 = time.time()
        try:
            rows = MODULES[name].main(quick=args.quick)
        except Exception as e:  # noqa: BLE001 — report all, fail at the end
            failures.append((name, repr(e)))
            print(f"{name},ERROR,{e!r}", flush=True)
            continue
        for row in rows:
            print(",".join(str(x) for x in row), flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
