"""Paper Fig. 6: pruning methods x normalizations x n_kernels — MEASURED CPU.

The i7-6700K analogue: real wall-clock timings of the cache-blocked GEMM on
this container's host CPU (see repro.core.cpubench).  This is the measured
counterpart to fig5's analytic-model dataset; the tuning pipeline is
identical for both data sources.
"""
from __future__ import annotations

from pathlib import Path

from repro.core.cpubench import build_cpu_dataset, cpu_problems
from repro.core.cluster import CLUSTER_METHODS
from repro.core.dataset import TuningDataset
from repro.core.normalize import NORMALIZATIONS
from repro.core.selection import evaluate_methods

from .common import out_path, save_json

_CACHE = out_path("cpu_dataset.npz")


def measured_dataset(quick: bool = False, refresh: bool = False) -> TuningDataset:
    n = 12 if quick else 24
    if _CACHE.exists() and not refresh:
        ds = TuningDataset.load(_CACHE)
        if len(ds.problems) >= n:  # cached quick run must not satisfy a full run
            return ds
    ds = build_cpu_dataset(cpu_problems(n), verbose=True)
    ds.save(_CACHE)
    return ds


def run(quick: bool = False) -> dict:
    ds = measured_dataset(quick)
    train, test = ds.split(0.25, seed=0)
    norms = list(NORMALIZATIONS) if not quick else ["standard", "sigmoid"]
    n_range = [4, 6, 8, 11, 15] if not quick else [4, 8]
    table = evaluate_methods(train, test, n_range, list(CLUSTER_METHODS), norms)
    result = {
        "device": "host_cpu",
        "source": "measured",
        "n_problems": len(ds.problems),
        "fractions": {f"{m}|{nm}|{n}": float(v) for (m, nm, n), v in table.items()},
    }
    save_json("fig6_pruning_cpu.json", result)
    return result


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    r = run(quick=quick)
    fr = r["fractions"]
    rows = []
    for n in (4, 8):
        items = {k: v for k, v in fr.items() if k.endswith(f"|standard|{n}")}
        if not items:
            continue
        best = max(items, key=items.get)
        topn = items.get(f"topn|standard|{n}", 0.0)
        rows.append(
            (
                f"fig6_cpu_best_at_{n}_kernels",
                round(items[best] * 100, 2),
                f"{best.split('|')[0]} vs topn={topn * 100:.1f}% (measured)",
            )
        )
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
