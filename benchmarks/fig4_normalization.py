"""Paper Fig. 4: effect of the four normalization schemes on one problem row."""
from __future__ import annotations

import numpy as np

from repro.core.normalize import NORMALIZATIONS, normalize

from .common import arch_dataset, save_json


def run(device_name: str = "tpu_v5e", quick: bool = False) -> dict:
    ds = arch_dataset(device_name, max_problems=120 if quick else 300)
    # the best-performing problem row (paper uses its best input set)
    row = int(np.argmax(ds.perf.max(axis=1)))
    raw = ds.perf[row]
    out = {"device": device_name, "problem": list(ds.problems[row]), "schemes": {}}
    for scheme in NORMALIZATIONS:
        v = normalize(raw[None, :], scheme)[0]
        out["schemes"][scheme] = {
            "nonzero": int((v > 0).sum()),
            "mean_nonzero": float(v[v > 0].mean()) if (v > 0).any() else 0.0,
            "max": float(v.max()),
        }
    save_json(f"fig4_normalization_{device_name}.json", out)
    return out


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    r = run(quick=quick)
    rows = []
    for scheme, s in r["schemes"].items():
        rows.append(
            (
                f"fig4_norm_{scheme}_nonzero",
                float(s["nonzero"]),
                f"mean_nz={s['mean_nonzero']:.3f}",
            )
        )
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
