"""Paper Fig. 7: end-to-end model comparison with different matmul backends.

The paper runs VGG16 inference through SYCL-DNN with (a) its tuned simple
kernel, (b) SYCL-BLAS, (c) CLBlast.  Our analogue runs the assigned
architectures' full GEMM workload (every projection/FFN/vocab GEMM a
train_4k step launches — harvested exactly like the tuning problems) and
totals the predicted per-GEMM time on TPU v5e under four launchers:

  * ``single_default``  — one fixed kernel (an untuned library; CLBlast's
                          single-tuned-kernel behaviour);
  * ``topn4``           — 4 kernels by best-count + oracle pick (the manual
                          heuristic baseline the paper describes);
  * ``tuned8``          — the full pipeline: PCA+K-means 8-kernel deployment
                          + decision-tree runtime selection (this paper);
  * ``oracle``          — best of ALL 210 configs per GEMM (upper bound).

Additionally a REAL measured end-to-end: the reduced granite LM forward pass
on this host CPU with the XLA backend vs Pallas-interpret tuned dispatch is
covered by tests; wall-clock comparison at full size needs the TPU.
"""
from __future__ import annotations

import numpy as np

from repro.configs import registry
from repro.core.dataset import build_model_dataset
from repro.core.dispatch import train_deployment
from repro.core.perfmodel import TPU_V5E, predict_time
from repro.core.selection import select_from_dataset
from repro.core.tuner import tune
from repro.kernels.matmul import DEFAULT_CONFIG

from .common import arch_dataset, save_json

ARCHS_E2E = ("phi4-mini-3.8b", "qwen3-moe-235b-a22b", "rwkv6-7b")


def _total_time(problems, pick_fn) -> float:
    return sum(min(predict_time(p, pick_fn(p), TPU_V5E), 60.0) for p in problems)


def run(quick: bool = False) -> dict:
    ds = arch_dataset("tpu_v5e", max_problems=120 if quick else 300)
    res = tune(ds, n_kernels=8, method="pca_kmeans", classifier="DecisionTreeA")
    dep = res.deployment
    train, _ = ds.split(0.25, seed=0)
    topn4 = select_from_dataset(train, 4, "topn", "standard")
    space = ds.configs

    out = {}
    archs = ARCHS_E2E if not quick else ARCHS_E2E[:1]
    for arch in archs:
        problems = registry.gemm_problems(arch, "train_4k")
        perf_rows = {
            p: np.array([predict_time(p, c, TPU_V5E) for c in space]) for p in set(problems)
        }

        def oracle_pick(p):
            return space[int(np.argmin(perf_rows[p]))]

        def topn_pick(p):
            sub = [(perf_rows[p][i], space[i]) for i in topn4]
            return min(sub)[1]

        times = {
            "single_default": _total_time(problems, lambda p: DEFAULT_CONFIG),
            "topn4": _total_time(problems, topn_pick),
            "tuned8": _total_time(problems, lambda p: dep.select_matmul(*p)),
            "oracle": _total_time(problems, oracle_pick),
        }
        out[arch] = {k: float(v * 1e3) for k, v in times.items()}  # ms
    # The committed artifact keeps rows from earlier (fuller) runs; the
    # RETURN value carries only this run's measurements, so CSV rows and the
    # perf gate never report an arch as measured that never ran.
    save_json("fig7_end_to_end.json",
              {"device": "tpu_v5e", "per_arch_ms": _merge_artifact(out)})
    return {"device": "tpu_v5e", "per_arch_ms": out}


def _merge_artifact(fresh: dict) -> dict:
    """Merge this run's per-arch rows into the committed JSON artifact.

    Idempotent append: an arch measured in this run replaces its previous
    row (re-running never duplicates provenance), while archs only present
    in an earlier full run survive a later ``--quick`` run instead of being
    clobbered.
    """
    import json

    from .common import out_path

    path = out_path("fig7_end_to_end.json")
    merged: dict = {}
    if path.exists():
        try:
            prev = json.loads(path.read_text())
            if isinstance(prev, dict) and prev.get("device") == "tpu_v5e":
                merged.update(prev.get("per_arch_ms") or {})
        except (json.JSONDecodeError, OSError):
            pass  # unreadable artifact: rebuild from this run alone
    merged.update(fresh)
    return merged


def main(quick: bool = False) -> list[tuple[str, float, str]]:
    r = run(quick=quick)
    rows = []
    for arch, t in r["per_arch_ms"].items():
        speedup = t["single_default"] / max(t["tuned8"], 1e-9)
        frac = t["oracle"] / max(t["tuned8"], 1e-9)
        rows.append(
            (
                f"fig7_{arch}_tuned8_ms",
                round(t["tuned8"], 3),
                f"{speedup:.2f}x vs single kernel; {frac * 100:.1f}% of oracle",
            )
        )
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
