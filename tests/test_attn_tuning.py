"""Attention-family tuning (the paper's pipeline on a second kernel space)."""
import numpy as np
import pytest

from repro.core.attnmodel import (
    attn_problem_features,
    build_attn_matrix,
    harvest_attn_problems,
    predict_attn_gflops,
    predict_attn_time,
)
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.dispatch import Deployment
from repro.core.tuner import tune, tune_attention
from repro.kernels.attention import AttentionConfig, attention_config_space


def test_attn_model_basics():
    space = attention_config_space()
    train_p = (4096, 4096, 128)
    decode_p = (1, 32768, 128)
    g_train = np.array([predict_attn_gflops(train_p, c) for c in space])
    g_dec = np.array([predict_attn_gflops(decode_p, c) for c in space])
    assert g_train.max() > 1000
    assert np.all(g_train >= 0) and np.all(g_dec >= 0)
    # decode attention is memory-bound: far below training throughput
    assert g_dec.max() < 0.1 * g_train.max()
    # VMEM overflow -> inf time
    assert predict_attn_time((128, 128, 8192), AttentionConfig(512, 1024)) == float("inf")


def test_attn_model_regimes_differ():
    """Best config differs across shape regimes (the tuning premise)."""
    space = attention_config_space()
    best = {}
    for p in [(1, 32768, 128), (4096, 4096, 128), (2048, 32768, 64)]:
        best[p] = space[int(np.argmax([predict_attn_gflops(p, c) for c in space]))]
    assert len(set(best.values())) >= 2


def test_harvest_attn_problems():
    probs = harvest_attn_problems()
    assert len(probs) >= 5
    assert all(len(p) == 3 for p in probs)
    assert any(p[0] == 1 for p in probs)  # decode shapes present
    feats = attn_problem_features(probs)
    assert feats.shape == (len(probs), 4)
    assert np.all(np.isfinite(feats))
    # ssm-only arch contributes nothing
    assert harvest_attn_problems(["rwkv6-7b"]) == []


def test_tune_attention_selects_and_classifies():
    configs, tree = tune_attention(n_kernels=4)
    assert 1 <= len(configs) <= 4
    assert len(set(configs)) == len(configs)
    probs = harvest_attn_problems()
    perf = build_attn_matrix(probs)
    space = list(attention_config_space())
    chosen_idx = [space.index(c) for c in configs]
    # classifier picks achieve most of the achievable-with-subset performance
    feats = attn_problem_features(probs)
    pred = np.clip(tree.predict(feats), 0, len(configs) - 1)
    picked = perf[np.arange(len(probs)), [chosen_idx[i] for i in pred]]
    best = perf.max(axis=1)
    frac = np.exp(np.mean(np.log(np.maximum(picked / best, 1e-12))))
    assert frac > 0.8, frac


def test_deployment_attention_tree_roundtrip(tmp_path):
    ds = build_model_dataset(synthetic_problems(60))
    res = tune(ds, n_kernels=5)
    assert res.deployment.attention_tree is not None
    path = tmp_path / "d.json"
    res.deployment.save(path)
    back = Deployment.load(path)
    for p in [(1, 32768, 128), (4096, 4096, 128), (512, 2048, 64)]:
        assert back.select_attention(*p) == res.deployment.select_attention(*p)
        assert back.select_attention(*p) in back.attention_configs
