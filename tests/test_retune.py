"""Continuous tuning loop: telemetry, drift, incremental retune, hot-swap."""
import json
import threading

import numpy as np
import pytest

from repro.core import retune
from repro.core.bundle import DeploymentBundle
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.dispatch import Deployment
from repro.core.online import OnlinePolicy
from repro.core.tuner import tune
from repro.kernels import ops
from repro.kernels.matmul import config_space
from repro.kernels.ops import FixedPolicy
from repro.core.runtime import default_runtime as rt
from repro.core.runtime import reset_default_runtime


@pytest.fixture(autouse=True)
def _clean_policy():
    # Fresh default runtime per test: no hand-maintained clear_* choreography.
    yield
    reset_default_runtime()


@pytest.fixture(scope="module")
def tuned():
    ds = build_model_dataset(synthetic_problems(80), device_name="tpu_v5e")
    return tune(ds, n_kernels=6), ds


def _shifted_snapshot(n: int = 100, seed: int = 1) -> retune.TelemetrySnapshot:
    """Decode-heavy deep-k traffic, disjoint from the synthetic tuning mix."""
    rng = np.random.default_rng(seed)
    snap = retune.TelemetrySnapshot()
    for _ in range(n):
        p = (int(rng.choice([1, 2, 4])), int(rng.choice([8192, 16384])),
             int(rng.choice([1024, 2048])), 1)
        b = retune.shape_bucket(p)
        snap.matmul_counts[b] = snap.matmul_counts.get(b, 0) + 1
        snap.problems[b] = p
        snap.n_events += 1
    return snap


def _snapshot_of(problems) -> retune.TelemetrySnapshot:
    snap = retune.TelemetrySnapshot()
    for p in problems:
        b = retune.shape_bucket(p)
        snap.matmul_counts[b] = snap.matmul_counts.get(b, 0) + 1
        snap.problems[b] = tuple(p)
        snap.n_events += 1
    return snap


# ---------------------------------------------------------------------------
# provenance + drift metric
# ---------------------------------------------------------------------------
def test_train_distribution_is_json_roundtrippable(tuned):
    res, ds = tuned
    dist = res.deployment.meta["train_distribution"]
    back = json.loads(json.dumps(dist))
    assert back == dist
    assert abs(sum(e["w"] for e in dist["buckets"].values()) - 1.0) < 1e-9
    # keys parse back to the buckets of the training problems
    keys = {retune.parse_bucket_key(k) for k in dist["buckets"]}
    assert keys == {retune.shape_bucket(p) for p in res.train.problems}


def test_js_divergence_bounds():
    p = {(1,): 0.5, (2,): 0.5}
    assert retune.js_divergence(p, p) == pytest.approx(0.0, abs=1e-12)
    q = {(3,): 1.0}
    assert retune.js_divergence(p, q) == pytest.approx(1.0, abs=1e-9)


def test_no_drift_on_training_distribution(tuned):
    res, _ = tuned
    snap = _snapshot_of(res.train.problems)
    rep = retune.detect_drift(snap, res.deployment)
    assert rep.score == pytest.approx(0.0, abs=1e-9)
    assert not rep.triggered and rep.unseen_fraction == 0.0


def test_drift_fires_on_shifted_traffic(tuned):
    res, _ = tuned
    rep = retune.detect_drift(_shifted_snapshot(), res.deployment)
    assert rep.triggered and rep.score > 0.5 and rep.unseen_fraction > 0.5
    assert rep.drifted_buckets  # re-harvest targets identified


def test_drift_respects_min_events(tuned):
    res, _ = tuned
    rep = retune.detect_drift(_shifted_snapshot(5), res.deployment, min_events=32)
    assert rep.score > 0.5 and not rep.triggered


def test_no_provenance_means_everything_unseen(tuned):
    res, _ = tuned
    bare = Deployment(
        device="tpu_v5e", configs=res.deployment.configs,
        classifier=res.deployment.classifier, meta={},
    )
    rep = retune.detect_drift(_shifted_snapshot(), bare)
    assert rep.score == 1.0 and rep.unseen_fraction == 1.0 and rep.triggered


def test_snapshot_from_selection_log_counts_cache_hits(tuned):
    res, _ = tuned
    rt().install(res.deployment)
    rt().set_selection_logging(True)
    rt().clear_selection_log()
    for _ in range(5):  # 1 miss + 4 cache hits: all must count as traffic
        ops.select_matmul_config(512, 784, 512, 16)
    snap = retune.TelemetrySnapshot.from_selection_log(ops.selection_log())
    b = retune.shape_bucket((512, 784, 512, 16))
    assert snap.matmul_counts[b] == 5 and snap.n_events == 5
    assert snap.problems[b] == (512, 784, 512, 16)


# ---------------------------------------------------------------------------
# incremental retune
# ---------------------------------------------------------------------------
def test_incremental_retune_reduces_drift_and_updates_provenance(tuned):
    res, _ = tuned
    snap = _shifted_snapshot()
    rep = retune.detect_drift(snap, res.deployment)
    out = retune.incremental_retune(res.deployment, snap, report=rep)
    nd = out.deployment
    assert out.warm_started and out.n_harvested > 0
    assert len(nd.configs) == len(res.deployment.configs)
    assert nd.meta["retune_count"] == 1
    assert nd.attention_configs == res.deployment.attention_configs  # carried over
    assert nd.attention_tree is res.deployment.attention_tree
    # the retuned artifact is measurably closer to the live distribution
    rep2 = retune.detect_drift(snap, nd)
    assert rep2.score < rep.score
    # and still answers the KernelPolicy protocol on live shapes
    cfg = nd.select_matmul(1, 8192, 1024, 1)
    assert cfg in nd.configs
    # blob round-trip keeps provenance (flat v2 payload)
    back = Deployment.from_blob(nd.to_blob())
    assert back.meta["train_distribution"] == nd.meta["train_distribution"]
    assert back.meta["retune_count"] == 1


def test_incremental_retune_classifier_tracks_live_buckets(tuned):
    """Traffic-weighted refit: live shapes get on-distribution predictions."""
    res, _ = tuned
    snap = _shifted_snapshot(200)
    nd = retune.incremental_retune(res.deployment, snap).deployment
    from repro.core.perfmodel import TPU_V5E, predict_time

    worse = 0
    for p in snap.problems.values():
        t_new = predict_time(p, nd.select_matmul(*p), TPU_V5E)
        t_old = predict_time(p, res.deployment.select_matmul(*p), TPU_V5E)
        worse += t_new > t_old * 1.05
    # the retuned deployment must not lose on the shapes it retuned FOR
    assert worse <= len(snap.problems) // 3


def test_incremental_retune_rejects_unmodeled_device(tuned):
    res, _ = tuned
    dep = Deployment(device="host_cpu", configs=res.deployment.configs,
                     classifier=res.deployment.classifier, meta={})
    with pytest.raises(ValueError, match="dataset_builder"):
        retune.incremental_retune(dep, _shifted_snapshot())


def test_warm_start_kmeans_respects_init_centers():
    from repro.core.cluster import kmeans

    rng = np.random.default_rng(0)
    x = np.vstack([rng.normal(0, 0.1, (20, 3)), rng.normal(5, 0.1, (20, 3))])
    labels, centers = kmeans(x, 2, init_centers=np.array([[0.0, 0, 0], [5.0, 5, 5]]))
    assert centers.shape == (2, 3)
    assert len(set(labels[:20])) == 1 and len(set(labels[20:])) == 1


def test_fit_weighted_replicates_for_weightless_classifiers():
    from repro.core.classify import KNeighborsClassifier, fit_weighted

    x = np.array([[0.0], [1.0], [2.0], [3.0]])
    y = np.array([0, 0, 1, 1])
    clf = fit_weighted(KNeighborsClassifier(k=1), x, y, np.array([1.0, 1.0, 0.0, 4.0]))
    assert list(clf.predict(np.array([[2.9]]))) == [1]


# ---------------------------------------------------------------------------
# bundle v4 provenance
# ---------------------------------------------------------------------------
def test_bundle_v4_provenance_roundtrip(tmp_path, tuned):
    res, _ = tuned
    bundle = DeploymentBundle({"tpu_v5e": res.deployment})
    path = tmp_path / "b.json"
    bundle.save(path)
    blob = json.loads(path.read_text())
    assert blob["version"] == 6
    assert "train_distribution" in blob["provenance"]["tpu_v5e"]
    back = DeploymentBundle.load(path)
    got = back.deployments["tpu_v5e"].meta["train_distribution"]
    assert got == res.deployment.meta["train_distribution"]


def test_bundle_v3_blob_without_provenance_still_loads(tmp_path, tuned):
    res, _ = tuned
    blob = DeploymentBundle({"tpu_v5e": res.deployment}).to_blob()
    blob["version"] = 3
    del blob["provenance"]
    del blob["checksums"]  # a genuine v3 artifact carries no checksum block
    # strip meta provenance to simulate a genuinely old artifact
    blob["deployments"]["tpu_v5e"]["meta"] = {}
    back = DeploymentBundle.from_blob(blob)
    assert back.devices == ["tpu_v5e"]
    assert "train_distribution" not in back.deployments["tpu_v5e"].meta


# ---------------------------------------------------------------------------
# OnlinePolicy prior hot-swap (regression: stale _attn_cache)
# ---------------------------------------------------------------------------
def test_online_policy_set_prior_invalidates_attn_cache(tuned):
    res, _ = tuned
    dep = res.deployment

    class OtherPrior:
        def select_attention(self, sq, skv, d):
            return "other"

        def select_matmul(self, m, k, n, batch):
            return dep.configs[0]

    pol = OnlinePolicy(lambda p, c: 1.0, dep.configs, prior=dep)
    first = pol.select_attention(128, 2048, 128)
    assert first == dep.select_attention(128, 2048, 128)
    assert pol.select_attention(128, 2048, 128) is first  # cached
    pol.set_prior(OtherPrior())
    # the swapped-in prior must be consulted, not the stale cache entry
    assert pol.select_attention(128, 2048, 128) == "other"


def test_online_policy_measurements_export():
    cands = list(config_space())[:3]
    pol = OnlinePolicy(lambda p, c: 0.5, cands)
    for _ in range(3):
        pol.select_matmul(512, 784, 512, 16)
    meas = pol.measurements()
    b = retune.shape_bucket((512, 784, 512, 16))
    assert b in meas and len(meas[b]) == 3
    assert all(t == pytest.approx(0.5) and n == 1 for _c, t, n in meas[b])
    snap = retune.TelemetrySnapshot.from_selection_log([], online=pol)
    assert b in snap.observed


# ---------------------------------------------------------------------------
# hot-swap under dispatch (regression: stale shape-cache entries)
# ---------------------------------------------------------------------------
def _two_policies():
    cfgs = list(config_space())
    a, b = cfgs[0], cfgs[-1]
    assert a != b
    return FixedPolicy(matmul_config=a), FixedPolicy(matmul_config=b), a, b


def test_hot_swap_invalidates_same_thread_shape_cache():
    pol_a, pol_b, cfg_a, cfg_b = _two_policies()
    rt().install_for_device("tpu_v5e", pol_a)
    rt().activate_device("tpu_v5e")
    assert ops.select_matmul_config(256, 256, 256, 1) == cfg_a
    assert ops.select_matmul_config(256, 256, 256, 1) == cfg_a  # cache hit
    assert ops.shape_cache_stats()["hits"] >= 1
    rt().install_for_device("tpu_v5e", pol_b)  # hot swap
    # the shape-memo entry from pol_a must not answer for pol_b
    assert ops.select_matmul_config(256, 256, 256, 1) == cfg_b


def test_hot_swap_epoch_bumps_only_on_live_device():
    pol_a, pol_b, *_ = _two_policies()
    rt().install_for_device("tpu_v5e", pol_a)
    rt().activate_device("tpu_v5e")
    e0 = ops.policy_epoch()
    rt().install_for_device("tpu_v4", pol_b)  # inactive: registration only
    assert ops.policy_epoch() == e0
    rt().install_for_device("tpu_v5e", pol_b)  # live: swap
    assert ops.policy_epoch() > e0


def test_concurrent_dispatch_never_sees_stale_policy_cache():
    """Workers hammering ops.matmul selection during a hot swap: once a thread
    has observed the new policy it may never fall back to a cached config of
    the old one, and every thread converges to the new policy."""
    pol_a, pol_b, cfg_a, cfg_b = _two_policies()
    rt().install_for_device("tpu_v5e", pol_a)
    rt().activate_device("tpu_v5e")

    stop = threading.Event()
    picks: dict[int, list] = {}
    errors: list = []

    def worker(wid: int):
        mine = picks[wid] = []
        try:
            while not stop.is_set():
                mine.append(ops.select_matmul_config(256, 256, 256, 1))
            mine.append(ops.select_matmul_config(256, 256, 256, 1))  # post-stop
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    # let every worker populate its thread-local shape cache with cfg_a
    import time

    time.sleep(0.05)
    rt().install_for_device("tpu_v5e", pol_b)  # the hot swap
    time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert not errors
    for wid, mine in picks.items():
        assert mine, f"worker {wid} made no selections"
        assert set(mine) <= {cfg_a, cfg_b}
        # monotone: once cfg_b is observed, cfg_a never reappears
        if cfg_b in mine:
            assert cfg_a not in mine[mine.index(cfg_b):], f"worker {wid} saw stale cache"
        # eventual consistency: the selection made after the swap+stop is new
        assert mine[-1] == cfg_b, f"worker {wid} never adopted the swapped policy"


def test_quarantined_config_never_served_from_stale_cache():
    """Two threads dispatching the same family while its config is
    quarantined: the breaker sits after the per-thread shape cache, so a
    warm cache entry from before the quarantine can never serve the
    quarantined config — every selection is redirected to the family
    default until the breaker re-probes."""
    from repro.core.families import get_family

    fam_default = get_family("matmul").default_config
    cfg_q = next(c for c in config_space() if c != fam_default)
    rt().install_for_device("tpu_v5e", FixedPolicy(matmul_config=cfg_q))
    rt().activate_device("tpu_v5e")

    warmed = threading.Barrier(3)
    quarantined = threading.Event()
    picks: dict[int, list] = {}
    errors: list = []

    def worker(wid: int):
        mine = picks[wid] = []
        try:
            # populate this thread's shape cache with the soon-bad config
            assert ops.select_matmul_config(256, 256, 256, 1) == cfg_q
            warmed.wait(timeout=10)
            quarantined.wait(timeout=10)
            for _ in range(20):
                mine.append(ops.select_matmul_config(256, 256, 256, 1))
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    warmed.wait(timeout=10)
    # repeated re-opens double the re-probe backoff past this test's window,
    # so no half-open probe can legitimately serve cfg_q below
    for _ in range(6):
        rt().quarantine_config("matmul", cfg_q, RuntimeError("injected fault"))
    quarantined.set()
    for t in threads:
        t.join(timeout=10)
    assert not errors
    for wid, mine in picks.items():
        assert len(mine) == 20
        assert cfg_q not in mine, f"worker {wid} served a quarantined config"
        assert set(mine) == {fam_default}
    (entry,) = rt().quarantined()
    assert entry["state"] == "open" and entry["skipped"] >= 40


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
class _ToyModel:
    vocab = 17

    def init_cache(self, b, cache_len):
        import jax.numpy as jnp

        return {"k": jnp.zeros((b, cache_len), jnp.float32)}

    def prefill(self, params, batch, cache_len):
        import jax

        tokens = batch["tokens"]
        cache = self.init_cache(tokens.shape[0], cache_len)
        logits = jax.nn.one_hot((tokens[:, -1:] + 1) % self.vocab, self.vocab)
        return logits, cache

    def decode_step(self, params, cache, tokens, positions):
        import jax

        return jax.nn.one_hot((tokens + 1) % self.vocab, self.vocab), cache


def test_engine_maybe_retune_swaps_policy(tuned):
    from repro.serve.engine import ServingEngine

    res, _ = tuned
    rt().install(res.deployment)
    eng = ServingEngine(_ToyModel(), params={}, max_batch=1, cache_len=16,
                        retune_interval=10_000, retune_min_events=8)
    assert ops.selection_logging_enabled()
    rt().clear_selection_log()
    rng = np.random.default_rng(2)
    for _ in range(50):  # shifted live traffic through the dispatch layer
        ops.select_matmul_config(int(rng.choice([1, 2])), 16384, 2048, 1)
    eng._prefill_cache[8] = object()  # a compiled program that must be dropped
    ev = eng.maybe_retune()
    assert ev is not None and ev.swapped
    assert eng.deployment is not None and eng.deployment is not res.deployment
    assert eng.deployment.meta["retune_count"] == 1
    assert ops.get_kernel_policy() is eng.deployment  # live policy swapped
    assert eng._prefill_cache == {}  # compiled programs invalidated
    assert ops.selection_log() == []  # fresh telemetry window


def test_engine_maybe_retune_propagates_prior_to_online_policy(tuned):
    """A hybrid-mode OnlinePolicy adopts the retuned deployment as prior."""
    from repro.serve.engine import ServingEngine

    res, _ = tuned
    rt().install(res.deployment)
    pol = OnlinePolicy(lambda p, c: 1.0, res.deployment.configs, prior=res.deployment)
    pol.select_attention(128, 2048, 128)  # populate the prior-derived cache
    eng = ServingEngine(_ToyModel(), params={}, max_batch=1, cache_len=16,
                        retune_interval=10_000, retune_min_events=8)
    rt().clear_selection_log()
    for _ in range(40):
        ops.select_matmul_config(1, 16384, 2048, 1)
    ev = eng.maybe_retune(online=pol)
    assert ev is not None and ev.swapped
    assert pol.prior is eng.deployment  # prior hot-swapped with the policy
    assert not pol._attn_cache  # and its stale attention cache dropped


def test_engine_maybe_retune_no_events_is_noop(tuned):
    from repro.serve.engine import ServingEngine

    res, _ = tuned
    rt().install(res.deployment)
    eng = ServingEngine(_ToyModel(), params={}, max_batch=1, cache_len=16,
                        retune_interval=10_000)
    rt().clear_selection_log()
    assert eng.maybe_retune() is None
    assert ops.get_kernel_policy() is res.deployment


# ---------------------------------------------------------------------------
# benchmarks/run.py exit-code propagation (the CI perf-gate depends on it)
# ---------------------------------------------------------------------------
def test_benchmark_runner_exits_nonzero_on_failure(tmp_path, monkeypatch, capsys):
    import benchmarks.run as run_mod

    class Boom:
        @staticmethod
        def main(quick=False):
            raise RuntimeError("boom")

    class Fine:
        @staticmethod
        def main(quick=False):
            return [("metric", 1.0, "derived")]

    out = tmp_path / "rows.json"
    monkeypatch.setitem(run_mod.MODULES, "fig2", Boom)
    monkeypatch.setitem(run_mod.MODULES, "fig3", Fine)
    rc = run_mod.main(["--only", "fig2", "--json", str(out)])
    assert rc == 1
    blob = json.loads(out.read_text())
    assert blob["failures"] and blob["failures"][0][0] == "fig2"
    assert run_mod.main(["--only", "fig3"]) == 0
    capsys.readouterr()


def test_perf_gate_flags_missing_baseline_metric():
    """A renamed/removed gated metric must fail the gate, not shrink it."""
    from benchmarks.perf_gate import gate

    verdicts, regressions = gate(
        {"fit_speedup": (10.0, "higher")},
        {"fit_speedup": 9.0, "predict_speedup": 5.0},
        tolerance=0.25,
    )
    assert verdicts["fit_speedup"]["ok"]
    assert not verdicts["predict_speedup"]["ok"]
    assert any("missing from the current run" in r for r in regressions)


def test_perf_gate_direction_aware_tolerance():
    from benchmarks.perf_gate import gate

    base = {"fit_speedup": 10.0, "fig7_x_tuned8_ms": 1000.0}
    _, regs = gate({"fit_speedup": (7.6, "higher"),
                    "fig7_x_tuned8_ms": (1240.0, "lower")}, base, 0.25)
    assert not regs  # both inside 25% in the good-enough direction
    _, regs = gate({"fit_speedup": (7.4, "higher"),
                    "fig7_x_tuned8_ms": (1260.0, "lower")}, base, 0.25)
    assert len(regs) == 2  # both just past the line


def test_benchmark_runner_catches_module_systemexit(monkeypatch, capsys):
    import benchmarks.run as run_mod

    class Tripwire:
        @staticmethod
        def main(quick=False):
            raise SystemExit("speedup regressed")

    monkeypatch.setitem(run_mod.MODULES, "fig2", Tripwire)
    rc = run_mod.main(["--only", "fig2"])
    assert rc == 1
    capsys.readouterr()


# ---------------------------------------------------------------------------
# telemetry wire form + federation merge (control-plane transport)
# ---------------------------------------------------------------------------
def _host_snapshot(seed: int) -> retune.TelemetrySnapshot:
    rng = np.random.default_rng(seed)
    snap = retune.TelemetrySnapshot()
    for _ in range(30):
        fam = str(rng.choice(["matmul", "ssm_scan"]))
        p = (int(rng.choice([1, 8, 64])), int(rng.choice([512, 4096])),
             int(rng.choice([512, 2048])), 1)
        b = retune.shape_bucket(p)
        counts = snap.counts.setdefault(fam, {})
        counts[b] = counts.get(b, 0) + 1
        snap.family_problems.setdefault(fam, {})[b] = p
        snap.n_events += 1
    snap.incidents.append({"seq": seed, "kind": "guarded", "site": "test"})
    snap.observed[(1, 2, 3, 0)] = [(None, 1e-3 * seed, 3)]
    return snap


def test_snapshot_wire_form_round_trips_exactly():
    snap = _host_snapshot(3)
    wire = snap.to_json()
    assert wire["version"] == 1
    back = retune.TelemetrySnapshot.from_json(json.loads(json.dumps(wire)))
    assert back.counts == snap.counts
    assert back.family_problems == snap.family_problems
    assert back.incidents == snap.incidents
    assert back.n_events == snap.n_events
    # a second trip is a fixed point (configs already name-flattened)
    assert back.to_json() == wire


def test_snapshot_merge_is_commutative_across_arrival_orders():
    import itertools

    hosts = [_host_snapshot(s) for s in (1, 2, 3)]
    aggregates = []
    for order in itertools.permutations(range(3)):
        agg = retune.TelemetrySnapshot()
        for i in order:
            agg.merge(retune.TelemetrySnapshot.from_json(hosts[i].to_json()))
        aggregates.append(agg.to_json())
    assert all(a == aggregates[0] for a in aggregates[1:])
    assert aggregates[0]["n_events"] == sum(h.n_events for h in hosts)


def test_snapshot_merge_accumulates_counts_and_keeps_max_problem():
    a, b = retune.TelemetrySnapshot(), retune.TelemetrySnapshot()
    p_small, p_big = (8, 512, 512, 1), (12, 700, 700, 1)
    bkt = retune.shape_bucket(p_small)
    assert bkt == retune.shape_bucket(p_big)  # same bucket, different members
    a.matmul_counts[bkt] = 2
    a.problems[bkt] = p_small
    a.n_events = 2
    b.matmul_counts[bkt] = 3
    b.problems[bkt] = p_big
    b.n_events = 3
    a.merge(b)
    assert a.matmul_counts[bkt] == 5 and a.n_events == 5
    assert a.problems[bkt] == p_big  # deterministic representative


def test_drift_verdict_identical_for_any_merge_order(tuned):
    res, _ = tuned
    hosts = [_shifted_snapshot(40, seed=s) for s in (1, 2)]
    ab = retune.TelemetrySnapshot()
    ab.merge(hosts[0]).merge(hosts[1])
    ba = retune.TelemetrySnapshot()
    ba.merge(hosts[1]).merge(hosts[0])
    ra = retune.detect_drift(ab, res.deployment, min_events=10)
    rb = retune.detect_drift(ba, res.deployment, min_events=10)
    assert (ra.score, ra.n_events, ra.triggered) == (rb.score, rb.n_events, rb.triggered)
    assert ra.triggered
