"""Pallas WKV kernel vs the jnp oracle: shape/config/state sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import wkv_ref
from repro.kernels.wkv import DEFAULT_WKV_CONFIG, WkvConfig, wkv_config_space, wkv_pallas
from repro.core.runtime import default_runtime as rt


def _inputs(b, s, h, hd, seed=0, with_state=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (b, s, h, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.5).clip(1e-3, 5.0)
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    state = jax.random.normal(ks[5], (b, h, hd, hd)) * 0.1 if with_state else None
    return r, k, v, logw, u, state


def _run_pallas(r, k, v, logw, u, state, cfg):
    b, s, h, hd = r.shape
    st = state if state is not None else jnp.zeros((b, h, hd, hd), jnp.float32)
    one = lambda rr, kk, vv, ww, uu, ss: wkv_pallas(rr, kk, vv, ww, uu, ss, cfg, interpret=True)
    fn = jax.vmap(jax.vmap(one, in_axes=(1, 1, 1, 1, 0, 0)), in_axes=(0, 0, 0, 0, None, 0))
    o, s_out = fn(r, k, v, logw, u, st)
    return o.transpose(0, 2, 1, 3), s_out


@pytest.mark.parametrize("s", [7, 16, 50, 128])
@pytest.mark.parametrize("with_state", [True, False])
def test_wkv_shapes(s, with_state):
    args = _inputs(2, s, 2, 64, with_state=with_state)
    o_ref, s_ref = wkv_ref(*args)
    o_p, s_p = _run_pallas(*args, DEFAULT_WKV_CONFIG)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", wkv_config_space())
def test_wkv_config_sweep(cfg):
    args = _inputs(1, 100, 2, 64, seed=3)
    o_ref, s_ref = wkv_ref(*args)
    o_p, s_p = _run_pallas(*args, cfg)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref), rtol=1e-4, atol=1e-4)


def test_wkv_state_chaining_equals_full_run():
    """run(s1) then run(s2 | state) == run(s1 + s2) — the serving invariant."""
    r, k, v, logw, u, _ = _inputs(1, 64, 2, 64, seed=5, with_state=False)
    o_full, s_full = wkv_ref(r, k, v, logw, u, None)
    half = 32
    o1, s1 = _run_pallas(r[:, :half], k[:, :half], v[:, :half], logw[:, :half], u, None, WkvConfig(16))
    o2, s2 = _run_pallas(r[:, half:], k[:, half:], v[:, half:], logw[:, half:], u, s1, WkvConfig(16))
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)), np.asarray(o_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-4, atol=1e-4)


def test_ops_wkv_pallas_path_matches_ref():
    args = _inputs(2, 40, 2, 64, seed=7)
    o_ref, s_ref = ops.wkv(*args)  # xla/jnp path
    rt().set_pallas_enabled(True, interpret=True)
    try:
        o_p, s_p = ops.wkv(*args)
    finally:
        rt().set_pallas_enabled(False)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref), rtol=1e-4, atol=1e-4)


def test_rwkv_model_uses_ops_wkv():
    """RWKV6 forward produces identical loss on both dispatch paths."""
    from repro.configs import registry
    from repro.models.model import build_model

    cfg = registry.get("rwkv6-7b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    loss_ref, _ = model.loss_fn(params, batch)
    rt().set_pallas_enabled(True, interpret=True)
    try:
        loss_p, _ = model.loss_fn(params, batch)
    finally:
        rt().set_pallas_enabled(False)
    np.testing.assert_allclose(float(loss_p), float(loss_ref), rtol=1e-4)
