"""Fault containment (DESIGN.md §11): injection plan, guarded dispatch,
quarantine circuit breaker, canary-gated hot-swap, auto-rollback, engine
retry.  The chaos CI job runs the same machinery end to end against a real
model (examples/chaos_demo.py); these tests pin each guarantee in isolation
plus the acceptance scenario on the deterministic toy engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import retune
from repro.core.bundle import BundleError, DeploymentBundle
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.faults import (
    FaultPlan,
    InjectedOOMError,
    incident,
)
from repro.core.families import get_family
from repro.core.runtime import (
    DEFAULT_INCIDENT_CAP,
    QUARANTINE_BACKOFF,
    KernelRuntime,
    default_runtime,
    reset_default_runtime,
)
from repro.core.tuner import tune
from repro.kernels import ops
from repro.kernels.matmul import config_space
from repro.kernels.ops import FixedPolicy
from repro.serve.engine import Request, ServingEngine


@pytest.fixture(autouse=True)
def _clean():
    yield
    reset_default_runtime()


@pytest.fixture(scope="module")
def tuned_dep():
    ds = build_model_dataset(synthetic_problems(80), device_name="tpu_v5e")
    return tune(ds, n_kernels=6).deployment


def _guarded_rt(seed: int = 0):
    """Runtime serving a non-default matmul config, with a fresh fault plan."""
    fam_default = get_family("matmul").default_config
    cfg = next(c for c in config_space() if c != fam_default)
    rt = KernelRuntime(name="faults-test")
    rt.install_for_device("tpu_v5e", FixedPolicy(matmul_config=cfg))
    rt.activate_device("tpu_v5e")
    plan = FaultPlan(seed=seed)
    rt.set_fault_plan(plan)
    return rt, plan, cfg


# ---------------------------------------------------------------------------
# FaultPlan: deterministic, seeded injection
# ---------------------------------------------------------------------------
def test_fault_plan_times_after_and_match():
    plan = FaultPlan(seed=0)
    spec = plan.inject("dispatch.matmul", "compile_error", times=2, after=1,
                       match="mm_")
    assert plan.fire("dispatch.matmul", "other") is None    # key match miss
    assert plan.fire("dispatch.matmul", "mm_x") is None     # 'after' skip
    assert plan.fire("dispatch.matmul", "mm_x") is spec
    assert plan.fire("dispatch.attention", "mm_x") is None  # site miss
    assert plan.fire("dispatch.matmul", "mm_x") is spec
    assert plan.fire("dispatch.matmul", "mm_x") is None     # times exhausted
    assert not plan.active
    assert [(e.seq, e.kind) for e in plan.events] == [
        (1, "compile_error"), (2, "compile_error")]


def test_fault_plan_prefix_site_and_parse():
    plan = FaultPlan.parse("dispatch.:latency:2, engine.prefill:oom", seed=3)
    assert [s.site for s in plan.specs()] == ["dispatch.", "engine.prefill"]
    assert plan.fire("dispatch.wkv").kind == "latency"      # prefix matches
    assert plan.fire("dispatch.matmul").kind == "latency"
    assert plan.fire("dispatch.matmul") is None
    with pytest.raises(InjectedOOMError):
        plan.raise_if("engine.prefill")
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultPlan.parse("nonsense")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(seed=0).inject("x", "segfault")


def test_fault_plan_probability_is_seeded():
    def firings(seed: int) -> list[bool]:
        plan = FaultPlan(seed=seed)
        plan.inject("s", "latency", times=None, p=0.5)
        return [plan.fire("s") is not None for _ in range(32)]

    assert firings(7) == firings(7)          # same seed, same schedule
    assert firings(7) != firings(8)
    assert 0 < sum(firings(7)) < 32          # genuinely probabilistic


def test_corrupt_text_is_spent_after_times():
    plan = FaultPlan(seed=1)
    plan.inject("bundle.load", "corrupt", value=8)
    out = plan.corrupt_text("bundle.load", "x" * 100)
    assert len(out) == 100 and out.count("#") >= 1
    assert plan.corrupt_text("bundle.load", "y" * 50) == "y" * 50  # spent


def test_incident_record_shape():
    rec = incident("dispatch.matmul", "matmul", None, RuntimeError("boom"),
                   "fallback_ref", device="tpu_v5e", seq=3)
    assert rec == {
        "seq": 3, "site": "dispatch.matmul", "family": "matmul",
        "config": None, "device": "tpu_v5e",
        "error": "RuntimeError: boom", "action": "fallback_ref",
    }


def test_incident_ring_buffer_caps_but_count_is_monotone():
    rt = KernelRuntime(name="cap")
    for _ in range(DEFAULT_INCIDENT_CAP + 44):
        rt.record_incident(incident("s", "f", None, "e", "a"))
    assert rt.incident_count() == DEFAULT_INCIDENT_CAP + 44
    assert len(rt.incidents()) == DEFAULT_INCIDENT_CAP
    assert rt.incidents()[-1]["seq"] == DEFAULT_INCIDENT_CAP + 44


# ---------------------------------------------------------------------------
# guarded dispatch: fallback, quarantine, re-probe, absolve
# ---------------------------------------------------------------------------
def test_injected_compile_error_serves_ref_and_quarantines():
    rt, plan, cfg = _guarded_rt()
    plan.inject("dispatch.matmul", "compile_error", times=1)
    with rt.activate():
        out = ops.matmul(jnp.ones((8, 16)), jnp.ones((16, 8)))
    # the caller never sees the fault: the reference path served the answer
    np.testing.assert_allclose(np.asarray(out), 16.0)
    (inc,) = [i for i in rt.incidents() if i["action"] == "quarantined"]
    assert inc["family"] == "matmul" and inc["config"] == cfg.name()
    assert "InjectedCompileError" in inc["error"]
    (q,) = rt.quarantined()
    assert q["name"] == cfg.name() and q["state"] == "open"


def test_nan_injection_is_contained_not_served():
    rt, plan, cfg = _guarded_rt()
    plan.inject("dispatch.matmul", "nan", times=1)
    with rt.activate():
        out = ops.matmul(jnp.ones((8, 16)), jnp.ones((16, 8)))
    assert bool(jnp.isfinite(out).all())  # poisoned output never reached the caller
    assert any("NonFiniteOutputError" in i["error"] for i in rt.incidents())
    assert rt.quarantined()


def test_nan_injection_never_poisons_a_jit_trace():
    # A nan spec firing while the op is being jit-traced must pass the
    # tracer through untouched: poisoning it would bake NaN into the
    # compiled program — uncontainable by the guard, which cannot inspect
    # values inside a trace (DESIGN.md §11).
    rt, plan, cfg = _guarded_rt()
    plan.inject("dispatch.matmul", "nan", times=1)
    x, y = jnp.ones((8, 16)), jnp.ones((16, 8))
    with rt.activate():
        out = jax.jit(lambda a, b: ops.matmul(a, b))(x, y)
        np.testing.assert_allclose(np.asarray(out), 16.0)
        # the spec fired (and was consumed) but corrupted nothing
        assert [e.kind for e in plan.events] == ["nan"]
        out2 = jax.jit(lambda a, b: ops.matmul(a, b))(x, y)
        np.testing.assert_allclose(np.asarray(out2), 16.0)
    assert not rt.quarantined()


def test_quarantine_reprobe_absolve_cycle():
    rt, plan, cfg = _guarded_rt()
    fam_default = get_family("matmul").default_config
    plan.inject("dispatch.matmul", "oom", times=1, match=cfg.name())
    x, y = jnp.ones((8, 16)), jnp.ones((16, 8))
    with rt.activate():
        ops.matmul(x, y)  # faults -> quarantined, ref served
        assert rt.quarantined()
        # while open, selections redirect to the family default...
        assert ops.select_matmul_config(8, 16, 8, 1) == fam_default
        # ...and after the backoff window a half-open probe re-runs cfg,
        # which now succeeds and closes the breaker.
        for _ in range(QUARANTINE_BACKOFF + 2):
            out = ops.matmul(x, y)
            assert bool(jnp.isfinite(out).all())
    assert not rt.quarantined()
    actions = [i["action"] for i in rt.incidents()]
    assert actions.count("quarantined") == 1 and actions.count("absolved") == 1


def test_quarantine_bumps_epoch_to_invalidate_shape_caches():
    rt, plan, cfg = _guarded_rt()
    with rt.activate():
        assert ops.select_matmul_config(256, 256, 256, 1) == cfg  # warm the cache
        e0 = rt.policy_epoch()
        rt.quarantine_config("matmul", cfg, RuntimeError("bad"))
        assert rt.policy_epoch() > e0
        # the warm entry cannot answer with the quarantined config
        assert ops.select_matmul_config(256, 256, 256, 1) != cfg
        e1 = rt.policy_epoch()
        rt.absolve("matmul", cfg)
        assert rt.policy_epoch() > e1
        assert ops.select_matmul_config(256, 256, 256, 1) == cfg


def test_latency_spike_records_incident_without_quarantine():
    rt, plan, cfg = _guarded_rt()
    plan.inject("dispatch.matmul", "latency", times=1, value=0.0)
    with rt.activate():
        out = ops.matmul(jnp.ones((4, 16)), jnp.ones((16, 4)))
    np.testing.assert_allclose(np.asarray(out), 16.0)
    assert any(i["action"] == "latency_spike" for i in rt.incidents())
    assert not rt.quarantined()  # slow is suspicious, not broken


def test_output_validation_opt_in_catches_real_non_finite():
    rt, _, cfg = _guarded_rt()
    rt.set_fault_plan(None)
    assert not rt.output_validation_enabled()
    rt.set_output_validation(True)
    assert rt.output_validation_enabled()
    bad = jnp.full((8, 16), jnp.nan)
    with rt.activate():
        ops.matmul(bad, jnp.ones((16, 8)))  # NaN in -> NaN out, flagged
    assert any("NonFiniteOutputError" in i["error"] for i in rt.incidents())
    assert rt.quarantined()


# ---------------------------------------------------------------------------
# canary-gated hot-swap
# ---------------------------------------------------------------------------
def _snap_of(problems) -> retune.TelemetrySnapshot:
    snap = retune.TelemetrySnapshot()
    for p in problems:
        b = retune.shape_bucket(p)
        snap.matmul_counts[b] = snap.matmul_counts.get(b, 0) + 1
        snap.problems[b] = tuple(p)
        snap.n_events += 1
    return snap


def test_canary_passes_trivially_without_traffic(tuned_dep):
    rep = retune.canary_deployment(tuned_dep, tuned_dep, retune.TelemetrySnapshot())
    assert rep.ok and rep.reason == "no holdout traffic"


def test_canary_same_deployment_passes_with_traffic(tuned_dep):
    snap = _snap_of([(64, 256, 512, 1), (1, 4096, 1024, 1)])
    rep = retune.canary_deployment(tuned_dep, tuned_dep, snap)
    assert rep.ok and rep.selection_ok and rep.numeric_ok


def test_canary_fault_site_rejects_candidate(tuned_dep):
    snap = _snap_of([(64, 256, 512, 1)])
    rt = KernelRuntime(name="canary")
    plan = FaultPlan(seed=0)
    plan.inject("canary.matmul", "compile_error", times=1)
    rt.set_fault_plan(plan)
    rep = retune.canary_deployment(tuned_dep, tuned_dep, snap, runtime=rt)
    assert not rep.ok and not rep.numeric_ok and rep.selection_ok


def _drifted_engine(tuned_dep, plan=None, **kw):
    """Engine over a runtime carrying drifted matmul telemetry."""
    import sys

    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
    from test_serve_engine import ToyModel

    rt = KernelRuntime(name="retune-chaos")
    rt.install(tuned_dep)
    if plan is not None:
        rt.set_fault_plan(plan)
    rt.set_selection_logging(True)
    rng = np.random.default_rng(0)
    with rt.activate():
        for _ in range(60):  # decode-heavy deep-k mix the tuning never saw
            ops.select_matmul_config(int(rng.choice([1, 2, 4])),
                                     int(rng.choice([8192, 16384])),
                                     int(rng.choice([1024, 2048])), 1)
    eng = ServingEngine(ToyModel(), params={}, max_batch=1, cache_len=32,
                        prefill_buckets=(8,), runtime=rt,
                        retune_min_events=8, drift_threshold=0.15, **kw)
    return eng, rt


def test_retune_candidate_fault_is_rejected(tuned_dep):
    plan = FaultPlan(seed=0)
    plan.inject("retune.candidate", "compile_error", times=None)
    eng, rt = _drifted_engine(tuned_dep, plan)
    ev = eng.maybe_retune(force=True)
    assert ev is not None and not ev.swapped and "matmul" in ev.rejected
    assert any(i["action"] == "candidate_failed" for i in rt.incidents())
    assert rt.policy() is tuned_dep  # incumbent untouched


def test_canary_rejects_numerically_poisoned_candidate(tuned_dep):
    plan = FaultPlan(seed=0)
    plan.inject("canary.matmul", "nan", times=None)
    eng, rt = _drifted_engine(tuned_dep, plan)
    ev = eng.maybe_retune(force=True)
    assert ev is not None and not ev.swapped and "matmul" in ev.rejected
    assert any(i["action"] == "candidate_rejected" for i in rt.incidents())
    assert rt.policy() is tuned_dep


def test_clean_candidate_swaps_and_arms_rollback_watchdog(tuned_dep):
    eng, rt = _drifted_engine(tuned_dep)
    ev = eng.maybe_retune(force=True)
    assert ev is not None and ev.swapped and not ev.rejected
    assert rt.policy() is not tuned_dep
    assert eng._swap_history and eng._incidents_at_swap is not None


# ---------------------------------------------------------------------------
# engine: retry, health state machine, auto-rollback
# ---------------------------------------------------------------------------
def test_engine_retries_survive_faults_with_zero_drops():
    import sys

    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
    from test_serve_engine import ToyModel

    rt = KernelRuntime(name="eng-chaos")
    plan = FaultPlan(seed=0)
    plan.inject("engine.prefill", "compile_error", times=1)
    plan.inject("engine.decode", "oom", times=1)
    rt.set_fault_plan(plan)
    eng = ServingEngine(ToyModel(), params={}, max_batch=1, cache_len=32,
                        prefill_buckets=(8,), runtime=rt)
    reqs = [Request(uid=i, prompt=np.array([1, 2, 3], dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    status = eng.run(reqs)
    assert status.completed == 3 and not status.exhausted  # zero drops
    assert all(r.done and r.state == "done" for r in reqs)
    assert sum(r.retries for r in reqs) >= 1  # a prefill retry was attributed
    actions = [i["action"] for i in rt.incidents()]
    assert actions.count("retry") == 2
    # health dipped to degraded while incidents were fresh, recovered clean
    states = [s for _, s in eng.health_events]
    assert states[0] == "degraded" and states[-1] == "healthy"
    assert status.health == "healthy"


def test_regressing_hot_swap_rolls_back_mid_run(tuned_dep):
    """The acceptance scenario: a swap happens, the new policy 'regresses'
    (incidents accumulate), the watchdog reinstalls the incumbent from swap
    history mid-run, and every request still completes."""
    eng, rt = _drifted_engine(tuned_dep, rollback_threshold=2)
    ev = eng.maybe_retune(force=True)
    assert ev is not None and ev.swapped
    swapped = rt.policy()
    assert swapped is not tuned_dep
    # the swapped-in policy starts faulting
    plan = FaultPlan(seed=0)
    plan.inject("engine.decode", "oom", times=2)
    rt.set_fault_plan(plan)
    reqs = [Request(uid=i, prompt=np.array([1, 2, 3], dtype=np.int32),
                    max_new_tokens=6) for i in range(2)]
    status = eng.run(reqs)
    assert status.completed == 2 and not status.exhausted  # zero drops
    rb = [e for e in eng.retune_events if e.rolled_back]
    assert len(rb) == 1 and rb[0].swapped
    assert rt.policy() is tuned_dep and eng.deployment is tuned_dep
    assert any(i["action"] == "rollback" for i in rt.incidents())
    assert eng.maybe_rollback() is None  # one rollback per swap
    states = [s for _, s in eng.health_events]
    assert "degraded" in states and eng.health == "healthy"
    assert status.health == "healthy"


def test_rollback_watchdog_requires_threshold():
    import sys

    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
    from test_serve_engine import ToyModel

    rt = KernelRuntime(name="watchdog")
    eng = ServingEngine(ToyModel(), params={}, max_batch=1, cache_len=32,
                        prefill_buckets=(8,), runtime=rt, rollback_threshold=3)
    prev = FixedPolicy()
    rt.install(FixedPolicy())
    eng._swap_history.append(prev)
    eng._incidents_at_swap = rt.incident_count()
    rt.record_incident(incident("s", "f", None, "e", "a"))
    assert eng.maybe_rollback() is None  # 1 < 3: stays put
    rt.record_incident(incident("s", "f", None, "e", "a"))
    rt.record_incident(incident("s", "f", None, "e", "a"))
    ev = eng.maybe_rollback()
    assert ev is not None and ev.rolled_back and rt.policy() is prev


# ---------------------------------------------------------------------------
# bundle.load chaos site
# ---------------------------------------------------------------------------
def test_bundle_load_corruption_surfaces_structured_error(tmp_path, tuned_dep):
    path = tmp_path / "b.json"
    DeploymentBundle({"tpu_v5e": tuned_dep}).save(path)
    plan = FaultPlan(seed=2)
    plan.inject("bundle.load", "corrupt", times=1, value=64)
    default_runtime().set_fault_plan(plan)
    with pytest.raises(BundleError):  # bit rot never escapes unstructured
        DeploymentBundle.load(path)
    # the spec is spent: the very next load of the same artifact is clean
    back = DeploymentBundle.load(path)
    assert back.devices == ["tpu_v5e"] and not back.load_errors
