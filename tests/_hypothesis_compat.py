"""Property-test shim: real hypothesis when installed, fixed-seed sweep otherwise.

Test modules import ``given`` / ``settings`` / ``st`` from here instead of
from ``hypothesis`` directly, so the tier-1 suite collects and runs on bare
environments (hypothesis is declared in requirements-dev.txt, not required).
The fallback draws a deterministic sample sweep from each strategy — weaker
than real shrinking-equipped property testing, but it executes the same
property bodies.
"""
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare environments
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    st = _Strategies()

    def settings(max_examples: int = 20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*gargs, **gkw):
        def deco(fn):
            def run():
                rng = np.random.default_rng(0)
                n = min(getattr(run, "_max_examples", getattr(fn, "_max_examples", 12)), 12)
                for _ in range(n):
                    vals = [s.draw(rng) for s in gargs]
                    kvals = {k: s.draw(rng) for k, s in gkw.items()}
                    fn(*vals, **kvals)

            # keep pytest's collected name/doc, but NOT the wrapped signature —
            # the strategy params must not be mistaken for pytest fixtures
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run

        return deco
