"""Compiled selection fast path: flat trees, dispatch cache, blob formats."""
import json

import numpy as np
import pytest

from repro.core.classify import DecisionTreeClassifier, RandomForestClassifier
from repro.core.codegen import dict_to_tree, tree_to_dict, tree_to_flat_dict, tree_to_python
from repro.core.dataset import FEATURE_NAMES, build_model_dataset, synthetic_problems
from repro.core.dispatch import Deployment
from repro.core.flattree import FlatTree
from repro.core.online import OnlinePolicy
from repro.core.tuner import tune
from repro.kernels import ops
from repro.kernels.matmul import config_space
from repro.core.runtime import default_runtime as rt
from repro.core.runtime import reset_default_runtime


@pytest.fixture(autouse=True)
def _clean_ops_state():
    # Fresh default runtime per test: no hand-maintained clear_* choreography.
    yield
    reset_default_runtime()


def _fit_random_tree(seed, n=120, d=4, k=5, **kw):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    y = rng.integers(0, k, size=n)
    return DecisionTreeClassifier(**kw).fit(x, y), rng


# ---------------------------------------------------------------------------
# flat-tree <-> nested-walk <-> generated-source equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(8))
def test_flat_predict_matches_nested_walk(seed):
    clf, rng = _fit_random_tree(seed, n=80 + 17 * seed, k=2 + seed % 4)
    xt = rng.normal(size=(500, 4)) * 3
    np.testing.assert_array_equal(clf.predict(xt), clf.predict_nested(xt))
    # the compiled form is a real flat tree with valid structure
    flat = clf.flat_
    assert isinstance(flat, FlatTree)
    flat.validate()
    assert flat.n_leaves() == clf.n_leaves()


def test_flat_predict_matches_generated_source():
    clf, rng = _fit_random_tree(3, d=len(FEATURE_NAMES))
    src = tree_to_python(clf)
    ns = {}
    exec(src, ns)  # noqa: S102 — generated launcher code, the paper's embedding
    xt = rng.normal(size=(300, len(FEATURE_NAMES))) * 3
    want = [ns["select_kernel"](*row) for row in xt]
    np.testing.assert_array_equal(clf.predict(xt), want)
    np.testing.assert_array_equal(clf.predict_nested(xt), want)


def test_flat_predict_no_python_recursion_on_large_batches():
    """10k-row predict iterates the tree depth, not the row count."""
    clf, rng = _fit_random_tree(0, n=400)
    xt = rng.normal(size=(10_000, 4)) * 2
    calls = {"n": 0}
    orig = FlatTree.apply

    def counting_apply(self, x):
        calls["n"] += 1
        return orig(self, x)

    FlatTree.apply = counting_apply
    try:
        out = clf.predict(xt)
    finally:
        FlatTree.apply = orig
    assert out.shape == (10_000,)
    assert calls["n"] == 1  # one vectorized descent for the whole batch


def test_forest_counts_match_nested(rng):
    x = rng.normal(size=(150, 5))
    y = (x[:, 0] > 0).astype(int) + 2 * (x[:, 1] > 0)
    rf = RandomForestClassifier(n_trees=8).fit(x, y)
    xt = rng.normal(size=(200, 5))
    for tree in rf.trees_:
        flat_counts = tree.predict_counts(xt)
        # nested oracle: strip the counts matrix to force the per-row fallback
        flat = tree.flat_
        tree.flat_ = FlatTree(flat.feature, flat.threshold, flat.left, flat.right,
                              flat.label, flat.n_classes, None)
        nested_counts = tree.predict_counts(xt)
        tree.flat_ = flat
        np.testing.assert_allclose(flat_counts, nested_counts)
    assert ((rf.predict(xt) >= 0) & (rf.predict(xt) < 4)).all()


# ---------------------------------------------------------------------------
# serialization: v1 (nested) and v2 (flat) round trips + back-compat
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
def test_tree_blob_roundtrips_both_formats(seed):
    clf, rng = _fit_random_tree(seed)
    xt = rng.normal(size=(300, 4)) * 3
    want = clf.predict(xt)
    for blob in (tree_to_dict(clf), tree_to_flat_dict(clf)):
        back = dict_to_tree(json.loads(json.dumps(blob)))  # through real JSON
        np.testing.assert_array_equal(back.predict(xt), want)
        np.testing.assert_array_equal(back.predict_nested(xt), want)
        # codegen still works on either parse
        assert tree_to_python(back).startswith("def select_kernel(")


def test_deployment_v1_and_v2_load_identically(tmp_path):
    ds = build_model_dataset(synthetic_problems(60))
    res = tune(ds, n_kernels=5)
    p_flat = tmp_path / "v2.json"
    p_nested = tmp_path / "v1.json"
    res.deployment.save(p_flat)
    res.deployment.save(p_nested, tree_format="nested")
    assert json.loads(p_flat.read_text())["tree"]["format"] == "flat"
    assert "root" in json.loads(p_nested.read_text())["tree"]
    a = Deployment.load(p_flat)
    b = Deployment.load(p_nested)
    assert a.configs == b.configs == res.deployment.configs
    for prob in [(64, 256, 512, 1), (1, 4096, 1024, 1), (2048, 2048, 2048, 8), (512, 784, 512, 16)]:
        assert a.select_matmul(*prob) == b.select_matmul(*prob) == res.deployment.select_matmul(*prob)
    for ap in [(128, 128, 64), (1, 2048, 128)]:
        assert a.select_attention(*ap) == b.select_attention(*ap)


def test_deployment_load_rejects_out_of_range_labels(tmp_path):
    ds = build_model_dataset(synthetic_problems(60))
    res = tune(ds, n_kernels=5)
    path = tmp_path / "d.json"
    res.deployment.save(path)
    blob = json.loads(path.read_text())
    blob["configs"] = blob["configs"][:2]  # truncate: tree labels now dangle
    path.write_text(json.dumps(blob))
    with pytest.raises(ValueError, match="configs are deployed"):
        Deployment.load(path)


def test_flat_blob_structural_validation():
    with pytest.raises(ValueError):  # child index out of range
        FlatTree.from_dict(
            {"format": "flat", "n_classes": 2, "feature": [0], "threshold": [0.0],
             "left": [5], "right": [1], "label": [0]}
        )
    with pytest.raises(ValueError):  # self-referential node: predict would hang
        FlatTree.from_dict(
            {"format": "flat", "n_classes": 2, "feature": [0], "threshold": [0.5],
             "left": [0], "right": [0], "label": [0]}
        )
    with pytest.raises(ValueError):  # back-edge cycle between two nodes
        FlatTree.from_dict(
            {"format": "flat", "n_classes": 2, "feature": [0, 0, -1], "threshold": [0.5, 0.5, 0.0],
             "left": [1, 0, -1], "right": [2, 2, -1], "label": [0, 0, 1]}
        )


# ---------------------------------------------------------------------------
# dispatch shape cache + bounded opt-in selection log
# ---------------------------------------------------------------------------
def test_shape_cache_hits_on_repeated_dispatch():
    ds = build_model_dataset(synthetic_problems(60))
    res = tune(ds, n_kernels=5)
    rt().install(res.deployment)
    cfg0 = ops.select_matmul_config(512, 784, 512, 16)
    stats = ops.shape_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 0
    for _ in range(5):
        assert ops.select_matmul_config(512, 784, 512, 16) == cfg0
    stats = ops.shape_cache_stats()
    assert stats["hits"] == 5 and stats["misses"] == 1
    # a different shape misses, and a policy swap clears the cache
    ops.select_matmul_config(1, 4096, 1024, 1)
    assert ops.shape_cache_stats()["misses"] == 2
    rt().install(res.deployment)
    assert ops.shape_cache_stats() == {"hits": 0, "misses": 0, "size": 0,
                                       "cap": ops.DEFAULT_SHAPE_CACHE_CAP,
                                       "per_family": {}}


def test_shape_cache_lru_eviction():
    ds = build_model_dataset(synthetic_problems(40))
    res = tune(ds, n_kernels=4)
    rt().install(res.deployment)
    rt().set_shape_cache_cap(4)
    try:
        for m in (8, 16, 32, 64, 128, 256):
            ops.select_matmul_config(m, 512, 512, 1)
        stats = ops.shape_cache_stats()
        assert stats["size"] == 4 and stats["cap"] == 4
        # oldest key evicted -> re-selecting it is a miss again
        ops.select_matmul_config(8, 512, 512, 1)
        assert ops.shape_cache_stats()["misses"] == 7
    finally:
        rt().set_shape_cache_cap(ops.DEFAULT_SHAPE_CACHE_CAP)


def test_online_policy_is_not_shape_cached():
    cands = list(config_space())[:4]
    times = iter(np.linspace(1.0, 0.1, 100))
    pol = OnlinePolicy(lambda p, c: next(times), cands, trials_per_arm=1)
    rt().install(pol)
    picks = [ops.select_matmul_config(512, 784, 512, 16) for _ in range(4)]
    assert picks == cands  # every call explored a fresh arm — no memoization
    assert ops.shape_cache_stats()["size"] == 0


def test_selection_log_opt_in_and_bounded():
    ds = build_model_dataset(synthetic_problems(40))
    res = tune(ds, n_kernels=4)
    rt().install(res.deployment)
    ops.select_matmul_config(64, 64, 64, 1)
    assert ops.selection_log() == []  # off by default
    rt().set_selection_logging(True, cap=8)
    for m in range(1, 21):
        ops.select_matmul_config(m, 64, 64, 1)
    log = ops.selection_log()
    assert len(log) == 8  # ring buffer keeps only the newest cap entries
    assert log[-1][1] == (20, 64, 64, 1)
    assert all(op == "matmul" for op, _, _ in log)
    rt().set_selection_logging(False, cap=ops.DEFAULT_LOG_CAP)
