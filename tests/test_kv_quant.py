"""int8 KV-cache quantization (§Perf beyond-paper optimization)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.layers import quantize_kv
from repro.models.model import build_model


def test_quantize_kv_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((4, 16, 2, 32)).astype(np.float32))
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert q.shape == x.shape and s.shape == x.shape[:-1]
    back = q.astype(jnp.float32) * s[..., None]
    # absmax int8: error bounded by scale/2 = absmax/254 per vector
    err = np.abs(np.asarray(back - x))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-6
    assert np.all(err <= bound)


def test_quantize_kv_zeros():
    q, s = quantize_kv(jnp.zeros((2, 3, 4)))
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.isfinite(np.asarray(s)))


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "qwen3-moe-235b-a22b", "llama-3.2-vision-90b"])
def test_kv_quant_decode_close_to_fp(arch):
    """int8-cache decode logits ≈ fp-cache decode logits (quantization tol)."""
    cfg = registry.get(arch).reduced()
    fp = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    q8 = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32, kv_quant=True)
    params = fp.init(jax.random.PRNGKey(0))

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["image_embs"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.n_image_tokens, cfg.d_model), jnp.float32
        )

    out_fp, cache_fp = fp.prefill(params, batch, 32)
    out_q8, cache_q8 = q8.prefill(params, batch, 32)
    assert cache_q8["k"].dtype == jnp.int8
    assert "k_scale" in cache_q8
    # prefill logits identical (attention runs on unquantized k/v)
    np.testing.assert_allclose(np.asarray(out_fp), np.asarray(out_q8), rtol=1e-5, atol=1e-5)

    pos = jnp.full((2,), 12, jnp.int32)
    nxt = jnp.ones((2, 1), jnp.int32)
    log_fp, _ = fp.decode_step(params, cache_fp, nxt, pos)
    log_q8, cache_q8b = q8.decode_step(params, cache_q8, nxt, pos)
    assert cache_q8b["k"].dtype == jnp.int8
    # decode reads the quantized cache: small quantization error tolerated
    np.testing.assert_allclose(np.asarray(log_fp), np.asarray(log_q8), rtol=0.1, atol=0.15)
    # ranking preserved for the top token
    assert np.all(np.argmax(np.asarray(log_fp), -1) == np.argmax(np.asarray(log_q8), -1))


def test_kv_quant_cache_is_half_the_bytes():
    cfg = registry.get("granite-8b").reduced()
    fp = build_model(cfg, dtype=jnp.bfloat16, kv_quant=False)
    q8 = build_model(cfg, dtype=jnp.bfloat16, kv_quant=True)
    c_fp = jax.eval_shape(lambda: fp.init_cache(4, 128))
    c_q8 = jax.eval_shape(lambda: q8.init_cache(4, 128))
    bytes_fp = sum(np.prod(v.shape) * v.dtype.itemsize for v in jax.tree.leaves(c_fp))
    bytes_q8 = sum(np.prod(v.shape) * v.dtype.itemsize for v in jax.tree.leaves(c_q8))
    assert bytes_q8 < 0.65 * bytes_fp  # int8 + f32/hd scales ≈ 0.53x
