"""Roofline analyzer + dry-run HLO collective parsing."""
import json
from pathlib import Path

import pytest

from repro.launch.roofline import HBM_BW, PEAK_FLOPS, analyze_record, model_flops, table


def _fake_record(**kw):
    rec = {
        "arch": "phi4-mini-3.8b",
        "shape": "train_4k",
        "mesh": "single",
        "devices": 256,
        "flops": 1.8e13,
        "bytes_accessed": 3.0e11,
        "argument_bytes": 176_000_000,
        "output_bytes": 0,
        "temp_bytes": 39_000_000_000,
        "alias_bytes": 0,
        "collectives": {"all-reduce_bytes": 8.7e9, "all-gather_bytes": 9.8e8,
                        "all-reduce_count": 18, "all-gather_count": 29},
    }
    rec.update(kw)
    return rec


def test_model_flops_kinds():
    t = model_flops("phi4-mini-3.8b", "train_4k")
    p = model_flops("phi4-mini-3.8b", "prefill_32k")
    d = model_flops("phi4-mini-3.8b", "decode_32k")
    assert t == pytest.approx(3 * p)  # 6ND vs 2ND at equal tokens
    assert d < p / 1000  # one token vs 32k tokens
    # MoE uses active params only
    moe_t = model_flops("qwen3-moe-235b-a22b", "train_4k")
    from repro.configs import registry

    cfg = registry.get("qwen3-moe-235b-a22b")
    assert moe_t == pytest.approx(6.0 * cfg.n_active_params() * 256 * 4096)
    assert cfg.n_active_params() < 0.2 * cfg.n_params()


def test_analyze_record_terms():
    r = analyze_record(_fake_record())
    mf = model_flops("phi4-mini-3.8b", "train_4k")
    assert r["t_compute_s"] == pytest.approx(mf / 256 / PEAK_FLOPS)
    assert r["t_memory_s"] == pytest.approx(3.0e11 / HBM_BW)
    assert r["dominant"] in ("compute", "memory", "collective")
    assert 0 < r["roofline_fraction"] <= 1.0
    assert r["model_over_hlo"] > 1  # scan bodies under-counted by XLA
    assert "hint" in r and len(r["hint"]) > 10


def test_analyze_record_dominant_switch():
    # blow up the collectives: dominant flips
    r = analyze_record(_fake_record(collectives={"all-to-all_bytes": 1e13, "all-to-all_count": 1}))
    assert r["dominant"] == "collective"
    assert r["roofline_fraction"] < 0.5


def test_table_renders():
    rows = [analyze_record(_fake_record())]
    out = table(rows)
    assert "phi4-mini-3.8b" in out and "| arch |" in out


def test_collective_parsing_real_record():
    """The committed dry-run record has sane collective bytes."""
    p = Path(__file__).parent.parent / "experiments/dryrun/phi4-mini-3.8b__train_4k__single.json"
    if not p.exists():
        pytest.skip("dry-run record not generated yet")
    rec = json.loads(p.read_text())
    colls = rec["collectives"]
    assert colls.get("all-reduce_count", 0) > 0
    assert colls.get("all-reduce_bytes", 0) > 1e6  # gradient reductions exist


def test_collective_bytes_parser():
    from repro.launch.hloanalysis import collective_bytes

    hlo = """
      %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups={}
      %ag.1 = bf16[2048]{0} all-gather(%y), dimensions={0}
      %junk = f32[8,8]{1,0} add(%a, %b)
      %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%p, %q)
    """
    out = collective_bytes(hlo)
    assert out["all-reduce_bytes"] == 1024 * 512 * 4
    assert out["all-gather_bytes"] == 2048 * 2
    assert out["all-to-all_bytes"] == 2 * 16 * 16 * 4
    assert out["all-reduce_count"] == 1
