"""Per-architecture smoke tests: reduced config, one forward/train step on CPU.

Asserts output shapes and finiteness (no NaNs), plus prefill/decode cache
consistency: decoding token t+1 after a prefill of t tokens must match the
full forward pass logits at that position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.model import build_model
from repro.optim import adamw
from repro.train.train_step import make_train_step

ARCHS = sorted(registry.ARCHS)
B, S = 2, 32


def _batch(cfg, rng):
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["image_embs"] = jax.random.normal(rng, (B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = registry.get(arch).reduced()
        model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(0))
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch, built):
    cfg, model, params = built[arch]
    batch = _batch(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, adamw.AdamWConfig()))
    params2, opt, metrics = step(params, adamw.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, pq: acc or bool(jnp.any(pq)),
        jax.tree.map(lambda a, b: jnp.any(a != b), params, params2),
        False,
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_shapes_and_vocab(arch, built):
    cfg, model, params = built[arch]
    batch = _batch(cfg, jax.random.PRNGKey(2))
    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert float(loss) > 0
    if cfg.moe is not None:
        assert "aux_loss" in metrics


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, built):
    """decode_step(t) after prefill(0..t-1) == last-position logits of prefill(0..t)."""
    cfg, model, params = built[arch]
    batch = _batch(cfg, jax.random.PRNGKey(3))
    toks = batch["tokens"]
    cache_len = S + 8

    sub = dict(batch, tokens=toks[:, : S - 1])
    sub.pop("targets", None)
    _, cache = model.prefill(params, sub, cache_len)
    positions = jnp.full((B,), S - 1, jnp.int32)
    logits_dec, _ = model.decode_step(params, cache, toks[:, S - 1 :], positions)

    full = dict(batch)
    full.pop("targets", None)
    logits_full, _ = model.prefill(params, full, cache_len)

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, -1]), np.asarray(logits_full[:, -1]), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_is_position_aware(arch, built):
    cfg, model, params = built[arch]
    cache = model.init_cache(B, 16)
    if cfg.family == "audio":
        cache["memory"] = jnp.zeros((B, 8, cfg.d_model), jnp.float32)
    toks = jnp.ones((B, 1), jnp.int32)
    logits, new_cache = model.decode_step(params, cache, toks, jnp.zeros((B,), jnp.int32))
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab()
    assert np.all(np.isfinite(np.asarray(logits)))
    # cache was updated (some leaf changed)
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache))
    )
    assert changed


def test_arch_configs_match_assignment():
    """Exact assigned architecture specs (the task's public-pool table)."""
    t = {a: registry.get(a) for a in ARCHS}
    def chk(name, L, d, H, kv, ff, vocab):
        c = t[name]
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
            L, d, H, kv, ff, vocab), name

    chk("phi4-mini-3.8b", 32, 3072, 24, 8, 8192, 200064)
    chk("qwen2.5-32b", 64, 5120, 40, 8, 27648, 152064)
    chk("granite-8b", 36, 4096, 32, 8, 14336, 49152)
    chk("glm4-9b", 40, 4096, 32, 2, 13696, 151552)
    chk("llama-3.2-vision-90b", 100, 8192, 64, 8, 28672, 128256)
    chk("qwen3-moe-235b-a22b", 94, 4096, 64, 4, 1536, 151936)
    chk("dbrx-132b", 40, 6144, 48, 8, 10752, 100352)
    chk("hymba-1.5b", 32, 1600, 25, 5, 5504, 32001)
    chk("seamless-m4t-large-v2", 24, 1024, 16, 16, 8192, 256206)
    # rwkv6 is attention-free; n_heads are internal wkv heads (head_dim=64)
    chk("rwkv6-7b", 32, 4096, 64, 64, 14336, 65536)
    assert t["qwen3-moe-235b-a22b"].moe.n_experts == 128
    assert t["qwen3-moe-235b-a22b"].moe.top_k == 8
    assert t["dbrx-132b"].moe.n_experts == 16 and t["dbrx-132b"].moe.top_k == 4
    assert t["hymba-1.5b"].ssm_state == 16
    assert t["qwen2.5-32b"].qkv_bias


def test_input_specs_shapes():
    specs = registry.input_specs("phi4-mini-3.8b", "train_4k")
    assert specs["tokens"].shape == (256, 4096)
    assert specs["targets"].shape == (256, 4096)
    specs = registry.input_specs("llama-3.2-vision-90b", "prefill_32k")
    assert specs["tokens"].shape == (32, 32768)
    assert "image_embs" in specs
    specs = registry.input_specs("rwkv6-7b", "decode_32k")
    assert specs["tokens"].shape == (128, 1)
    specs = registry.input_specs("seamless-m4t-large-v2", "train_4k")
    assert "frames" in specs


def test_cells_and_skips():
    cells = registry.all_cells()
    # 10 archs x 4 shapes - 8 long_500k skips = 32 runnable cells
    assert len(cells) == 32
    skips = registry.skipped_cells()
    assert len(skips) == 8
    assert all(s[1] == "long_500k" for s in skips)
    assert ("rwkv6-7b", "long_500k") in cells
    assert ("hymba-1.5b", "long_500k") in cells
