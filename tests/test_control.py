"""Tuning control plane: registry versioning, job lifecycle, federation."""
import json
import threading
import time

import numpy as np
import pytest

from repro.core import retune
from repro.core.bundle import BundleFormatError, DeploymentBundle, parse_registry_uri
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.tuner import tune
from repro.control import (
    ArtifactRegistry,
    ArtifactVersion,
    ControlPlane,
    ControlPlaneClient,
    ControlPlaneError,
    PolicySubscriber,
    content_version,
)


@pytest.fixture(scope="module")
def tuned_bundle():
    ds = build_model_dataset(synthetic_problems(80), device_name="tpu_v5e")
    res = tune(ds, n_kernels=6)
    return DeploymentBundle({"tpu_v5e": res.deployment}, meta={"test": True})


@pytest.fixture()
def plane(tuned_bundle):
    """A running control plane whose tuner seam returns the tuned bundle."""
    p = ControlPlane(port=0, min_events=10, tuner=lambda spec: tuned_bundle)
    p.start()
    yield p
    p.stop()


def _shifted_snapshot(n: int = 100, seed: int = 1) -> retune.TelemetrySnapshot:
    """Deep-k decode traffic, disjoint from the synthetic tuning mix."""
    rng = np.random.default_rng(seed)
    snap = retune.TelemetrySnapshot()
    for _ in range(n):
        p = (int(rng.choice([1, 2, 4])), int(rng.choice([8192, 16384])),
             int(rng.choice([1024, 2048])), 1)
        b = retune.shape_bucket(p)
        snap.matmul_counts[b] = snap.matmul_counts.get(b, 0) + 1
        snap.problems[b] = p
        snap.n_events += 1
    return snap


# ---------------------------------------------------------------------------
# content-hash versioning + the registry
# ---------------------------------------------------------------------------
def test_content_version_tracks_content():
    blob = {"a": 1, "nested": {"x": [1, 2]}}
    v1 = content_version(blob)
    assert v1 == content_version({"nested": {"x": [1, 2]}, "a": 1})  # key order
    assert v1 != content_version({**blob, "a": 2})
    assert len(v1) == 12 and int(v1, 16) >= 0


def test_publish_is_idempotent_on_content(tuned_bundle):
    reg = ArtifactRegistry()
    r1 = reg.publish("default", tuned_bundle, spec={"archs": ["a"]})
    r2 = reg.publish("default", tuned_bundle, spec={"archs": ["a"]})
    assert r1.version == r2.version and r1.seq == r2.seq == 0
    assert [r.version for r in reg.versions("default")] == [r1.version]


def test_changed_blob_mints_new_version(tuned_bundle):
    reg = ArtifactRegistry()
    r1 = reg.publish("default", tuned_bundle)
    changed = DeploymentBundle(
        dict(tuned_bundle.deployments), meta={**tuned_bundle.meta, "note": "v2"}
    )
    r2 = reg.publish("default", changed, parent=r1.version)
    assert r2.version != r1.version
    assert (r1.seq, r2.seq) == (0, 1)
    assert reg.latest("default").version == r2.version
    assert r2.lineage["parent"] == r1.version
    rec, blob = reg.get("default", r1.version)  # older versions stay fetchable
    assert rec.version == r1.version == content_version(blob)


def test_registry_round_trips_through_disk(tmp_path, tuned_bundle):
    reg = ArtifactRegistry(tmp_path)
    rec = reg.publish("fleet", tuned_bundle, spec={"devices": ["tpu_v5e"]})
    reborn = ArtifactRegistry(tmp_path)  # a restarted control plane
    rec2, blob2 = reborn.get("fleet")
    assert rec2 == ArtifactVersion.from_json(rec.to_json())
    assert blob2 == tuned_bundle.to_blob()
    assert reborn.get_bundle("fleet").provenance() == tuned_bundle.provenance()


def test_unknown_artifact_and_version_raise(tuned_bundle):
    reg = ArtifactRegistry()
    with pytest.raises(KeyError):
        reg.get("nope")
    reg.publish("default", tuned_bundle)
    with pytest.raises(KeyError):
        reg.get("default", "cafecafecafe")


# ---------------------------------------------------------------------------
# registry URIs
# ---------------------------------------------------------------------------
def test_parse_registry_uri():
    assert parse_registry_uri("registry://h:80/fleet/abc123") == (
        "http://h:80", "fleet", "abc123")
    assert parse_registry_uri("registry://h:80/fleet") == (
        "http://h:80", "fleet", "latest")
    for bad in ("registry://h:80", "registry:///fleet", "file:///x"):
        with pytest.raises(BundleFormatError):
            parse_registry_uri(bad)


def test_load_bundle_opens_registry_uri(plane, tuned_bundle):
    import repro

    client = ControlPlaneClient(plane.url)
    job = client.submit({"kind": "tune", "name": "fleet"})
    client.wait_job(job["id"], timeout=60)
    uri = client.registry_uri("fleet")
    assert uri.startswith("registry://") and uri.endswith("/fleet/latest")
    bundle = repro.load_bundle(uri)
    assert bundle.to_blob() == tuned_bundle.to_blob()  # byte-identical payload
    # a plain http:// URL on the artifact route works too
    ver = plane.registry.latest("fleet").version
    direct = repro.load_bundle(f"{plane.url}/artifacts/fleet/{ver}")
    assert direct.to_blob() == tuned_bundle.to_blob()


def test_load_bundle_unreachable_registry_raises():
    with pytest.raises(BundleFormatError):
        DeploymentBundle.load("registry://127.0.0.1:9/missing/latest")


# ---------------------------------------------------------------------------
# job lifecycle over HTTP
# ---------------------------------------------------------------------------
def test_job_walks_queued_running_succeeded(plane):
    client = ControlPlaneClient(plane.url)
    job = client.submit({"kind": "tune", "name": "default"})
    assert job["state"] == "queued"
    done = client.wait_job(job["id"], timeout=60)
    assert done["state"] == "succeeded"
    assert [s for s, _t in done["history"]] == ["queued", "running", "succeeded"]
    ts = [t for _s, t in done["history"]]
    assert ts == sorted(ts)
    assert done["artifact"]["name"] == "default"
    assert done["artifact"]["version"] == plane.registry.latest("default").version


def test_crashing_tune_becomes_failed_job(tuned_bundle):
    def tuner(spec):
        raise RuntimeError("benchmark harness exploded")

    with ControlPlane(port=0, tuner=tuner) as plane:
        client = ControlPlaneClient(plane.url)
        job = client.submit({"kind": "tune"})
        done = client.wait_job(job["id"], timeout=60)
        assert done["state"] == "failed"
        assert "RuntimeError" in done["error"]
        assert "exploded" in done["error"]
        assert [s for s, _t in done["history"]] == ["queued", "running", "failed"]
        assert done["artifact"] is None


def test_bad_specs_and_unknown_routes(plane):
    client = ControlPlaneClient(plane.url)
    with pytest.raises(ControlPlaneError, match="400"):
        client.submit({"kind": "mystery"})
    with pytest.raises(ControlPlaneError, match="400"):
        client.submit({"kind": "retune"})  # no device
    with pytest.raises(ControlPlaneError, match="404"):
        client.job("job-9999")
    with pytest.raises(ControlPlaneError, match="404"):
        client.artifact("never-published")


def test_health_counts_jobs_and_artifacts(plane):
    client = ControlPlaneClient(plane.url)
    job = client.submit({"kind": "tune", "name": "default"})
    client.wait_job(job["id"], timeout=60)
    health = client.healthz()
    assert health["status"] == "ok"
    assert health["jobs"]["succeeded"] >= 1
    assert health["artifacts"]["default"] == 1
    assert health["uptime_s"] >= 0


# ---------------------------------------------------------------------------
# telemetry federation -> drift -> retune -> policy push
# ---------------------------------------------------------------------------
def test_federation_merges_and_triggers_once_over_min_events(plane):
    client = ControlPlaneClient(plane.url)
    job = client.submit({"kind": "tune", "name": "default"})
    client.wait_job(job["id"], timeout=60)

    ack1 = client.post_telemetry("tpu_v5e", _shifted_snapshot(6, seed=1), host="h1")
    assert ack1["merged_events"] == 6 and ack1["hosts"] == 1
    assert ack1["retune_job"] is None  # under the min-events floor
    assert not any(r["triggered"] for r in ack1["drift"].values())

    ack2 = client.post_telemetry("tpu_v5e", _shifted_snapshot(6, seed=2), host="h2")
    assert ack2["merged_events"] == 12 and ack2["hosts"] == 2
    assert ack2["drift"]["matmul"]["triggered"]
    assert ack2["retune_job"] is not None

    # a third post while the retune is pending does not double-schedule
    ack3 = client.post_telemetry("tpu_v5e", _shifted_snapshot(6, seed=3), host="h3")
    done = client.wait_job(ack2["retune_job"], timeout=120)
    assert ack3["retune_job"] in (None, ack2["retune_job"])
    assert done["state"] == "succeeded"
    art = done["artifact"]
    assert art["parent"] == plane.registry.versions("default")[0].version
    assert art["families"] == ["matmul"]
    assert len(plane.registry.versions("default")) == 2


def test_retune_without_telemetry_fails(plane):
    client = ControlPlaneClient(plane.url)
    job = client.submit({"kind": "tune", "name": "default"})
    client.wait_job(job["id"], timeout=60)
    bad = client.submit({"kind": "retune", "device": "tpu_v5e"})
    done = client.wait_job(bad["id"], timeout=60)
    assert done["state"] == "failed"
    assert "telemetry" in done["error"]


def test_policy_longpoll_delivers_and_times_out(plane):
    client = ControlPlaneClient(plane.url)
    assert client.policy("tpu_v5e", after=0, timeout=0.0) is None  # 204: empty board
    job = client.submit({"kind": "tune", "name": "default"})
    client.wait_job(job["id"], timeout=60)
    ent = client.policy("tpu_v5e", after=0, timeout=5.0)
    assert ent["seq"] == 1 and ent["job"] == job["id"]
    assert ent["version"] == plane.registry.latest("default").version
    assert client.policy("tpu_v5e", after=ent["seq"], timeout=0.0) is None

    # a parked long-poll wakes when the board advances
    got = {}

    def poll():
        got["ent"] = client.policy("tpu_v5e", after=ent["seq"], timeout=20.0)

    t = threading.Thread(target=poll)
    t.start()
    time.sleep(0.2)
    client.post_telemetry("tpu_v5e", _shifted_snapshot(40), host="h1")
    t.join(timeout=30.0)
    assert not t.is_alive()
    assert got["ent"] is not None and got["ent"]["seq"] == 2


def test_subscriber_applies_policy_to_runtime(plane, tuned_bundle):
    client = ControlPlaneClient(plane.url)
    job = client.submit({"kind": "tune", "name": "default"})
    client.wait_job(job["id"], timeout=60)

    rt = tuned_bundle.runtime(device="tpu_v5e", name="sub-test")
    epoch0 = rt.policy_epoch()
    with PolicySubscriber(client, "tpu_v5e", rt, poll_timeout=2.0) as sub:
        # start_from="current" skips the bring-up announcement...
        time.sleep(0.3)
        assert sub.updates == []
        # ...and delivers the retune announcement that follows
        ack = client.post_telemetry("tpu_v5e", _shifted_snapshot(40), host="h1")
        client.wait_job(ack["retune_job"], timeout=120)
        deadline = time.monotonic() + 20.0
        while time.monotonic() < deadline and not sub.updates:
            time.sleep(0.1)
    assert sub.errors == []
    assert [u["seq"] for u in sub.updates] == [2]
    assert sub.updates[0]["version"] == plane.registry.latest("default").version
    assert rt.policy_epoch() > epoch0  # hot-swapped into the live registry


def test_runtime_apply_policy_update_targets_device(tuned_bundle):
    rt = tuned_bundle.runtime(device="tpu_v5e", name="apply-test")
    dep, _ = tuned_bundle.deployment_for("tpu_v5e")
    assert rt.apply_policy_update(dep, "tpu_v5e") == "tpu_v5e"
    assert rt.active_device() == "tpu_v5e"


# ---------------------------------------------------------------------------
# HTTP edges
# ---------------------------------------------------------------------------
def test_telemetry_post_requires_device_and_snapshot(plane):
    client = ControlPlaneClient(plane.url)
    with pytest.raises(ControlPlaneError, match="400"):
        client._request("POST", "/telemetry", {"device": "tpu_v5e"})
    with pytest.raises(ControlPlaneError, match="400"):
        client._request("POST", "/telemetry", {"snapshot": {}})


def test_artifact_envelope_shape(plane):
    client = ControlPlaneClient(plane.url)
    job = client.submit({"kind": "tune", "name": "default"})
    client.wait_job(job["id"], timeout=60)
    env = client.artifact("default")
    assert env["format"] == "artifact"
    assert env["version"] == content_version(env["blob"])
    assert json.dumps(env)  # the whole envelope is JSON-serializable
    assert env["lineage"]["spec"]["name"] == "default"
